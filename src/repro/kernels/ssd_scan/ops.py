"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xdt, dta, b, c, *, chunk: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_scan_pallas(xdt, dta, b, c, chunk=chunk, interpret=interpret)


__all__ = ["ssd_scan", "ssd_scan_ref"]
