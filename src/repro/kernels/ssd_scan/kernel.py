"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid = (B, H, num_chunks); the chunk dimension iterates sequentially per
(batch, head), so the inter-chunk SSM state (P × N) lives in VMEM scratch
and is carried across grid steps — HBM sees each token exactly once.
Within a chunk the dual (quadratic) form runs on the MXU:

    y_intra[t] = Σ_{u≤t} (c_t·b_u) · exp(cum_t − cum_u) · xdt_u
    y_inter[t] = exp(cum_t) · c_t · state_in
    state_out  = exp(cum_L) · state_in + Σ_u exp(cum_L − cum_u) b_u ⊗ xdt_u

Chunk size is the VMEM knob: tiles (chunk × P) and (chunk × N) with
chunk = 128/256 keep the working set ≪ 16 MB VMEM and MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, dta_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)    # (ck, P)
    dta = dta_ref[0, :, 0].astype(jnp.float32)       # (ck,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)        # (ck, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)        # (ck, N)

    cum = jnp.cumsum(dta)                            # (ck,)
    # intra-chunk quadratic term
    seg = cum[:, None] - cum[None, :]                # (t, u)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(t_idx >= u_idx, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # (t, u)
    y_intra = jax.lax.dot_general(cb * decay, xdt,
                                  (((1,), (0,)), ((), ())))   # (t, P)
    # inter-chunk: contribution of carried state
    state = state_scr[...]                           # (P, N)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())))          # (t, P)
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    end = cum[-1]
    w = jnp.exp(end - cum)                           # (u,)
    bx = jax.lax.dot_general(xdt * w[:, None], b,
                             (((0,), (0,)), ((), ())))  # (P, N)
    state_scr[...] = state * jnp.exp(end) + bx


def ssd_scan_pallas(xdt, dta, b, c, *, chunk: int = 128,
                    interpret: bool = True):
    """xdt: (B,S,H,P); dta: (B,S,H); b/c: (B,S,H,N) -> y (B,S,H,P)."""
    B, S, H, P = xdt.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b_, h, ci: (b_, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h, ci: (b_, ci, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda b_, h, ci: (b_, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b_, h, ci: (b_, ci, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P),
                               lambda b_, h, ci: (b_, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, dta, b, c)
