"""Pure-jnp oracle for the SSD (Mamba-2) chunked scan kernel.

Sequential (non-chunked) reference recurrence:
    h_t = exp(dta_t) h_{t-1} + b_t ⊗ xdt_t
    y_t = c_t · h_t
All heads independent; b/c already expanded per-head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(xdt, dta, b, c, initial_state=None):
    """xdt: (B,S,H,P) dt-weighted inputs; dta: (B,S,H) log decays;
    b, c: (B,S,H,N). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = xdt.shape
    N = b.shape[-1]
    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h, inp):
        x_t, dta_t, b_t, c_t = inp
        decay = jnp.exp(dta_t)[..., None, None]            # (B,H,1,1)
        h = h * decay + x_t[..., :, None] * b_t[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    xs = (xdt.swapaxes(0, 1).astype(jnp.float32),
          dta.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), hT
