from repro.kernels.msp_select.ops import msp_select  # noqa: F401
from repro.kernels.msp_select.ref import msp_select_ref  # noqa: F401
