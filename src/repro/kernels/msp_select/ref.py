"""Pure-jnp oracle for the fused IDKD labeling kernel (msp_select)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def msp_select_ref(logits, *, temperature: float, k: int,
                   detector: str = "msp"):
    """Fused IDKD labeling pass (Algorithm 1 lines 5+7) on (N, C) logits:

    Returns (conf (N,), topk_vals (N,k), topk_idx (N,k)):
      * conf — detector confidence at T=1: max softmax probability
               (MSP, the default) or the energy score logsumexp(z)
      * topk — top-k of the *temperature* softmax, renormalized
               (the sparse soft label payload)

    The D_ID membership test (``conf > t_opt``) lives with the caller:
    the threshold is ROC-calibrated from these confidences, so it does
    not exist yet when the kernel runs.
    """
    lf = logits.astype(jnp.float32)
    if detector == "energy":
        conf = jax.nn.logsumexp(lf, axis=-1)
    else:
        probs1 = jax.nn.softmax(lf, axis=-1)
        conf = jnp.max(probs1, axis=-1)
    probsT = jax.nn.softmax(lf / temperature, axis=-1)
    vals, idx = jax.lax.top_k(probsT, k)
    vals = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    return conf, vals, idx.astype(jnp.int32)
