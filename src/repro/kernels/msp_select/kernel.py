"""Pallas TPU kernel: fused IDKD public-set labeling (msp_select).

IDKD's hot loop reads every public-set logit row once and produces
(i) detector confidence and (ii) the top-k sparse soft label. Unfused,
XLA performs 2 HBM passes over the (N × vocab) logits (softmax@T=1 →
max; softmax@T → top_k); this kernel does one pass with everything
fused in VMEM. The D_ID membership bit is *not* computed here: the
threshold is ROC-calibrated from the confidences downstream, so the
mask is one compare the caller owns (``conf > t_opt``) — see
``kernels/head_select`` for the vocab-tiled generalization that starts
from hidden states instead of logits.

Tiling: (block_n × C) row tiles — the vocab axis stays resident in VMEM
(256k vocab ≈ 1 MB/row in f32, so block_n is chosen so block_n × C × 4B
fits comfortably; 8 rows × 257k ≈ 8 MB). Top-k (k ≤ 16) is computed by
iterative argmax on the VMEM tile — k sequential VPU max-reductions beat
a full sort at these k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _msp_kernel(logits_ref, conf_ref, vals_ref, idx_ref, *,
                temperature: float, k: int, detector: str):
    lf = logits_ref[...].astype(jnp.float32)               # (bn, C)
    # detector confidence at T=1 from one stable softmax reduction:
    # MSP = exp(0)/Σexp(lf−m1); energy = logsumexp = m1 + log Σexp(lf−m1)
    m1 = jnp.max(lf, axis=-1, keepdims=True)
    z1 = jnp.sum(jnp.exp(lf - m1), axis=-1)
    if detector == "energy":
        conf = m1[:, 0] + jnp.log(jnp.maximum(z1, 1e-30))
    else:
        conf = 1.0 / jnp.maximum(z1, 1e-30)
    conf_ref[...] = conf
    # temperature softmax for the soft labels
    lT = lf / temperature
    mT = jnp.max(lT, axis=-1, keepdims=True)
    eT = jnp.exp(lT - mT)
    zT = jnp.sum(eT, axis=-1, keepdims=True)
    probs = eT / jnp.maximum(zT, 1e-30)                    # (bn, C)

    # iterative top-k by repeated argmax (k small)
    work = probs
    total = jnp.zeros((probs.shape[0],), jnp.float32)
    vals_list, idx_list = [], []
    cols = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    for j in range(k):
        v = jnp.max(work, axis=-1)
        i = jnp.argmax(work, axis=-1).astype(jnp.int32)
        vals_list.append(v)
        idx_list.append(i)
        total = total + v
        work = jnp.where(cols == i[:, None], NEG_INF, work)
    vals = jnp.stack(vals_list, axis=-1)                   # (bn, k)
    idx = jnp.stack(idx_list, axis=-1)
    vals_ref[...] = vals / jnp.maximum(total, 1e-9)[:, None]
    idx_ref[...] = idx


def msp_select_pallas(logits, *, temperature: float, k: int = 8,
                      block_n: int = 8, interpret: bool = True,
                      detector: str = "msp"):
    """logits: (N, C) -> (conf (N,), vals (N, k), idx (N, k))."""
    N, C = logits.shape
    block_n = min(block_n, N)
    assert N % block_n == 0, "pad rows to a block multiple"
    assert detector in ("msp", "energy"), detector
    kernel = functools.partial(_msp_kernel, temperature=temperature,
                               k=k, detector=detector)
    return pl.pallas_call(
        kernel,
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((block_n, C), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((N,), jnp.float32),
            jax.ShapeDtypeStruct((N, k), jnp.float32),
            jax.ShapeDtypeStruct((N, k), jnp.int32),
        ),
        interpret=interpret,
    )(logits)
