"""Jit'd public wrapper for the msp_select kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.msp_select.kernel import msp_select_pallas
from repro.kernels.msp_select.ref import msp_select_ref


@functools.partial(jax.jit, static_argnames=("temperature", "k", "block_n",
                                             "interpret", "detector"))
def msp_select(logits, *, temperature: float = 10.0, k: int = 8,
               block_n: int = 8, interpret: bool | None = None,
               detector: str = "msp"):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return msp_select_pallas(logits, temperature=temperature, k=k,
                             block_n=block_n, interpret=interpret,
                             detector=detector)


__all__ = ["msp_select", "msp_select_ref"]
