"""Pallas TPU flash-attention forward (causal, GQA).

TPU-native design decisions (DESIGN.md §6):
  * grid = (B, H, num_q_blocks, num_k_blocks); the innermost k dimension
    iterates sequentially on a TensorCore, so the online-softmax running
    state (m, l, acc) lives in VMEM scratch and persists across k steps.
  * q/k tiles are (block_q × D) / (block_k × D) with block sizes that are
    multiples of 128 in production — MXU-aligned on both matmul operands.
  * GQA is handled in the BlockSpec index_map (kv head = h // group) — no
    KV duplication in HBM or VMEM.
  * fully-masked (above-diagonal) k blocks are skipped with ``pl.when``,
    halving work for causal attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, sm_scale: float,
                  num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    run = (k_start <= q_start + block_q - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * sm_scale   # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)              # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, KVH, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seq to block multiple"
    nq, nk = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sm_scale=sm_scale, num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
