"""Jit'd public wrapper for the flash-attention kernel.

``flash_attention(q, k, v)`` dispatches to the Pallas TPU kernel when
running on TPU (interpret=False) and to interpret mode on CPU; the pure-jnp
oracle lives in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)


__all__ = ["flash_attention", "flash_attention_ref"]
