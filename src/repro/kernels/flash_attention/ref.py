"""Pure-jnp oracle for the flash attention kernel (causal GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Naive attention. q: (B, Sq, H, D); k/v: (B, Sk, KVH, D)."""
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)
