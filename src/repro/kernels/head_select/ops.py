"""Jit'd public wrapper for the head_select kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.head_select.kernel import head_select_pallas
from repro.kernels.head_select.ref import (head_select_ref,
                                           head_select_stats_ref,
                                           merge_head_stats)


@functools.partial(jax.jit, static_argnames=("temperature", "k",
                                             "block_rows", "block_c",
                                             "interpret", "detector",
                                             "raw_stats"))
def head_select(hidden, w, bias=None, *, temperature: float = 10.0,
                k: int = 8, block_rows: int = 8, block_c: int = 512,
                interpret: bool | None = None, detector: str = "msp",
                raw_stats: bool = False):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return head_select_pallas(hidden, w, bias, temperature=temperature,
                              k=k, block_rows=block_rows, block_c=block_c,
                              interpret=interpret, detector=detector,
                              raw_stats=raw_stats)


__all__ = ["head_select", "head_select_ref", "head_select_stats_ref",
           "merge_head_stats"]
