"""Pure-jnp oracle for the fused head-select kernel.

XLA fuses this the same way on CPU (one pass over the chunk's logits),
so the streaming labeling driver runs identical math off-TPU — the
chunk logits ``hidden @ w`` are a *microbatch-sized* intermediate, never
the full public set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def head_select_ref(hidden, w, bias=None, *, temperature: float, k: int,
                    detector: str = "msp"):
    """Fused labeling pass from pre-head activations:

    hidden (N, D) @ w (D, C) [+ bias (C,)] ->
      * conf (N,)   — detector confidence at T=1 (MSP or energy)
      * vals (N, k) — top-k of the temperature softmax, renormalized
      * idx  (N, k) — their class / vocab indices
    """
    logits = (hidden.astype(jnp.float32) @ w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)
    if detector == "energy":
        conf = jax.nn.logsumexp(logits, axis=-1)
    else:
        conf = jnp.max(jax.nn.softmax(logits, axis=-1), axis=-1)
    vals, idx = jax.lax.top_k(logits, k)
    vals = jax.nn.softmax(vals / temperature, axis=-1)
    return conf, vals, idx.astype(jnp.int32)
