"""Pure-jnp oracle for the fused head-select kernel.

XLA fuses this the same way on CPU (one pass over the chunk's logits),
so the streaming labeling driver runs identical math off-TPU — the
chunk logits ``hidden @ w`` are a *microbatch-sized* intermediate, never
the full public set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def head_select_ref(hidden, w, bias=None, *, temperature: float, k: int,
                    detector: str = "msp"):
    """Fused labeling pass from pre-head activations:

    hidden (N, D) @ w (D, C) [+ bias (C,)] ->
      * conf (N,)   — detector confidence at T=1 (MSP or energy)
      * vals (N, k) — top-k of the temperature softmax, renormalized
      * idx  (N, k) — their class / vocab indices
    """
    logits = (hidden.astype(jnp.float32) @ w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)
    if detector == "energy":
        conf = jax.nn.logsumexp(logits, axis=-1)
    else:
        conf = jnp.max(jax.nn.softmax(logits, axis=-1), axis=-1)
    vals, idx = jax.lax.top_k(logits, k)
    vals = jax.nn.softmax(vals / temperature, axis=-1)
    return conf, vals, idx.astype(jnp.int32)


def head_select_stats_ref(hidden, w, bias=None, *, k: int):
    """Pre-finalizer half of :func:`head_select_ref`: raw online-softmax
    stats and the top-k *logits* over this vocab slice —
    ``(m (N,), z (N,), tv (N, k), ti (N, k))``. One slice's worth of the
    vocab-sharded label pass; :func:`merge_head_stats` combines slices.
    """
    logits = (hidden.astype(jnp.float32) @ w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)
    m = jnp.max(logits, axis=-1)
    z = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
    tv, ti = jax.lax.top_k(logits, k)
    return m, z, tv, ti.astype(jnp.int32)


def merge_head_stats(ms, zs, tvs, tis, *, temperature: float, k: int,
                     detector: str = "msp"):
    """Merge per-vocab-slice stats into the global labeling quantities —
    the cross-shard form of the kernel's cross-tile streaming merge.

    ``ms/zs (S, N)``, ``tvs (S, N, k_loc)``, ``tis (S, N, k_loc)``
    stacked over S slices; ``tis`` holds *global* vocab indices. Returns
    the same ``(conf, vals, idx)`` as :func:`head_select_ref` on the
    unsharded head: ``m_g = max_s m``, ``z_g = Σ_s z_s·exp(m_s − m_g)``
    re-bases each slice's normalizer, the global top-k is the top-k of
    the concatenated per-slice candidates (each slice's true top-k_loc
    contains every global winner that lives in that slice), and the
    temperature/detector finalizer runs only here.
    """
    m_g = jnp.max(ms, axis=0)                              # (N,)
    z_g = jnp.maximum(jnp.sum(zs * jnp.exp(ms - m_g[None]), axis=0), 1e-30)
    if detector == "energy":
        conf = m_g + jnp.log(z_g)
    else:
        conf = 1.0 / z_g
    S = tvs.shape[0]
    cv = jnp.concatenate([tvs[s] for s in range(S)], axis=-1)  # (N, S·k_loc)
    ci = jnp.concatenate([tis[s] for s in range(S)], axis=-1)
    vals, pos = jax.lax.top_k(cv, k)
    idx = jnp.take_along_axis(ci, pos, axis=-1)
    vals = jax.nn.softmax(vals / temperature, axis=-1)
    return conf, vals, idx.astype(jnp.int32)
