from repro.kernels.head_select.kernel import NEG_INF  # noqa: F401
from repro.kernels.head_select.ops import head_select  # noqa: F401
from repro.kernels.head_select.ref import (head_select_ref,  # noqa: F401
                                           head_select_stats_ref,
                                           merge_head_stats)
