"""Pallas TPU kernel: fused, vocab-tiled head-select (streaming labeling).

The logit-free generalization of ``msp_select``: instead of reading a
precomputed ``(rows, C)`` logit tensor from HBM, it takes the final
hidden states ``(rows, D)`` and the classifier / unembedding matrix
``(D, C)`` and computes the IDKD labeling quantities — detector
confidence and the renormalized top-k sparse soft label — with the
**vocab axis tiled**: the full ``(rows, C)`` logit tensor never exists
in any memory.

Per ``(row_block, vocab_block)`` grid cell the kernel does one MXU
matmul ``hidden @ W[:, c0:c1]`` in VMEM and folds the block into
running per-row state (the same scratch-accumulator pattern as the
in-repo flash_attention kernel, whose online-softmax (m, l) carry this
reuses):

* ``m, z``   — online-softmax running max / normalizer at T=1, from
  which both detectors fall out at the final block (MSP ``1/z``,
  energy ``m + log z``);
* ``tv, ti`` — running top-k *logits* + global vocab indices, merged
  blockwise (iterative argmax inside the block, then a 2k-wide merge
  with the carry). Top-k of the temperature softmax equals top-k of
  the logits (softmax is monotonic), and the *renormalized* top-k
  payload depends only on the top-k logits themselves —
  ``v_j = exp(l_j/T) / Σ_{j'∈topk} exp(l_j'/T)`` — so the temperature
  enters only in the finalizer and no softmax over C is ever formed.

VMEM per cell: ``block_rows × D`` hidden + ``D × block_c`` weights +
``block_rows × block_c`` scores (f32). At D=4k, block_c=512,
block_rows=8 that is ≈ 9 MB — comfortably resident; HBM traffic is one
read of W per row block and one read of the hidden states, with
O(rows · k) outputs instead of O(rows · C).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _head_kernel(h_ref, w_ref, b_ref, *refs,
                 temperature: float, k: int, detector: str,
                 block_c: int, num_c_blocks: int, num_classes: int,
                 raw_stats: bool = False):
    # outputs: (conf, vals, idx) or — raw_stats, for the model-axis
    # merge — (m, z, tv, ti); the last four refs are always the
    # (m, z, tv, ti) VMEM scratch carry.
    out_refs, (m_scr, z_scr, tv_scr, ti_scr) = refs[:-4], refs[-4:]
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)
        tv_scr[...] = jnp.full_like(tv_scr, NEG_INF)
        ti_scr[...] = jnp.zeros_like(ti_scr)

    h = h_ref[...].astype(jnp.float32)                     # (bn, D)
    w = w_ref[...].astype(jnp.float32)                     # (D, bc)
    s = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s + b_ref[...].astype(jnp.float32)                 # (1, bc) bias
    col0 = ci * block_c
    local = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col0 + local < num_classes, s, NEG_INF)  # C padding

    # ---- online-softmax detector stats at T=1 (flash-attention carry)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    z_scr[...] = (z_scr[...] * jnp.exp(m_prev - m_new)
                  + jnp.sum(jnp.exp(s - m_new[:, None]), axis=1))
    m_scr[...] = m_new

    # ---- block top-k of the raw logits by iterative argmax (k small)
    work = s
    bv_list, bi_list = [], []
    for _ in range(k):
        v = jnp.max(work, axis=-1)
        i = jnp.argmax(work, axis=-1).astype(jnp.int32)
        bv_list.append(v)
        bi_list.append(col0 + i)
        work = jnp.where(local == i[:, None], NEG_INF, work)
    bv = jnp.stack(bv_list, axis=-1)                       # (bn, k)
    bi = jnp.stack(bi_list, axis=-1)

    # ---- streaming merge with the carry: top-k of the 2k candidates
    cv = jnp.concatenate([tv_scr[...], bv], axis=-1)       # (bn, 2k)
    cidx = jnp.concatenate([ti_scr[...], bi], axis=-1)
    slot = jax.lax.broadcasted_iota(jnp.int32, cv.shape, 1)
    mv_list, mi_list = [], []
    for _ in range(k):
        v = jnp.max(cv, axis=-1)
        p = jnp.argmax(cv, axis=-1)
        mv_list.append(v)
        mi_list.append(jnp.take_along_axis(cidx, p[:, None], axis=-1)[:, 0])
        cv = jnp.where(slot == p[:, None], NEG_INF, cv)
    tv_scr[...] = jnp.stack(mv_list, axis=-1)
    ti_scr[...] = jnp.stack(mi_list, axis=-1)

    @pl.when(ci == num_c_blocks - 1)
    def _finalize():
        if raw_stats:
            # vocab-sharded path: ship the raw carry; the caller merges
            # (m, z) and the top-k logits across model-axis shards with
            # the same streaming math (ref.merge_head_stats) and only
            # then applies the detector / temperature finalizer.
            m_ref, z_ref, tv_ref, ti_ref = out_refs
            m_ref[...] = m_scr[...]
            z_ref[...] = z_scr[...]
            tv_ref[...] = tv_scr[...]
            ti_ref[...] = ti_scr[...]
            return
        conf_ref, vals_ref, idx_ref = out_refs
        z = jnp.maximum(z_scr[...], 1e-30)
        if detector == "energy":
            conf_ref[...] = m_scr[...] + jnp.log(z)
        else:
            conf_ref[...] = 1.0 / z
        tv = tv_scr[...]                                   # sorted desc
        e = jnp.exp((tv - tv[:, :1]) / temperature)
        vals_ref[...] = e / jnp.maximum(jnp.sum(e, -1, keepdims=True),
                                        1e-30)
        idx_ref[...] = ti_scr[...]


def head_select_pallas(hidden, w, bias, *, temperature: float, k: int = 8,
                       block_rows: int = 8, block_c: int = 512,
                       interpret: bool = True, detector: str = "msp",
                       raw_stats: bool = False):
    """hidden (N, D) + head (D, C) [+ bias (C,)] ->
    (conf (N,), vals (N, k), idx (N, k)) with the vocab axis tiled.

    ``raw_stats=True`` returns the pre-finalizer carry
    ``(m (N,), z (N,), tv (N, k), ti (N, k))`` instead — the per-shard
    half of the vocab-sharded 2-D label round, merged across the model
    axis by ``ref.merge_head_stats``."""
    N, D = hidden.shape
    C = w.shape[1]
    assert w.shape[0] == D, (w.shape, hidden.shape)
    assert k <= C, "clamp k to the class count before calling"
    assert detector in ("msp", "energy"), detector
    block_rows = min(block_rows, N)
    assert N % block_rows == 0, "pad rows to a block multiple"
    block_c = min(block_c, C)
    pad_c = (-C) % block_c
    if bias is None:
        bias = jnp.zeros((C,), jnp.float32)
    if pad_c:
        w = jnp.pad(w, ((0, 0), (0, pad_c)))
        bias = jnp.pad(bias, (0, pad_c))
    bias = bias.reshape(1, -1)
    num_c_blocks = (C + pad_c) // block_c

    kernel = functools.partial(
        _head_kernel, temperature=temperature, k=k, detector=detector,
        block_c=block_c, num_c_blocks=num_c_blocks, num_classes=C,
        raw_stats=raw_stats)
    row_spec = pl.BlockSpec((block_rows,), lambda i, c: (i,))
    topk_spec = pl.BlockSpec((block_rows, k), lambda i, c: (i, 0))
    if raw_stats:
        out_specs = (row_spec, row_spec, topk_spec, topk_spec)
        out_shape = (
            jax.ShapeDtypeStruct((N,), jnp.float32),
            jax.ShapeDtypeStruct((N,), jnp.float32),
            jax.ShapeDtypeStruct((N, k), jnp.float32),
            jax.ShapeDtypeStruct((N, k), jnp.int32),
        )
    else:
        out_specs = (row_spec, topk_spec, topk_spec)
        out_shape = (
            jax.ShapeDtypeStruct((N,), jnp.float32),
            jax.ShapeDtypeStruct((N, k), jnp.float32),
            jax.ShapeDtypeStruct((N, k), jnp.int32),
        )
    return pl.pallas_call(
        kernel,
        grid=(N // block_rows, num_c_blocks),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i, c: (i, 0)),
            pl.BlockSpec((D, block_c), lambda i, c: (0, c)),
            pl.BlockSpec((1, block_c), lambda i, c: (0, c)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows, k), jnp.float32),
            pltpu.VMEM((block_rows, k), jnp.int32),
        ],
        interpret=interpret,
    )(hidden, w, bias)
