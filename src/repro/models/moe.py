"""Mixture-of-experts layer with sort-based (argsort-by-expert) dispatch.

TPU-native design: instead of a GShard one-hot dispatch einsum (whose
(tokens × experts × capacity) tensor is prohibitive at 256 experts), tokens
are argsorted by routed expert id and scattered into per-expert capacity
buffers (E, C, d). The per-expert FFN is then one block einsum on the MXU.
Experts are sharded over the ``model`` mesh axis; XLA inserts the
all-to-alls at the token→expert buffer boundary.

Supports: top-k softmax routing (Arctic), sigmoid routing with bias-based
aux-free balancing (DeepSeek-V3), shared experts, Arctic's dense-residual
parallel branch, and an optional load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_mlp, dense_init, init_mlp


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    glu = cfg.mlp_type in ("swiglu", "geglu")

    def expert_bank(k, n, dff):
        kk = jax.random.split(k, 3)
        p = {"wi": jax.vmap(lambda q: dense_init(q, d, dff, dtype))(
                jax.random.split(kk[0], n)),
             "wo": jax.vmap(lambda q: dense_init(q, dff, d, dtype))(
                jax.random.split(kk[1], n))}
        if glu:
            p["wg"] = jax.vmap(lambda q: dense_init(q, d, dff, dtype))(
                jax.random.split(kk[2], n))
        return p

    p = {"router": dense_init(ks[0], d, m.num_experts, dtype, scale=0.02),
         "router_bias": jnp.zeros((m.num_experts,), jnp.float32),
         "experts": expert_bank(ks[1], m.num_experts, m.moe_d_ff)}
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[2], cfg, d, m.num_shared_experts * m.moe_d_ff,
                               dtype)
    if m.dense_residual_ff:
        p["dense_residual"] = init_mlp(ks[3], cfg, d, m.dense_residual_ff, dtype)
    return p


def _route(params, x, cfg: ModelConfig):
    """Router: returns (expert_ids (T,k), weights (T,k), aux_loss)."""
    m = cfg.moe
    logits = (x @ params["router"]).astype(jnp.float32)       # (T, E)
    if m.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        biased = scores + params["router_bias"]               # bias only ranks
        _, ids = jax.lax.top_k(biased, m.num_experts_per_tok)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, m.num_experts_per_tok)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (density * mean prob per expert).
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(
        jax.nn.one_hot(ids[:, 0], m.num_experts, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(density * mean_prob)
    return ids, w.astype(x.dtype), aux


def _expert_ffn(bank, xb, cfg: ModelConfig):
    """xb: (E, C, d) -> (E, C, d) via per-expert GLU MLP."""
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, bank["wg"])) * \
            jnp.einsum("ecd,edf->ecf", xb, bank["wi"])
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xb, bank["wg"])) * \
            jnp.einsum("ecd,edf->ecf", xb, bank["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xb, bank["wi"]))
    return jnp.einsum("ecf,efd->ecd", h, bank["wo"])


def _dispatch_group(xt, ids, w, bank, cfg: ModelConfig, cap: int):
    """Sort-based dispatch for ONE token group.

    xt: (T, d); ids/w: (T, k). Local argsort by expert id → per-expert
    capacity buffers → block einsum → weighted combine."""
    m = cfg.moe
    T, d = xt.shape
    k = m.num_experts_per_tok
    E = m.num_experts
    flat_ids = ids.reshape(T * k)                             # assignment ids
    flat_w = w.reshape(T * k)
    order = jnp.argsort(flat_ids)                             # stable sort
    sorted_ids = flat_ids[order]
    # position of each assignment within its expert's buffer
    same = jnp.cumsum(jnp.ones_like(sorted_ids)) - 1
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(E))   # (E,)
    pos_in_expert = same - seg_start[sorted_ids]
    keep = pos_in_expert < cap
    token_of = order // k                                     # source token
    # scatter tokens into (E*cap, d) buffers (last row = dropped slot)
    dest = jnp.where(keep, sorted_ids * cap + pos_in_expert, E * cap)
    buf = jnp.zeros((E * cap + 1, d), xt.dtype)
    buf = buf.at[dest].set(xt[token_of])
    yb = _expert_ffn(bank, buf[:-1].reshape(E, cap, d), cfg)
    yb = jnp.concatenate([yb.reshape(E * cap, d),
                          jnp.zeros((1, d), xt.dtype)])
    y_assign = yb[dest] * (flat_w[order] * keep)[:, None]
    return jnp.zeros((T, d), xt.dtype).at[token_of].add(y_assign)


def moe_forward(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    With ``dispatch_groups == 1`` the argsort spans all tokens (simple but
    unshardable: GSPMD must all-gather every token — see EXPERIMENTS §Perf).
    With G > 1 tokens are split into G groups (aligned with the data
    shards), each group sorts locally with capacity cap/G, and the
    group→expert movement lowers to all-to-alls."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    ids, w, aux = _route(params, xt, cfg)                     # (T,k)
    k = m.num_experts_per_tok
    E = m.num_experts
    G = max(1, m.dispatch_groups)
    if T % G:
        G = 1
    cap = int(m.capacity_factor * (T // G) * k / E) + 1
    if G == 1:
        out = _dispatch_group(xt, ids, w, params["experts"], cfg, cap)
    else:
        xg = xt.reshape(G, T // G, d)
        idg = ids.reshape(G, T // G, k)
        wg = w.reshape(G, T // G, k)
        out = jax.vmap(lambda a, b, c: _dispatch_group(
            a, b, c, params["experts"], cfg, cap))(xg, idg, wg)
        out = out.reshape(T, d)
    if m.num_shared_experts:
        out = out + apply_mlp(params["shared"], xt, cfg)
    if m.dense_residual_ff:
        out = out + apply_mlp(params["dense_residual"], xt, cfg)
    return out.reshape(B, S, d), aux
