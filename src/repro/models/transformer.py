"""Composable decoder stack covering all assigned architectures.

Layer params are stacked (L, ...) pytrees consumed by ``lax.scan`` so HLO
size is O(1) in depth (61-layer DeepSeek-V3 lowers in seconds). Per-layer
heterogeneity (Hymba's global-vs-sliding-window layers) rides along the
scan as a (L,) window array; MoE-with-leading-dense stacks (DeepSeek) are
split into two scanned segments.

Public surface (used by the trainer, server, dry-run and IDKD):

    model = DecoderModel(cfg)
    params = model.init(key)
    logits, aux = model.forward(params, batch)
    loss, metrics = model.loss(params, batch)
    state = model.init_decode_state(batch_size, context)
    logits, state = model.decode_step(params, tokens, state)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, dense_init,
                                 embed_init, init_mlp, init_norm)
from repro.models.moe import init_moe, moe_forward


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# §Perf hook: when set (by the launch layer under a mesh), the residual
# stream is re-constrained at every scanned layer so GSPMD cannot drift
# into batch-replicated activations inside the while body.
# Signature: h (B, S, d) -> h.
RESIDUAL_CONSTRAINT = None


def _constrain(h):
    if RESIDUAL_CONSTRAINT is not None:
        return RESIDUAL_CONSTRAINT(h)
    return h


def _remat_policy(cfg: ModelConfig):
    """``jax.checkpoint`` policy for ``cfg.remat_policy``: "nothing"
    (recompute everything — the minimum-HBM default; "full" is its
    legacy alias), "dots" (save matmul outputs, so TP all-reduces are
    not recomputed in the backward pass), "everything" (save all
    residuals — remat as a structural no-op)."""
    name = cfg.remat_policy
    if name in ("nothing", "full"):
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name == "everything":
        return jax.checkpoint_policies.everything_saveable
    raise ValueError(f"unknown remat_policy {name!r}: expected 'nothing', "
                     "'dots', or 'everything'")


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: str, dtype):
    """kind: 'dense' | 'moe' — the FFN flavour of this layer."""
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": init_norm(cfg, cfg.d_model, dtype)}
    if cfg.mla.enabled:
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    elif not cfg.is_attention_free:
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cfg.ssm.enabled:
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, dtype)
        if cfg.hybrid_parallel:
            p["attn_branch_norm"] = jnp.ones((cfg.d_model,), dtype)
            p["ssm_branch_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.cross_attention:
        p["ln_cross"] = init_norm(cfg, cfg.d_model, dtype)
        p["cross"] = attn.init_cross_attention(ks[2], cfg, dtype)
    if cfg.d_ff or kind == "moe":
        p["ln2"] = init_norm(cfg, cfg.d_model, dtype)
        if kind == "moe":
            p["moe"] = init_moe(ks[3], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[3], cfg, cfg.d_model, cfg.d_ff, dtype)
    return p


def _mix_forward(p, h, cfg: ModelConfig, window, memory):
    """Token-mixing sub-block (attention / SSM / hybrid-parallel)."""
    if cfg.hybrid_parallel:
        a = attn.attention_forward(p["attn"], h, cfg, layer_window=window)
        s = ssm_mod.ssm_forward(p["ssm"], h, cfg)

        def _rms(x, scale):
            xf = x.astype(jnp.float32)
            ms = jnp.mean(xf * xf, -1, keepdims=True)
            return (xf * jax.lax.rsqrt(ms + cfg.norm_eps)
                    * scale.astype(jnp.float32)).astype(x.dtype)
        return 0.5 * (_rms(a, p["attn_branch_norm"])
                      + _rms(s, p["ssm_branch_norm"]))
    if cfg.ssm.enabled:
        return ssm_mod.ssm_forward(p["ssm"], h, cfg)
    if cfg.mla.enabled:
        return attn.mla_forward(p["attn"], h, cfg)
    return attn.attention_forward(p["attn"], h, cfg, layer_window=window)


def _layer_forward(p, x, cfg: ModelConfig, kind: str, window, memory):
    h = apply_norm(p["ln1"], x, cfg)
    x = x + _mix_forward(p, h, cfg, window, memory)
    aux = jnp.zeros((), jnp.float32)
    if cfg.cross_attention and memory is not None:
        h = apply_norm(p["ln_cross"], x, cfg)
        x = x + attn.cross_attention_forward(p["cross"], h, memory, cfg)
    if "ln2" in p:
        h = apply_norm(p["ln2"], x, cfg)
        if kind == "moe":
            y, aux = moe_forward(p["moe"], h, cfg)
        else:
            y = apply_mlp(p["mlp"], h, cfg)
        x = x + y
    return x, aux


def _mix_decode(p, h, cfg: ModelConfig, window, layer_state):
    if cfg.hybrid_parallel:
        a, kv = attn.attention_decode(p["attn"], h, cfg, layer_state["kv"],
                                      layer_window=window)
        s, ssm_state = ssm_mod.ssm_decode(p["ssm"], h, cfg, layer_state["ssm"])

        def _rms(x, scale):
            xf = x.astype(jnp.float32)
            ms = jnp.mean(xf * xf, -1, keepdims=True)
            return (xf * jax.lax.rsqrt(ms + cfg.norm_eps)
                    * scale.astype(jnp.float32)).astype(x.dtype)
        out = 0.5 * (_rms(a, p["attn_branch_norm"])
                     + _rms(s, p["ssm_branch_norm"]))
        return out, {"kv": kv, "ssm": ssm_state}
    if cfg.ssm.enabled:
        out, st = ssm_mod.ssm_decode(p["ssm"], h, cfg, layer_state["ssm"])
        return out, {"ssm": st}
    if cfg.mla.enabled:
        out, st = attn.mla_decode(p["attn"], h, cfg, layer_state["kv"])
        return out, {"kv": st}
    out, st = attn.attention_decode(p["attn"], h, cfg, layer_state["kv"],
                                    layer_window=window)
    return out, {"kv": st}


def _layer_decode(p, x, cfg: ModelConfig, kind: str, window, layer_state,
                  memory):
    h = apply_norm(p["ln1"], x, cfg)
    mix, new_state = _mix_decode(p, h, cfg, window, layer_state)
    x = x + mix
    if cfg.cross_attention and memory is not None:
        h = apply_norm(p["ln_cross"], x, cfg)
        x = x + attn.cross_attention_forward(p["cross"], h, memory, cfg)
    if "ln2" in p:
        h = apply_norm(p["ln2"], x, cfg)
        if kind == "moe":
            y, _ = moe_forward(p["moe"], h, cfg)
        else:
            y = apply_mlp(p["mlp"], h, cfg)
        x = x + y
    return x, new_state


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class Segment(NamedTuple):
    kind: str        # 'dense' | 'moe'
    num_layers: int


class DecoderModel:
    """Functional model wrapper; all methods are jit-compatible."""

    input_key = "tokens"

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.moe.enabled and cfg.moe.first_k_dense:
            self.segments = [Segment("dense", cfg.moe.first_k_dense),
                             Segment("moe", cfg.num_layers - cfg.moe.first_k_dense)]
        elif cfg.moe.enabled:
            self.segments = [Segment("moe", cfg.num_layers)]
        else:
            self.segments = [Segment("dense", cfg.num_layers)]

    # -- windows per layer (Hymba global-vs-SWA pattern) --------------------
    def layer_windows(self) -> jnp.ndarray:
        cfg = self.cfg
        L = cfg.num_layers
        if not cfg.sliding_window:
            return jnp.zeros((L,), jnp.int32)
        w = jnp.full((L,), cfg.sliding_window, jnp.int32)
        if cfg.global_attn_every:
            idx = jnp.arange(L)
            is_global = (idx % cfg.global_attn_every == 0) | (idx == L - 1)
            w = jnp.where(is_global, 0, w)
        return w

    def _segment_windows(self):
        w = self.layer_windows()
        out, off = [], 0
        for seg in self.segments:
            out.append(w[off:off + seg.num_layers])
            off += seg.num_layers
        return out

    # -- init ----------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = _dtype(cfg)
        keys = jax.random.split(key, 8)
        p: Dict[str, Any] = {}
        p["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
        if cfg.num_codebooks > 1:
            p["embed_cb"] = jax.vmap(
                lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dtype))(
                jax.random.split(keys[1], cfg.num_codebooks - 1))
        if not cfg.tie_embeddings:
            nheads = max(cfg.num_codebooks, 1)
            p["head"] = jax.vmap(
                lambda k: dense_init(k, cfg.d_model, cfg.vocab_size, dtype))(
                jax.random.split(keys[2], nheads)) if nheads > 1 else \
                dense_init(keys[2], cfg.d_model, cfg.vocab_size, dtype)
        if cfg.num_prefix_tokens and cfg.arch_type == "hybrid":
            # learned meta tokens (Hymba); VLM prefixes come from input_specs
            p["meta_tokens"] = (jax.random.normal(
                keys[3], (cfg.num_prefix_tokens, cfg.d_model)) * 0.02
            ).astype(dtype)
        seg_keys = jax.random.split(keys[4], len(self.segments))
        for si, seg in enumerate(self.segments):
            lkeys = jax.random.split(seg_keys[si], seg.num_layers)
            stacked = jax.vmap(
                lambda k, kind=seg.kind: _init_layer(k, cfg, kind, dtype))(lkeys)
            p[f"layers_{si}"] = stacked
        p["ln_f"] = init_norm(cfg, cfg.d_model, dtype)
        if cfg.mtp_depth:
            p["mtp_proj"] = dense_init(keys[5], 2 * cfg.d_model, cfg.d_model,
                                       dtype)
            kind = self.segments[-1].kind
            p["mtp_layer"] = _init_layer(keys[6], cfg, kind, dtype)
            p["mtp_ln"] = init_norm(cfg, cfg.d_model, dtype)
        return p

    # -- embedding / head ------------------------------------------------------
    def embed_tokens(self, params, tokens):
        cfg = self.cfg
        if cfg.num_codebooks > 1:
            # tokens: (B, S, K) — sum codebook embeddings (MusicGen)
            e = params["embed"][tokens[..., 0]]
            for i in range(cfg.num_codebooks - 1):
                e = e + params["embed_cb"][i][tokens[..., i + 1]]
            return e
        return params["embed"][tokens]

    def logits(self, params, h):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return jnp.einsum("...d,vd->...v", h, params["embed"])
        if cfg.num_codebooks > 1:
            return jnp.einsum("...d,kdv->...kv", h, params["head"])
        return h @ params["head"]

    # -- forward ----------------------------------------------------------------
    def _run_stack(self, params, h, memory):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        seg_windows = self._segment_windows()
        for si, seg in enumerate(self.segments):
            stacked = params[f"layers_{si}"]
            windows = seg_windows[si]

            def body(x, scanned, kind=seg.kind):
                lp, win = scanned

                def f(lp_, x_, win_):
                    return _layer_forward(lp_, x_, cfg, kind, win_, memory)
                if cfg.remat:
                    f = jax.checkpoint(f, policy=_remat_policy(cfg))
                y, aux = f(lp, x, win)
                return _constrain(y), aux

            if cfg.scan_layers and seg.num_layers > 1:
                h, auxs = jax.lax.scan(body, h, (stacked, windows))
                aux_total = aux_total + jnp.sum(auxs)
            else:
                for li in range(seg.num_layers):
                    lp = jax.tree.map(lambda t: t[li], stacked)
                    h, aux = body(h, (lp, windows[li]))
                    aux_total = aux_total + aux
        return h, aux_total

    def hidden(self, params, batch: Dict[str, Any]):
        """Post-stack, post-final-norm hidden states with prefixes stripped.
        Returns (h, aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self.embed_tokens(params, tokens)
        B = h.shape[0]
        n_prefix = 0
        if cfg.arch_type == "hybrid" and cfg.num_prefix_tokens:
            meta = jnp.broadcast_to(params["meta_tokens"][None],
                                    (B,) + params["meta_tokens"].shape)
            h = jnp.concatenate([meta, h], axis=1)
            n_prefix = cfg.num_prefix_tokens
        if cfg.arch_type == "vlm":
            patches = batch["patch_embeddings"].astype(h.dtype)  # (B,P,d)
            h = jnp.concatenate([patches, h], axis=1)
            n_prefix = patches.shape[1]
        memory = batch.get("conditioning") if cfg.cross_attention else None
        if memory is not None:
            memory = memory.astype(h.dtype)
        h, aux = self._run_stack(params, h, memory)
        h = apply_norm(params["ln_f"], h, cfg)
        if n_prefix:
            h = h[:, n_prefix:]
        return h, aux

    def forward_features(self, params, batch: Dict[str, Any]):
        """Pre-head activations (B, S, d) — alias of :meth:`hidden`, the
        streaming-labeling hook shared with ResNetModel."""
        return self.hidden(params, batch)

    def head_params(self, params):
        """(unembedding (d, V), bias=None) — the matrix the streaming
        head-select kernel tiles over the vocab axis. Multi-codebook
        heads (MusicGen) emit (B, S, K, V) logits that the labeling
        engine does not model; they keep the one-shot path."""
        cfg = self.cfg
        if cfg.num_codebooks > 1:
            raise ValueError("streaming head-select supports a single "
                             "unembedding head; num_codebooks > 1 uses "
                             "the one-shot labeling path")
        if cfg.tie_embeddings:
            return params["embed"].T, None
        return params["head"], None

    def forward(self, params, batch: Dict[str, Any]):
        """Returns (logits, aux). batch['tokens']: (B,S[,K]) int32."""
        h, aux = self.hidden(params, batch)
        return self.logits(params, h), aux

    # -- loss ---------------------------------------------------------------------
    def loss(self, params, batch: Dict[str, Any]):
        cfg = self.cfg
        h, aux = self.hidden(params, batch)
        logits = self.logits(params, h)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
        nll = lse - gold
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(nll.shape, jnp.float32)
        else:
            mask = jnp.broadcast_to(mask[..., None] if mask.ndim < nll.ndim
                                    else mask, nll.shape).astype(jnp.float32)
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        metrics = {"nll": loss, "aux": aux}
        if cfg.mtp_depth:
            loss = loss + self._mtp_loss(params, batch, h)
        if cfg.moe.enabled:
            loss = loss + cfg.moe.router_aux_coef * aux
        return loss, metrics

    def _mtp_loss(self, params, batch, h):
        """DeepSeek-V3 multi-token prediction: predict t+2 from
        [h_t ; emb(t_{+1})] through one extra layer, shared head.
        Reuses the trunk hidden states ``h`` (no stack re-run)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        h = apply_norm(params["mtp_ln"], h, cfg)
        emb_next = self.embed_tokens(params, jnp.roll(tokens, -1, axis=1))
        hcat = jnp.concatenate([h, emb_next], axis=-1) @ params["mtp_proj"]
        win = jnp.asarray(0, jnp.int32)
        hcat, _ = _layer_forward(params["mtp_layer"], hcat, cfg,
                                 self.segments[-1].kind, win, None)
        logits = self.logits(params, hcat)
        labels = jnp.roll(batch["labels"], -1, axis=1)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
        # mask the wrapped last position
        S = tokens.shape[1]
        mask = (jnp.arange(S) < S - 1).astype(jnp.float32)[None, :]
        mask = jnp.broadcast_to(mask, lse.shape)
        return 0.1 * jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # -- decode -------------------------------------------------------------------
    def init_decode_state(self, batch: int, context: int):
        cfg = self.cfg
        dtype = _dtype(cfg)
        states = []
        for seg in self.segments:
            st = {}
            if cfg.ssm.enabled:
                st["ssm"] = ssm_mod.make_ssm_state(cfg, batch, dtype)
            if cfg.mla.enabled:
                st["kv"] = attn.make_mla_cache(cfg, batch, context, dtype)
            elif not cfg.is_attention_free:
                # uniform cache across scanned layers: ring cap = window
                # only when *every* layer is windowed
                uniform_window = (cfg.sliding_window
                                  and not cfg.global_attn_every)
                st["kv"] = attn.make_kv_cache(
                    cfg, batch, context, dtype,
                    window_override=(cfg.sliding_window if uniform_window
                                     else 0))
            stacked = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None],
                                           (seg.num_layers,) + t.shape), st)
            states.append(stacked)
        return states

    def decode_step(self, params, tokens, states, memory=None):
        """tokens: (B, 1[, K]) — returns (logits, new_states)."""
        cfg = self.cfg
        h = self.embed_tokens(params, tokens)
        seg_windows = self._segment_windows()
        new_states = []
        for si, seg in enumerate(self.segments):
            stacked = params[f"layers_{si}"]
            windows = seg_windows[si]
            st = states[si]

            def body(x, scanned, kind=seg.kind):
                lp, win, layer_state = scanned
                y, new_state = _layer_decode(lp, x, cfg, kind, win,
                                             layer_state, memory)
                return y, new_state

            if cfg.scan_layers and seg.num_layers > 1:
                h, new_st = jax.lax.scan(body, h, (stacked, windows, st))
            else:
                outs = []
                for li in range(seg.num_layers):
                    lp = jax.tree.map(lambda t: t[li], stacked)
                    lst = jax.tree.map(lambda t: t[li], st)
                    h, ns = body(h, (lp, windows[li], lst))
                    outs.append(ns)
                new_st = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            new_states.append(new_st)
        h = apply_norm(params["ln_f"], h, cfg)
        return self.logits(params, h), new_states
