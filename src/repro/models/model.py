"""``build_model(cfg)`` — single entry point dispatching on arch family."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.resnet import ResNetModel
from repro.models.transformer import DecoderModel


def build_model(cfg: ModelConfig):
    if cfg.arch_type == "cnn":
        return ResNetModel(cfg)
    return DecoderModel(cfg)
