"""Mamba-2 (SSD — state-space duality) mixer [arXiv:2405.21060].

The SSD layer computes, per head h with state size N and head dim P:

    h_t = exp(a_t) * h_{t-1} + b_t ⊗ (x_t * dt_t)
    y_t = c_t · h_t + D * x_t

with input-dependent dt (softplus), shared B/C across head groups, and a
short causal depthwise conv on (x, B, C). We implement the *chunked dual
form*: intra-chunk quadratic attention-like term on the MXU plus an
inter-chunk sequential state recurrence — the same decomposition the
Pallas ``ssd_scan`` kernel uses (this file is its oracle via
``repro.kernels.ssd_scan.ref``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


# §Perf hook (set by the launch layer): constrains the per-head SSD inputs
# (B, S, H, ·) to batch×head sharding so the O(ck²) intra-chunk
# intermediates are sharded on BOTH the data and model axes instead of
# GSPMD's head-only choice (which batch-replicates every chunk tensor).
HEAD_CONSTRAINT = None


def _constrain_heads(t):
    if HEAD_CONSTRAINT is not None:
        return HEAD_CONSTRAINT(t)
    return t


class SSMState(NamedTuple):
    """Decode-time recurrent state (O(1) in context length)."""
    conv: jax.Array      # (B, conv_width-1, conv_dim) rolling conv inputs
    ssm: jax.Array       # (B, nheads, head_dim, state) running SSM state
    length: jax.Array    # (B,)


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.state_size
    return d_inner, nheads, conv_dim


def init_ssm(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 8)
    gn = s.ngroups * s.state_size
    p = {
        "a_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)),  # f32
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "w_out": dense_init(ks[2], d_inner, d, dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
    }
    if s.split_proj:
        # §Perf variant: one projection per stream — every output dim is a
        # standalone tensor, so TP sharding never crosses a split boundary
        # (the fused layout forces an all-gather at z/x/B/C/dt slicing).
        p.update({
            "w_z": dense_init(ks[0], d, d_inner, dtype),
            "w_x": dense_init(ks[3], d, d_inner, dtype),
            "w_b": dense_init(ks[4], d, gn, dtype),
            "w_c": dense_init(ks[5], d, gn, dtype),
            "w_dt": dense_init(ks[6], d, nheads, dtype),
            "conv_wx": (jax.random.normal(ks[1], (s.conv_width, d_inner),
                                          jnp.float32) * 0.1).astype(dtype),
            "conv_wb": (jax.random.normal(ks[7], (s.conv_width, gn),
                                          jnp.float32) * 0.1).astype(dtype),
            "conv_wc": (jax.random.normal(ks[7], (s.conv_width, gn),
                                          jnp.float32) * 0.1).astype(dtype),
            "conv_bx": jnp.zeros((d_inner,), dtype),
            "conv_bb": jnp.zeros((gn,), dtype),
            "conv_bc": jnp.zeros((gn,), dtype),
        })
    else:
        # fused mamba2 layout: in_proj emits [z, x, B, C, dt]
        p.update({
            "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * gn + nheads,
                               dtype),
            "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim),
                                         jnp.float32) * 0.1).astype(dtype),
            "conv_b": jnp.zeros((conv_dim,), dtype),
        })
    return p


def _split_in(proj, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, _ = ssm_dims(cfg)
    gn = s.ngroups * s.state_size
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _gated_norm(y, z, scale, eps=1e-6):
    """Mamba2's RMSNorm(y * silu(z)) output gate."""
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    ms = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv along time. xbc: (B, S, C); conv_w: (W, C)."""
    W = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * conv_w[i] for i in range(W))
    return jax.nn.silu(out + conv_b)


def ssd_chunked(x, dt, a_log, b, c, chunk: int, initial_state=None):
    """Chunked SSD scan (pure jnp oracle).

    x:  (B, S, H, P)   per-head inputs
    dt: (B, S, H)      post-softplus timestep
    a_log: (H,)        A = -exp(a_log)
    b, c: (B, S, G, N) shared across H//G head groups
    Returns y: (B, S, H, P), final_state: (B, H, P, N).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    nchunks = -(-S // chunk)
    pad = nchunks * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_p = nchunks * chunk
    rep = H // G
    a = -jnp.exp(a_log)                                   # (H,)
    dta = dt * a                                          # (B,S,H) log-decay
    xdt = x * dt[..., None]                               # dt-weighted input

    def reshape_chunks(t):
        return t.reshape((B, nchunks, chunk) + t.shape[2:])

    xc, dtac, bc_, cc_ = map(reshape_chunks, (xdt, dta, b, c))
    # cumulative log-decay within chunk: L[t] = sum_{u<=t} dta[u]
    cum = jnp.cumsum(dtac, axis=2)                        # (B,nc,ck,H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,t,u,H) t>=u
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # clamp BEFORE exp: masked (t<u) entries have seg>0 and would overflow,
    # and 0·inf in the backward pass poisons gradients with NaNs.
    seg = jnp.where(tri, seg, -1e30)
    decay = jnp.exp(seg)
    # expand grouped B/C to per-head, then intra-chunk quadratic term:
    #   y_t += sum_{u<=t} (c_t·b_u) decay(t,u) x_u dt_u
    b_h = jnp.repeat(bc_, rep, axis=3) if G != H else bc_  # (B,nc,ck,H,N)
    c_h = jnp.repeat(cc_, rep, axis=3) if G != H else cc_
    cb = jnp.einsum("bntHN,bnuHN->bntuH", c_h, b_h)        # (B,nc,t,u,H)
    y_intra = jnp.einsum("bntuH,bntuH,bnuHp->bntHp", cb, decay, xc)
    # chunk-final states: state_n = sum_u exp(cum_end - cum_u) b_u x_u
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,ck,H)
    chunk_state = jnp.einsum("bnuH,bnuHN,bnuHp->bnHpN", end_decay, b_h, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H) total decay

    # inter-chunk recurrence over nchunks (sequential scan)
    def scan_fn(state, inp):
        cs, cd = inp                                       # (B,H,P,N), (B,H)
        new = state * cd[..., None, None] + cs
        return new, state                                  # emit state *before*

    init = (jnp.zeros((B, H, P, N), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (chunk_state.swapaxes(0, 1).astype(jnp.float32),
         chunk_decay.swapaxes(0, 1).astype(jnp.float32)))
    prev_states = prev_states.swapaxes(0, 1)               # (B,nc,H,P,N)
    # contribution of carried-in state: y_t += exp(cum_t) c_t · state_in
    in_decay = jnp.exp(cum)                                # (B,nc,ck,H)
    y_inter = jnp.einsum("bntH,bntHN,bnHpN->bntHp",
                         in_decay, c_h, prev_states)
    y = (y_intra + y_inter).reshape(B, S_p, H, P)[:, :S]
    return y, final_state


def ssm_forward(params, x, cfg: ModelConfig):
    """Full-sequence SSD forward. x: (B, S, d_model) -> (B, S, d_model)."""
    s = cfg.ssm
    B, S, _ = x.shape
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    gn = s.ngroups * s.state_size
    if s.split_proj:
        z = x @ params["w_z"]
        xin = _causal_conv(x @ params["w_x"], params["conv_wx"],
                           params["conv_bx"])
        b = _causal_conv(x @ params["w_b"], params["conv_wb"],
                         params["conv_bb"])
        c = _causal_conv(x @ params["w_c"], params["conv_wc"],
                         params["conv_bc"])
        dt_raw = x @ params["w_dt"]
    else:
        proj = x @ params["w_in"]
        z, xbc, dt_raw = _split_in(proj, cfg)
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xin, b, c = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])             # (B,S,H)
    xh = _constrain_heads(xin.reshape(B, S, nheads, s.head_dim))
    bh = b.reshape(B, S, s.ngroups, s.state_size)
    ch = c.reshape(B, S, s.ngroups, s.state_size)
    y, _ = ssd_chunked(xh.astype(jnp.float32), dt, params["a_log"],
                       bh.astype(jnp.float32), ch.astype(jnp.float32),
                       chunk=min(s.chunk_size, S))
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    return y @ params["w_out"]


def make_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, nheads, s.head_dim, s.state_size), jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def _fused_weights(params, cfg: ModelConfig):
    """Reassemble the fused in-proj/conv layout from split params (decode
    reuses the fused code path; identical math)."""
    if "w_in" in params:
        return params["w_in"], params["conv_w"], params["conv_b"]
    w_in = jnp.concatenate([params["w_z"], params["w_x"], params["w_b"],
                            params["w_c"], params["w_dt"]], axis=-1)
    conv_w = jnp.concatenate([params["conv_wx"], params["conv_wb"],
                              params["conv_wc"]], axis=-1)
    conv_b = jnp.concatenate([params["conv_bx"], params["conv_bb"],
                              params["conv_bc"]], axis=-1)
    return w_in, conv_w, conv_b


def ssm_decode(params, x, cfg: ModelConfig, state: SSMState):
    """Single-token recurrent step. x: (B, 1, d_model)."""
    s = cfg.ssm
    B = x.shape[0]
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    w_in, conv_w, conv_b = _fused_weights(params, cfg)
    proj = x[:, 0] @ w_in                                  # (B, ·)
    z, xbc, dt_raw = _split_in(proj, cfg)
    # rolling conv buffer
    hist = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                          conv_w.astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + conv_b.astype(jnp.float32))
    new_conv = hist[:, 1:].astype(state.conv.dtype)
    gn = s.ngroups * s.state_size
    xin, b, c = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])                          # (H,)
    decay = jnp.exp(dt * a)                                # (B,H)
    xh = xin.reshape(B, nheads, s.head_dim)
    bh = jnp.repeat(b.reshape(B, s.ngroups, s.state_size),
                    nheads // s.ngroups, axis=1)           # (B,H,N)
    ch = jnp.repeat(c.reshape(B, s.ngroups, s.state_size),
                    nheads // s.ngroups, axis=1)
    upd = (dt[..., None] * xh)[..., :, None] * bh[..., None, :]  # (B,H,P,N)
    new_ssm = state.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bhpN,bhN->bhp", new_ssm, ch)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = _gated_norm(y, z[:, None, :], params["norm_scale"], cfg.norm_eps)
    out = y @ params["w_out"]
    return out, SSMState(new_conv, new_ssm, state.length + 1)
