"""Attention: GQA/MHA with RoPE, qk-norm, qkv-bias, sliding window,
prefix-LM masks, cross-attention, KV-cache decode, and DeepSeek-style MLA
(multi-head latent attention) with the absorbed decode form.

The training/prefill path uses a chunked flash-style attention in pure jnp
(online softmax over KV blocks) — this is simultaneously:
  * the memory-bounded XLA path used for CPU dry-run lowering, and
  * the numerical oracle for the Pallas ``flash_attention`` kernel.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_head_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked flash-style attention (jnp oracle / XLA path)
# ---------------------------------------------------------------------------


def chunked_attention(q, k, v, *, q_offset=0, causal=True, window=0,
                      prefix_len: int = 0, kv_valid_len=None, chunk: int = 512):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, H, D); k/v: (B, Sk, KVH, Dk/Dv). GQA handled by head-group
    reshape (no KV duplication in memory beyond one chunk).

    * ``q_offset`` — absolute position of q[0] (decode: cache length).
    * ``window`` — sliding-window size (0 = full). May be a traced int32
      scalar (per-layer windows ride along the layer scan).
    * ``prefix_len`` — bidirectional prefix (PaliGemma prefix-LM).
    * ``kv_valid_len`` — (B,) number of valid cache entries (decode).
    """
    window = jnp.asarray(window, jnp.int32)
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KVH
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Sq)

    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KVH, D)
    vc = v.reshape(B, n_chunks, chunk, KVH, Dv)

    def mask_for(k_pos):
        # (Sq, chunk) boolean allow-mask
        kp = k_pos[None, :]
        qp = q_pos[:, None]
        m = jnp.ones((Sq, chunk), bool)
        if causal:
            allow = kp <= qp
            if prefix_len:
                allow = allow | ((qp < prefix_len) & (kp < prefix_len))
            m = m & allow
        m = m & ((window <= 0) | (qp - kp < window))
        m = m & (kp < Sk)  # chunk padding
        return m

    def step(carry, inputs):
        m_run, l_run, acc = carry
        kch, vch, base = inputs
        k_pos = base + jnp.arange(chunk)
        # qf: (B,Sq,KVH,G,D) x kch: (B,chunk,KVH,D) -> (B,Sq,KVH,G,chunk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kch.astype(jnp.float32))
        allow = mask_for(k_pos)[None, :, None, None, :]
        if kv_valid_len is not None:
            allow = allow & (k_pos[None, :] < kv_valid_len[:, None])[:, None, None, None, :]
        s = jnp.where(allow, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vch.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Sq, KVH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KVH, G, Dv), jnp.float32)
    bases = jnp.arange(n_chunks) * chunk
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), bases))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# standard GQA attention block
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Decode cache. Full attention: capacity = context length.
    Sliding window: capacity = window (ring buffer, positions tracked)."""
    k: jax.Array          # (B, cap, KVH, D)
    v: jax.Array          # (B, cap, KVH, D)
    pos: jax.Array        # (B, cap) absolute positions, -1 = empty
    length: jax.Array     # (B,) tokens seen so far


def init_attention(key, cfg: ModelConfig, dtype, layer_global: bool = True):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KVH = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KVH * hd, dtype),
        "wv": dense_init(ks[2], d, KVH * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KVH * hd,), dtype)
        p["bv"] = jnp.zeros((KVH * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def make_kv_cache(cfg: ModelConfig, batch: int, context: int, dtype,
                  window_override: Optional[int] = None) -> KVCache:
    hd = cfg.resolved_head_dim
    window = cfg.sliding_window if window_override is None else window_override
    cap = min(context, window) if window else context
    return KVCache(
        k=jnp.zeros((batch, cap, cfg.num_kv_heads, hd), dtype),
        v=jnp.zeros((batch, cap, cfg.num_kv_heads, hd), dtype),
        pos=jnp.full((batch, cap), -1, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def _project_qkv(params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_head_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_forward(params, x, cfg: ModelConfig, *, positions=None,
                      layer_window=None):
    """Training/prefill self-attention.

    ``layer_window``: sliding window for THIS layer (0 = full); may be a
    traced scalar from the layer scan. ``None`` falls back to the config."""
    B, S, _ = x.shape
    window = cfg.sliding_window if layer_window is None else layer_window
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(
        q, k, v, causal=True, window=window,
        prefix_len=cfg.prefix_lm_prefix, chunk=min(cfg.attn_chunk, S))
    return out.reshape(B, S, -1) @ params["wo"]


def attention_decode(params, x, cfg: ModelConfig, cache: KVCache, *,
                     layer_window=None):
    """One-token decode against the cache; returns (out, new_cache)."""
    B = x.shape[0]
    window = jnp.asarray(cfg.sliding_window if layer_window is None
                         else layer_window, jnp.int32)
    pos = cache.length  # (B,)
    q, k, v = _project_qkv(params, x, cfg)  # S=1
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    cap = cache.k.shape[1]
    slot = pos % cap  # ring-buffer for windowed; identity while pos < cap
    bidx = jnp.arange(B)
    new_k = cache.k.at[bidx, slot].set(k[:, 0])
    new_v = cache.v.at[bidx, slot].set(v[:, 0])
    new_pos = cache.pos.at[bidx, slot].set(pos)
    valid = jnp.minimum(pos + 1, cap)
    # Ring buffer stores arbitrary order; mask by stored positions.
    kp = new_pos  # (B, cap)
    allow = (kp >= 0) & (kp <= pos[:, None])
    allow = allow & ((window <= 0) | (pos[:, None] - kp < window))
    qf = q.reshape(B, 1, cfg.num_kv_heads, -1, q.shape[-1]) \
          .transpose(0, 1, 3, 2, 4).astype(jnp.float32)
    G = cfg.num_heads // cfg.num_kv_heads
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqghd,bkhd->bqghk", qf * scale, new_k.astype(jnp.float32))
    s = s.transpose(0, 1, 3, 2, 4)  # (B,1,KVH,G,cap)
    s = jnp.where(allow[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, new_v.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.num_heads * v.shape[-1]).astype(x.dtype)
    out = o @ params["wo"]
    new_cache = KVCache(new_k, new_v, new_pos, pos + 1)
    return out, new_cache


# ---------------------------------------------------------------------------
# cross-attention (audio conditioning; non-causal over a fixed memory)
# ---------------------------------------------------------------------------


def init_cross_attention(key, cfg: ModelConfig, dtype):
    return init_attention(key, cfg, dtype)


def cross_attention_forward(params, x, memory, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = (memory @ params["wk"]).reshape(B, memory.shape[1], cfg.num_kv_heads, hd)
    v = (memory @ params["wv"]).reshape(B, memory.shape[1], cfg.num_kv_heads, hd)
    out = chunked_attention(q, k, v, causal=False,
                            chunk=min(cfg.attn_chunk, memory.shape[1]))
    return out.reshape(B, S, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# Multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    c_kv: jax.Array       # (B, cap, kv_lora) compressed latents
    k_rope: jax.Array     # (B, cap, rope_dim)
    length: jax.Array     # (B,)


def init_mla(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, m.q_lora_rank, dtype)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(ks[1], m.q_lora_rank, H * qd, dtype)
    else:
        p["wq"] = dense_init(ks[0], d, H * qd, dtype)
    p["wkv_a"] = dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype)
    p["kv_norm"] = jnp.ones((m.kv_lora_rank,), dtype)
    # decoupled: W_UK (kv_lora -> H*nope), W_UV (kv_lora -> H*v)
    p["w_uk"] = dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype)
    p["w_uv"] = dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dtype)
    p["wo"] = dense_init(ks[5], H * m.v_head_dim, d, dtype)
    return p


def _mla_q(params, x, cfg: ModelConfig):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        cq = x @ params["wq_a"]
        cqf = cq.astype(jnp.float32)
        cq = (cqf * jax.lax.rsqrt(jnp.mean(cqf * cqf, -1, keepdims=True) + cfg.norm_eps)
              * params["q_norm"].astype(jnp.float32)).astype(x.dtype)
        q = cq @ params["wq_b"]
    else:
        q = x @ params["wq"]
    q = q.reshape(B, S, H, qd)
    return q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def _mla_latent(params, x, cfg: ModelConfig):
    m = cfg.mla
    kv = x @ params["wkv_a"]
    c_kv, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    cf = c_kv.astype(jnp.float32)
    c_kv = (cf * jax.lax.rsqrt(jnp.mean(cf * cf, -1, keepdims=True) + cfg.norm_eps)
            * params["kv_norm"].astype(jnp.float32)).astype(x.dtype)
    return c_kv, k_rope


def mla_forward(params, x, cfg: ModelConfig, *, positions=None):
    """Training/prefill MLA in the expanded form."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(params, x, cfg)
    c_kv, k_rope = _mla_latent(params, x, cfg)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope], -1)
    out = chunked_attention(q, k, v, causal=True, chunk=min(cfg.attn_chunk, S))
    return out.reshape(B, S, -1) @ params["wo"]


def make_mla_cache(cfg: ModelConfig, batch: int, context: int, dtype) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, context, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, context, m.qk_rope_head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def mla_decode(params, x, cfg: ModelConfig, cache: MLACache):
    """Absorbed-form decode: scores/outputs computed in the latent space so
    the cache stays (kv_lora + rope_dim) per token — MLA's whole point."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    pos = cache.length
    q_nope, q_rope = _mla_q(params, x, cfg)          # (B,1,H,·)
    c_kv, k_rope = _mla_latent(params, x, cfg)       # (B,1,·)
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0]
    bidx = jnp.arange(B)
    new_ckv = cache.c_kv.at[bidx, pos].set(c_kv[:, 0])
    new_krope = cache.k_rope.at[bidx, pos].set(k_rope[:, 0])
    # absorb W_UK into q: q̃ = q_nope @ W_UK^T  -> latent-space query
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    # scores: latent part + rope part
    s_lat = jnp.einsum("bqhc,bkc->bhqk", q_lat, new_ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        new_krope.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.qk_nope_head_dim + m.qk_rope_head_dim,
                                       jnp.float32))
    s = (s_lat + s_rope) * scale
    allow = (jnp.arange(cache.c_kv.shape[1])[None, :] <= pos[:, None])
    s = jnp.where(allow[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqk,bkc->bqhc", p, new_ckv.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhc,chd->bqhd", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    out = o @ params["wo"]
    return out, MLACache(new_ckv, new_krope, pos + 1)
