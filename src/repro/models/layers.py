"""Shared model building blocks (functional: init_* returns a param pytree,
apply functions are pure).

All parameters are plain nested dicts of jnp arrays so the decentralized
optimizers, gossip mixing, and checkpointing treat every architecture
uniformly as a pytree.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LLM inits)."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out), jnp.float32)
    return (w * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return w.astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: int, dtype):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(params, x, cfg: ModelConfig):
    eps = cfg.norm_eps
    xf = x.astype(jnp.float32) if cfg.norm_in_f32 else x
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps=1e-6):
    """Per-head RMS norm used by Qwen3 qk-norm (normalizes head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def evonorm_b0(x, params, eps: float = 1e-5):
    """EvoNorm-B0 (Liu et al. 2020): batch-free at inference? No — B0 uses
    batch variance; for decentralized non-IID training the paper wants
    batch-stat-free layers, and EvoNorm-S0 is the sample-based variant.
    We implement **EvoNorm-S0** (group-std based, no batch statistics),
    which is the variant that transfers to decentralized training:

        y = x * sigmoid(v * x) / group_std(x) * gamma + beta
    """
    gamma, beta, v = params["gamma"], params["beta"], params["v"]
    b, h, w, c = x.shape
    groups = max(1, c // 8)
    xg = x.reshape(b, h, w, groups, c // groups)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    std = jnp.sqrt(var + eps)
    std = jnp.broadcast_to(std, xg.shape).reshape(b, h, w, c)
    num = x * jax.nn.sigmoid(v * x)
    return num / std * gamma + beta


def init_evonorm(c: int, dtype=jnp.float32):
    return {"gamma": jnp.ones((c,), dtype), "beta": jnp.zeros((c,), dtype),
            "v": jnp.ones((c,), dtype)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"wi": dense_init(k1, d, d_ff, dtype),
                "wg": dense_init(k2, d, d_ff, dtype),
                "wo": dense_init(k3, d_ff, d, dtype)}
    return {"wi": dense_init(k1, d, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d, dtype)}


def apply_mlp(params, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(x @ params["wg"]) * (x @ params["wi"])
    else:
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]
