"""ResNet-CIFAR with EvoNorm-S0 — the paper's architecture (ResNet20).

Faithful to §4.1: BasicBlock ResNet (3 stages), BatchNorm replaced with a
batch-statistics-free EvoNorm so the model trains correctly under non-IID
decentralized data (Hsieh et al. 2020; Andreux et al. 2020).
Implemented in NHWC with jax.lax.conv_general_dilated.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import evonorm_b0, init_evonorm


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
    return w * jnp.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_im2col(x, w, stride=1):
    """SAME conv as patch-gather + one matmul (no ``lax.conv`` in the
    graph). XLA:CPU runs vmapped-kernel convs ~4× slower and any conv
    inside a ``while`` loop ~5× slower (DESIGN.md §5); matmuls hit
    neither pathology, so this path makes the scan/shard runners viable
    for conv models on CPU (``ModelConfig.conv_backend="im2col"``).
    Padding follows XLA's SAME convention (low = total // 2), so outputs
    match ``_conv`` to float tolerance at every stride.
    """
    kh, kw, cin, cout = w.shape
    B, H, W, _ = x.shape
    ho = -(-H // stride)
    wo = -(-W // stride)
    ph = max((ho - 1) * stride + kh - H, 0)
    pw = max((wo - 1) * stride + kw - W, 0)
    xp = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                     (pw // 2, pw - pw // 2), (0, 0)))
    patches = [
        xp[:, dy:dy + (ho - 1) * stride + 1:stride,
           dx:dx + (wo - 1) * stride + 1:stride, :]
        for dy in range(kh) for dx in range(kw)
    ]
    cols = jnp.stack(patches, axis=-2)          # (B, ho, wo, kh·kw, cin)
    cols = cols.reshape(B, ho, wo, kh * kw * cin)
    return cols @ w.reshape(kh * kw * cin, cout)


class ResNetModel:
    """Same interface surface as DecoderModel (init / forward / loss)."""

    input_key = "images"

    def __init__(self, cfg: ModelConfig):
        assert cfg.arch_type == "cnn"
        if cfg.conv_backend not in ("lax", "im2col"):
            raise ValueError(f"unknown conv_backend {cfg.conv_backend!r}; "
                             "expected 'lax' or 'im2col'")
        self.cfg = cfg
        self._conv = _conv_im2col if cfg.conv_backend == "im2col" else _conv

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = iter(jax.random.split(key, 256))
        p: Dict[str, Any] = {
            "stem": _conv_init(next(keys), 3, 3, cfg.image_channels,
                               cfg.cnn_width),
            "stem_norm": init_evonorm(cfg.cnn_width),
        }
        cin = cfg.cnn_width
        for si, blocks in enumerate(cfg.cnn_stages):
            cout = cfg.cnn_width * (2 ** si)
            for bi in range(blocks):
                stride = 2 if (si > 0 and bi == 0) else 1
                blk = {
                    "conv1": _conv_init(next(keys), 3, 3, cin, cout),
                    "norm1": init_evonorm(cout),
                    "conv2": _conv_init(next(keys), 3, 3, cout, cout),
                    "norm2": init_evonorm(cout),
                }
                if stride != 1 or cin != cout:
                    blk["proj"] = _conv_init(next(keys), 1, 1, cin, cout)
                p[f"s{si}b{bi}"] = blk
                cin = cout
        p["fc_w"] = jax.random.normal(next(keys), (cin, cfg.num_classes),
                                      jnp.float32) / jnp.sqrt(cin)
        p["fc_b"] = jnp.zeros((cfg.num_classes,), jnp.float32)
        return p

    def forward_features(self, params, batch):
        """batch['images']: (B, H, W, C) float32 -> (feats (B, F), aux=0)
        — the pooled pre-head activations (streaming labeling hook)."""
        cfg = self.cfg
        conv = self._conv
        x = batch["images"]
        x = conv(x, params["stem"])
        x = evonorm_b0(x, params["stem_norm"])
        for si, blocks in enumerate(cfg.cnn_stages):
            for bi in range(blocks):
                stride = 2 if (si > 0 and bi == 0) else 1
                blk = params[f"s{si}b{bi}"]
                h = conv(x, blk["conv1"], stride)
                h = evonorm_b0(h, blk["norm1"])
                h = conv(h, blk["conv2"])
                h = evonorm_b0(h, blk["norm2"])
                sc = conv(x, blk["proj"], stride) if "proj" in blk else x
                x = jax.nn.relu(h + sc)
        return jnp.mean(x, axis=(1, 2)), jnp.zeros((), jnp.float32)

    def head_params(self, params):
        """(weight (F, C), bias (C,)) of the classifier head."""
        return params["fc_w"], params["fc_b"]

    def forward(self, params, batch):
        """batch['images']: (B, H, W, C) float32 -> (logits, aux=0)."""
        x, aux = self.forward_features(params, batch)
        w, b = self.head_params(params)
        return x @ w + b, aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        if labels.ndim == logits.ndim:          # soft labels (distillation)
            nll = -jnp.sum(labels * logp, axis=-1)
        else:
            nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        w = batch.get("weights")
        if w is None:
            loss = jnp.mean(nll)
        else:
            loss = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
        acc = jnp.mean((jnp.argmax(logits, -1) ==
                        (labels if labels.ndim == 1 else jnp.argmax(labels, -1))
                        ).astype(jnp.float32))
        return loss, {"nll": loss, "acc": acc, "aux": aux}
