"""Unified per-round communication ledger.

Before the scheduler, byte accounting was split: the simulator derived a
single ``comm_bytes_per_iter`` from the mean topology degree and tacked
the label payload on as one ``label_bytes_total`` scalar, while the
launch path accounted for nothing. The ledger records both kinds of
traffic in one place — per node, per round, per scenario:

* **gossip** — every training step, each *active* node ships its
  parameters to each active neighbour. Bytes are wire-dtype aware
  (bf16 params gossiped "native" cost 2 bytes/element, §Perf
  byte-halving; the simulator's full-precision mixing costs 4).
* **labels** — at each homogenization round, each node serializes its
  D_ID label payload once (``distill.label_bytes``: dense ``P·C·4`` or
  sparse top-k ``P·k·8``). Per-link traffic is this payload times the
  node's degree; the ledger records the serialized payload (the
  convention of the pre-scheduler accounting, kept so Table 6 numbers
  stay comparable).

"Round r" spans from the r-th homogenization step to the next one
(round 0 is everything before the first round), so a K-round schedule
yields K+1 gossip buckets and K label buckets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.topology import Topology


def wire_elem_bytes(wire_dtype: str, param_dtype: str) -> int:
    """Bytes per parameter element on the gossip wire."""
    if wire_dtype == "float32":
        return 4
    if param_dtype == "bfloat16":
        return 2
    return int(np.dtype(param_dtype).itemsize)


def gossip_bytes_per_step(topology: Topology, active: Optional[np.ndarray],
                          param_count: int, elem_bytes: int, *,
                          payload_elems: Optional[int] = None,
                          index_bytes: int = 0,
                          stale: Optional[np.ndarray] = None) -> np.ndarray:
    """(n,) bytes each node sends per step: active-degree · payload
    elements · per-element wire bytes. Down nodes (and links to them)
    carry nothing.

    Compressed wires (DESIGN.md §9): ``payload_elems`` overrides the raw
    ``param_count`` with the sparsified per-node element count
    (``mixing.payload_elem_count``), and ``index_bytes`` adds the int32
    index rider each value carries (top-k/random-k send value+index
    pairs, so 4 there; dense sends leave it 0). ``stale`` marks
    straggler nodes whose *outgoing* payload is frozen — they ship
    nothing new, so their send bytes are 0 (they still receive, which
    their neighbours' rows account for)."""
    n = topology.n
    act = np.ones(n, bool) if active is None else np.asarray(active, bool)
    deg = np.array([sum(act[j] for j in topology.neighbors(i))
                    if act[i] else 0 for i in range(n)], np.int64)
    if stale is not None:
        deg = np.where(np.asarray(stale, bool), 0, deg)
    elems = int(param_count) if payload_elems is None else int(payload_elems)
    return deg * elems * (int(elem_bytes) + int(index_bytes))


# per-node traffic status codes for gossip entries (see LedgerEntry.status)
STATUS_ACTIVE = 0       # training + gossiping normally
STATUS_STALE = 1        # straggler: frozen *outgoing* payload, 0 send bytes
STATUS_INACTIVE = 2     # churned out (freeze/isolate): no traffic at all
STATUS_QUARANTINED = 3  # guard-tripped / wire offender: held out by the
                        #   resilience layer (params frozen, no traffic)


@dataclass
class LedgerEntry:
    round_index: int          # rounds fired so far when this traffic moved
    kind: str                 # "gossip" | "labels"
    start: int                # first step of the span (labels: round step)
    stop: int                 # one past the last step (labels: == start)
    per_node: np.ndarray      # (n,) bytes
    status: Optional[np.ndarray] = None   # (n,) int8 STATUS_* codes, or None

    @property
    def total(self) -> float:
        return float(self.per_node.sum())


@dataclass
class CommLedger:
    """Append-only per-(node, round) byte ledger for one scenario run."""
    num_nodes: int
    meta: Dict = field(default_factory=dict)
    entries: List[LedgerEntry] = field(default_factory=list)

    def log_gossip(self, round_index: int, start: int, stop: int,
                   per_node_bytes_per_step: np.ndarray,
                   status: Optional[np.ndarray] = None) -> None:
        """``status`` (optional (n,) STATUS_* codes) attributes each
        node's 0-byte rows explicitly: a stale straggler's frozen send
        and a churned-out node's silence both cost 0 bytes, and without
        the codes mixed-traffic rounds cannot tell the two apart in
        ``per_round`` (the telemetry stream needs the distinction)."""
        per_node = np.asarray(per_node_bytes_per_step,
                              np.float64) * (stop - start)
        st = (np.asarray(status, np.int8) if status is not None else None)
        self.entries.append(LedgerEntry(round_index, "gossip", start, stop,
                                        per_node, st))

    def log_labels(self, round_index: int, step: int,
                   per_node_bytes: np.ndarray) -> None:
        self.entries.append(LedgerEntry(
            round_index, "labels", step, step,
            np.asarray(per_node_bytes, np.float64)))

    # ------------------------------------------------------------ queries
    def _sum(self, kind: str) -> float:
        return float(sum(e.total for e in self.entries if e.kind == kind))

    @property
    def gossip_bytes(self) -> float:
        return self._sum("gossip")

    @property
    def label_bytes(self) -> float:
        return self._sum("labels")

    @property
    def total_bytes(self) -> float:
        return self.gossip_bytes + self.label_bytes

    def gossip_steps(self) -> int:
        return sum(e.stop - e.start for e in self.entries
                   if e.kind == "gossip")

    def per_round(self) -> List[Dict]:
        """One row per round bucket: gossip + label bytes, totals and
        per-node breakdowns. When gossip entries carry status codes the
        row also attributes the quiet steps per node —
        ``stale_steps_per_node`` (frozen outgoing payload) vs
        ``inactive_steps_per_node`` (churned out entirely) — so a
        0-byte node is never ambiguous in mixed-traffic rounds."""
        rounds = sorted({e.round_index for e in self.entries})
        out = []
        for r in rounds:
            row = {"round": r}
            for kind in ("gossip", "labels"):
                sel = [e for e in self.entries
                       if e.round_index == r and e.kind == kind]
                per_node = (np.sum([e.per_node for e in sel], axis=0)
                            if sel else np.zeros(self.num_nodes))
                row[f"{kind}_bytes"] = float(np.sum(per_node))
                row[f"{kind}_per_node"] = np.asarray(
                    per_node, np.float64).tolist()
            gossip_sel = [e for e in self.entries
                          if e.round_index == r and e.kind == "gossip"]
            row["steps"] = sum(e.stop - e.start for e in gossip_sel)
            stale = np.zeros(self.num_nodes, np.int64)
            inactive = np.zeros(self.num_nodes, np.int64)
            quarantined = np.zeros(self.num_nodes, np.int64)
            for e in gossip_sel:
                if e.status is None:
                    continue
                span = e.stop - e.start
                stale += span * (e.status == STATUS_STALE)
                inactive += span * (e.status == STATUS_INACTIVE)
                quarantined += span * (e.status == STATUS_QUARANTINED)
            row["stale_steps_per_node"] = stale.tolist()
            row["inactive_steps_per_node"] = inactive.tolist()
            row["quarantined_steps_per_node"] = quarantined.tolist()
            out.append(row)
        return out

    def as_dict(self) -> Dict:
        return {"meta": dict(self.meta),
                "num_nodes": self.num_nodes,
                "gossip_bytes": self.gossip_bytes,
                "label_bytes": self.label_bytes,
                "total_bytes": self.total_bytes,
                "per_round": self.per_round()}
