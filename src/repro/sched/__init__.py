"""Federation scheduler subsystem: schedule compiler, event-driven outer
loop, and the unified communication ledger (DESIGN.md §6)."""
from repro.sched.ledger import (CommLedger, LedgerEntry,  # noqa: F401
                                gossip_bytes_per_step, wire_elem_bytes)
from repro.sched.schedule import (CHURN_MODES, GOSSIP_MODES,  # noqa: F401
                                  ChurnEvent, FaultEvent, HomogenizeEvent,
                                  RewireEvent, Schedule, Segment,
                                  compile_schedule, fit_every_k,
                                  idkd_round_steps, parse_churn,
                                  parse_faults)
from repro.sched.scheduler import (CompiledFederationHooks,  # noqa: F401
                                   FederationHooks, run_schedule,
                                   validate_shard_schedule)
