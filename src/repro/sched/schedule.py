"""Federation schedule compiler.

A :class:`Schedule` is the outer-loop structure of a decentralized run —
the thing both drivers used to hand-roll: train chunks between eval
boundaries, homogenization (label-exchange) rounds, and the scenario
events that make the federation *dynamic* (nodes dropping out and
rejoining, the gossip graph being rewired mid-run).

:func:`compile_schedule` turns (steps, eval boundaries, round steps,
events) into an ordered tuple of :class:`Segment` s — the exact chunk
[start, stop) spans the scan/host runners of ``core.driver`` consume.
Events are attached to the segment at whose *start* they fire, ordered
so topology changes (churn / rewire) land before the homogenization
round at the same step: a label exchange always runs on the graph that
is current at its step.

Degenerate-schedule equivalence (DESIGN.md §6): with a single round at
``start_step`` and no events, the compiled segment spans are *identical*
to ``core.driver.eval_boundaries(steps, eval_every, extra=start_step)``
— the boundaries both drivers used before the scheduler existed — so a
1-round schedule reproduces the pre-scheduler trajectories exactly
(same chunks, same PRNG key sequence, same jitted step).

Schedule parameters are validated loudly: unknown event types, malformed
churn specs, out-of-range steps, and inconsistent IDKD round settings
(``num_rounds > 1`` with ``every_k_steps <= 0``) all raise instead of
being silently ignored.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple, Union

from repro.configs.base import IDKDConfig
from repro.core.topology import Topology
from repro.resil.faults import CORRUPT_MODES, FAULT_KINDS

CHURN_MODES = ("freeze", "isolate", "stale")
GOSSIP_MODES = ("sync", "delayed")


@dataclass(frozen=True)
class HomogenizeEvent:
    """Run one IDKD labeling round at ``step`` (before training resumes)."""
    step: int
    round_index: int = 0


@dataclass(frozen=True)
class ChurnEvent:
    """Node availability change at ``step``.

    ``down`` nodes leave the federation, ``up`` nodes rejoin.
    ``mode="freeze"``: a down node neither trains nor gossips (its params
    and optimizer state are held). ``mode="isolate"``: a *straggler* — it
    keeps training locally but misses every gossip exchange.
    ``mode="stale"``: a *slow* node — it stays in the federation (trains,
    receives gossip, keeps its Metropolis weights) but its *outgoing*
    payload is frozen at the last one it produced, so neighbours mix a
    stale snapshot instead of stalling on it (DESIGN.md §9). Stale runs
    use the stateful gossip mixers; the scheduler forces the comm pytree
    on for the whole schedule so its structure never changes mid-scan.
    """
    step: int
    down: Tuple[int, ...] = ()
    up: Tuple[int, ...] = ()
    mode: str = "freeze"


@dataclass(frozen=True)
class RewireEvent:
    """Swap the gossip graph at ``step``. ``topology`` is a kind string
    (resolved via ``Topology.make`` against the run's node count) or a
    prebuilt :class:`Topology`."""
    step: int
    topology: Union[str, Topology] = "ring"


@dataclass(frozen=True)
class FaultEvent:
    """Deterministic fault at ``step`` (DESIGN.md §12).

    ``kind="drop"``: the listed nodes' outgoing gossip payloads are lost
    from this step on. ``kind="corrupt"``: they are corrupted in flight
    with ``mode`` (``nan`` / ``inf`` / ``bitflip``). ``kind="crash"``:
    the whole run process dies here (``resil.SimulatedCrash``) —
    recovery is auto-resume from the latest durable snapshot.
    ``kind="clear"``: the listed nodes' wire faults end (no nodes =
    clear all). Wire faults are per-segment static: the compiler cuts a
    boundary at every fault step, so the jitted runner bakes the fault
    in as a mixer wrapper with no in-jit step dependence."""
    step: int
    kind: str = "drop"
    nodes: Tuple[int, ...] = ()
    mode: str = "nan"


Event = Union[HomogenizeEvent, ChurnEvent, RewireEvent, FaultEvent]
_EVENT_TYPES = (HomogenizeEvent, ChurnEvent, RewireEvent, FaultEvent)


@dataclass(frozen=True)
class Segment:
    """One train chunk [start, stop); ``events`` fire at ``start`` before
    any step runs; ``eval_after`` marks an eval boundary at ``stop``."""
    start: int
    stop: int
    events: Tuple[Event, ...] = ()
    eval_after: bool = False

    @property
    def num_steps(self) -> int:
        return self.stop - self.start


@dataclass(frozen=True)
class Schedule:
    steps: int
    eval_every: int
    segments: Tuple[Segment, ...] = ()
    round_steps: Tuple[int, ...] = ()
    gossip: str = "sync"    # "sync" | "delayed" (one-step-stale mixing)

    @property
    def has_stale(self) -> bool:
        """True when any churn event marks a node a stale straggler —
        the run then needs the stateful gossip mixers from step 0."""
        return any(isinstance(ev, ChurnEvent) and ev.mode == "stale"
                   for seg in self.segments for ev in seg.events)

    @property
    def has_faults(self) -> bool:
        return any(isinstance(ev, FaultEvent)
                   for seg in self.segments for ev in seg.events)

    def boundaries(self) -> List[Tuple[int, int]]:
        """The chunk [start, stop) spans — ``driver.eval_boundaries``'s
        contract, for the degenerate-equivalence check."""
        return [(s.start, s.stop) for s in self.segments]

    @property
    def num_rounds(self) -> int:
        return len(self.round_steps)

    def validate_resume(self, step: int, with_ctx: bool = False) -> None:
        """Resume is legal at step 0 or at a segment start; if any
        homogenization round precedes the resume point, the resume step
        must itself be a round step (the round re-fires there from the
        restored params — earlier rounds' sampler payloads are stale and
        unreconstructable without replaying training). ``with_ctx=True``
        relaxes the round rule: the checkpoint carries the
        homogenization ctx itself (a durable snapshot), so *any* segment
        boundary is resumable."""
        if step == 0:
            return
        starts = {s.start for s in self.segments}
        if step not in starts:
            raise ValueError(
                f"cannot resume at step {step}: not a segment boundary "
                f"(boundaries: {sorted(starts)})")
        if not with_ctx and any(r < step for r in self.round_steps) and \
                step not in self.round_steps:
            raise ValueError(
                f"cannot resume at step {step}: a homogenization round "
                f"fired earlier ({[r for r in self.round_steps if r < step]}) "
                "and its sampler state is not part of the checkpoint; "
                "resume at a round boundary instead "
                f"(rounds: {list(self.round_steps)})")


def fit_every_k(steps: int, start: int, rounds: int) -> int:
    """The even ``every_k_steps`` spacing that fits ``rounds``
    homogenization rounds into ``[start, steps)`` — the CLIs' default
    when the user asks for a round count without a period."""
    return max(1, (steps - start) // max(rounds, 1))


def idkd_round_steps(cfg: IDKDConfig, steps: int) -> Tuple[int, ...]:
    """The homogenization steps an :class:`IDKDConfig` asks for:
    ``num_rounds`` rounds spaced ``every_k_steps`` apart from
    ``start_step``, clipped to the run length. This is where the
    previously dead ``every_k_steps`` knob is routed."""
    rounds = int(cfg.num_rounds)
    if rounds < 0:
        raise ValueError(f"IDKDConfig.num_rounds must be >= 0, got {rounds}")
    if rounds > 1 and cfg.every_k_steps <= 0:
        raise ValueError(
            f"IDKDConfig.num_rounds={rounds} needs every_k_steps > 0 "
            f"to space the rounds, got {cfg.every_k_steps}")
    if rounds == 0 or cfg.start_step < 0:
        return ()
    out = [cfg.start_step + j * cfg.every_k_steps for j in range(rounds)]
    return tuple(s for s in out if s < steps)


def _validate_events(events: Sequence[Event], steps: int) -> List[Event]:
    out = []
    for ev in events:
        if not isinstance(ev, _EVENT_TYPES):
            raise TypeError(
                f"unknown schedule event {ev!r}; expected one of "
                f"{[t.__name__ for t in _EVENT_TYPES]}")
        if isinstance(ev, HomogenizeEvent):
            # rounds must come in via round_steps: a round smuggled
            # through events= would be invisible to Schedule.round_steps,
            # validate_resume, and the drivers' no-KD guards, and would
            # fire before same-step churn/rewire events
            raise ValueError(
                "pass homogenization rounds via round_steps=, not "
                "events=; HomogenizeEvents are compiled from round_steps "
                "so resume validation and the drivers' KD guards see them")
        if not 0 <= ev.step < steps:
            raise ValueError(f"event step {ev.step} outside [0, {steps})")
        if isinstance(ev, ChurnEvent):
            if ev.mode not in CHURN_MODES:
                raise ValueError(f"unknown churn mode {ev.mode!r}; "
                                 f"expected one of {CHURN_MODES}")
            if not ev.down and not ev.up:
                raise ValueError(f"churn event at step {ev.step} names no "
                                 "nodes (empty down and up)")
        if isinstance(ev, FaultEvent):
            if ev.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {ev.kind!r}; "
                                 f"expected one of {FAULT_KINDS}")
            if ev.mode not in CORRUPT_MODES:
                raise ValueError(f"unknown corruption mode {ev.mode!r}; "
                                 f"expected one of {CORRUPT_MODES}")
            if ev.kind in ("drop", "corrupt") and not ev.nodes:
                raise ValueError(f"{ev.kind} fault at step {ev.step} "
                                 "names no sender nodes")
        out.append(ev)
    return out


def compile_schedule(steps: int, eval_every: int, *,
                     round_steps: Sequence[int] = (),
                     events: Sequence[Event] = (),
                     gossip: str = "sync") -> Schedule:
    """Compile the outer loop into runner-ready segments.

    Cuts fall at 0/steps, after every eval step, at every homogenization
    round, and at every event step; each segment carries the events that
    fire at its start (churn/rewire ordered before the round at the same
    step) and an ``eval_after`` flag matching the drivers' historical
    ``last % eval_every == 0 or last == steps - 1`` eval rule.
    ``gossip="delayed"`` selects one-step-stale mixing for every training
    segment (the drivers pick the stateful mixers accordingly).
    """
    if steps <= 0:
        raise ValueError(f"steps must be positive, got {steps}")
    if eval_every <= 0:
        raise ValueError(f"eval_every must be positive, got {eval_every}")
    if gossip not in GOSSIP_MODES:
        raise ValueError(f"unknown gossip mode {gossip!r}; expected one "
                         f"of {GOSSIP_MODES}")
    rounds = sorted(set(int(s) for s in round_steps))
    for s in rounds:
        if not 0 <= s < steps:
            raise ValueError(f"round step {s} outside [0, {steps})")
    events = _validate_events(events, steps)

    # eval cuts come from the drivers' own boundary rule — one source of
    # truth for the degenerate-equivalence contract (DESIGN.md §6)
    from repro.core.driver import eval_boundaries
    cuts = {0}
    cuts |= {b for _, b in eval_boundaries(steps, eval_every)}
    cuts |= set(rounds)
    cuts |= {ev.step for ev in events}
    edges = sorted(cuts)

    by_step: dict = {}
    for ev in events:                          # churn / rewire fire first
        by_step.setdefault(ev.step, []).append(ev)
    for i, s in enumerate(rounds):             # then the label exchange
        by_step.setdefault(s, []).append(HomogenizeEvent(s, round_index=i))

    segments = []
    for a, b in zip(edges[:-1], edges[1:]):
        segments.append(Segment(
            start=a, stop=b, events=tuple(by_step.get(a, ())),
            eval_after=((b - 1) % eval_every == 0 or b == steps)))
    return Schedule(steps=steps, eval_every=eval_every,
                    segments=tuple(segments), round_steps=tuple(rounds),
                    gossip=gossip)


# ------------------------------------------------------------- CLI parsing
def parse_churn(spec: str, num_nodes: int, steps: int,
                mode: str = "freeze") -> List[ChurnEvent]:
    """Parse a ``node@down-up[,node@down-up...]`` churn spec into paired
    down/up events, e.g. ``"3@120-180"``: node 3 leaves at step 120 and
    rejoins at step 180 (omit ``-up`` to keep the node down to the end).
    Malformed specs and out-of-range nodes/steps raise."""
    events: List[ChurnEvent] = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        try:
            node_s, window = part.split("@")
            node = int(node_s)
            lo_s, _, hi_s = window.partition("-")
            lo = int(lo_s)
            hi = int(hi_s) if hi_s else None
        except ValueError as e:
            raise ValueError(
                f"malformed churn spec {part!r}; expected node@down-up "
                "(e.g. '3@120-180' or '3@120')") from e
        if not 0 <= node < num_nodes:
            raise ValueError(f"churn node {node} outside [0, {num_nodes})")
        if not 0 <= lo < steps or (hi is not None and not lo < hi < steps):
            raise ValueError(f"churn window {part!r} outside the "
                             f"[0, {steps}) run")
        events.append(ChurnEvent(step=lo, down=(node,), mode=mode))
        if hi is not None:
            events.append(ChurnEvent(step=hi, up=(node,), mode=mode))
    return events


def parse_faults(spec: str, num_nodes: int, steps: int) -> List[FaultEvent]:
    """Parse a ``kind@step[/nodes][/mode]`` fault spec (comma-separated;
    nodes joined with ``+``), e.g. ``"corrupt@8/2/nan,crash@14"``: node
    2's gossip payloads turn NaN from step 8, the process crashes at
    step 14. ``clear@step`` ends all wire faults. Malformed specs and
    out-of-range nodes/steps raise."""
    events: List[FaultEvent] = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        try:
            kind, _, rest = part.partition("@")
            fields = rest.split("/")
            step = int(fields[0])
            nodes = tuple(int(v) for v in fields[1].split("+")) \
                if len(fields) > 1 and fields[1] else ()
            mode = fields[2] if len(fields) > 2 else "nan"
        except (ValueError, IndexError) as e:
            raise ValueError(
                f"malformed fault spec {part!r}; expected "
                "kind@step[/nodes][/mode] (e.g. 'corrupt@8/2/nan', "
                "'drop@5/0+3', 'crash@14')") from e
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; expected one "
                             f"of {FAULT_KINDS}")
        if not 0 <= step < steps:
            raise ValueError(f"fault step {step} outside [0, {steps})")
        for node in nodes:
            if not 0 <= node < num_nodes:
                raise ValueError(f"fault node {node} outside "
                                 f"[0, {num_nodes})")
        events.append(FaultEvent(step=step, kind=kind, nodes=nodes,
                                 mode=mode))
    return events
