"""Event-driven federation scheduler — the one outer loop.

:func:`run_schedule` replays a compiled :class:`~repro.sched.schedule.
Schedule` against a set of driver *hooks*: the scheduler owns segment
iteration, event application (churn masks, graph rewires, homogenization
rounds), communication accounting, mid-run checkpoint capture, and
resume; the hooks own everything model-specific (how to build a runner
for the current phase/graph, how to run a labeling round, what to do at
an eval boundary). ``core.simulator.DecentralizedSimulator`` and
``launch.train.run_training`` both drive this loop — neither hand-rolls
the chunked scan/eval/homogenize structure anymore.

The federation state threaded through the loop:

* ``topology`` — the current gossip graph (swapped by ``RewireEvent``);
* ``active``  — the node availability mask (updated by ``ChurnEvent``);
* ``frozen``  — the subset of down nodes with ``freeze`` semantics
  (params and optimizer state held); down nodes *not* in it are
  ``isolate`` stragglers — they keep training locally but miss gossip.
  Each ChurnEvent's ``mode`` applies to its own ``down`` nodes, so
  frozen and isolated nodes coexist;
* ``stale``   — the straggler-tolerant mask (``mode="stale"`` churn):
  stale nodes stay *active* — they train and receive gossip — but
  their outgoing payload is frozen at the last one they produced, so
  neighbours mix a stale snapshot instead of waiting (DESIGN.md §9).
  The ledger charges stale senders zero bytes;
* ``comm``    — the stateful gossip mixers' comm pytree (error-feedback
  residuals + last wire payloads) when the schedule uses compression,
  delayed gossip, or stale churn: built once by ``hooks.init_comm`` and
  threaded through every runner call, like params;
* rounds fired so far — the ledger's round bucket index.

Resume replays topology events *before* the resume step (they are cheap
and parameter-free) but skips training and any homogenization round in
the skipped span — ``Schedule.validate_resume`` guarantees the first
executed segment re-fires a round when one is needed, so a checkpoint
taken at a round boundary rejoins the uninterrupted trajectory exactly
(same params → same labeling round → same sampler → same keys).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology
from repro.resil.faults import SimulatedCrash, WireFault
from repro.sched.ledger import CommLedger, gossip_bytes_per_step
from repro.sched.schedule import (ChurnEvent, FaultEvent, HomogenizeEvent,
                                  RewireEvent, Schedule)


class FederationHooks:
    """Driver-specific callbacks for :func:`run_schedule` (subclass and
    override; the base class documents the protocol)."""

    def init_comm(self, params, topology: Topology,
                  schedule: Schedule) -> Optional[Any]:
        """Build the stateful gossip mixers' initial comm pytree for a
        schedule that needs one (compression, delayed gossip, or stale
        churn anywhere in the run — the comm structure must be constant
        across every segment, so statefulness is decided up front).
        Return None for plain synchronous gossip (the base default)."""
        return None

    def init_metrics(self, params, topology: Topology) -> Optional[Any]:
        """Build the on-device metrics-bus pytree (:mod:`repro.obs.
        metrics`) threaded through every runner call when telemetry is
        on. Return None to keep the metrics bus off (the base default)."""
        return None

    def init_guard(self, params, topology: Topology) -> Optional[Any]:
        """Build the on-device health-guard counter pytree (:mod:`repro.
        resil.guards`) threaded through every runner call when the
        resilience guard is on. Return None to keep the guard off (the
        base default)."""
        return None

    def restore_ctx(self, ctx: Dict, phase: str) -> None:
        """A durable snapshot captured mid-phase is being restored:
        rebuild the KD sampler from the snapshot's flat str→array
        homogenization payload and set the phase. The base default
        rejects mid-phase resume — only hooks that homogenize need it."""
        raise NotImplementedError(
            "these hooks cannot restore a mid-phase homogenization "
            "context; resume from a round-boundary snapshot instead")

    def on_topology(self, topology: Topology, active: np.ndarray,
                    frozen: np.ndarray, stale: np.ndarray) -> None:
        """The gossip graph, availability mask, or straggler mask
        changed; invalidate or re-key any mixer/step caches."""

    def on_segment(self, segment, index: int) -> None:
        """A schedule segment is about to run (telemetry hook; the base
        default does nothing)."""

    def on_round(self, params, round_index: int, step: int,
                 topology: Topology, active: np.ndarray
                 ) -> Optional[np.ndarray]:
        """Run one homogenization round from the current params; swap the
        KD sampler in. Returns (n,) per-node label payload bytes for the
        ledger (or None to skip label accounting)."""
        return None

    def on_labels(self, round_index: int, step: int,
                  stats: Optional[Dict]) -> None:
        """Label-round statistics are available (telemetry hook).
        ``stats`` is whatever the ``on_round`` implementation stashed in
        ``self.last_round_stats`` — detector thresholds, per-node
        selected counts, neighbour top-k overlap — or None when the
        round produced none. The base default does nothing."""

    def runner(self, topology: Topology, active: np.ndarray,
               frozen: np.ndarray, stale: np.ndarray) -> Callable:
        """A ``run(params, opt_state, key, step0, num_steps)`` runner for
        the current phase, graph, availability mask, frozen subset, and
        straggler (stale) mask. A runner flagged ``run.comm`` takes and
        returns the gossip comm pytree: ``run(..., comm=comm) -> (params,
        opt_state, key, losses, comm)``; one flagged ``run.metrics``
        takes and returns the metrics pytree the same way (trailing,
        after comm when both are present)."""
        raise NotImplementedError

    def on_eval(self, params, step: int, losses) -> None:
        """An eval boundary was crossed after ``step``."""


class CompiledFederationHooks(FederationHooks):
    """:class:`FederationHooks` plus the compiled-object caching both
    drivers need: mixers, steps, and runners keyed by (phase, graph,
    availability mask, freeze mask), so alternating churn masks and
    repeated graphs reuse their jitted executables, and the
    round-varying sampler payload rides in ``self.ctx`` (threaded
    through the runner for every non-plain phase — a traced argument,
    so refreshing it costs no recompile).

    ``driver_mode="shard"`` routes step building through
    ``driver.make_shard_step`` — the node axis lives on a
    ``launch.mesh.make_node_mesh`` mesh and gossip runs inside
    ``shard_map`` via the ppermute backend. Shard mode has no churn
    path: availability masks raise here (and
    :func:`validate_shard_schedule` rejects such schedules before the
    run starts), topology swaps are fine as long as the target is a
    ring/complete graph.

    Subclasses set ``model``, ``algo``, ``lr_fn``, ``driver_mode`` —
    plus ``compression`` / ``gossip`` for the compressed-wire path —
    and the phase state (``phase`` starts "plain"; ``on_round``
    overrides advance it and refresh ``ctx``), and implement:

    * ``_make_mixer(topology, active, stale=None)`` — backend /
      wire-dtype choice (``active`` is None for the all-up mask,
      ``stale`` None for no stragglers); forwards ``_mixer_opts()`` to
      ``mixing.make_mixer`` so compression / gossip / forced
      statefulness reach every mixer it builds;
    * ``_adapter()`` — the loss adapter for the current phase;
    * ``_sampler()`` — the sampler for the current phase.

    Graphs are keyed by ``Topology.edge_key()`` (the canonical edge set),
    not by name, so a rewire back to an equivalent graph — or a schedule
    replay that re-resolves its events — hits the warm cache.
    """

    model = None
    algo = None
    lr_fn = None
    driver_mode = "scan"
    model_parallel = 1        # shard mode: width of the mesh "model" axis
    compression = None        # None | "topk:frac" | "randk:frac" | (kind, f)
    gossip = "sync"           # overwritten from the schedule by init_comm

    def __init__(self):
        self.phase = "plain"
        self.ctx = None
        self._mixers: Dict = {}
        self._steps: Dict = {}
        self._runners: Dict = {}
        self._node_mesh = None
        self._force_state = False
        # telemetry: a repro.obs.Telemetry (or None). Its metrics flag
        # turns the on-device metrics bus on, so the step/runner caches
        # key on it — the same graph compiles differently with the
        # metrics carry attached.
        self.telemetry = None
        # resilience: a repro.resil.Resilience (or None). Its guard spec
        # attaches the health-guard carry (step/runner caches key on it)
        # and wire_fault is the currently-injected WireFault, updated by
        # run_schedule as FaultEvents fire (mixer caches key on it).
        self.resil = None
        self.wire_fault: Optional[WireFault] = None
        # on_round implementations stash label-round statistics here for
        # run_schedule to hand to on_labels / the run log
        self.last_round_stats: Optional[Dict] = None

    def _metrics_on(self) -> bool:
        tel = self.telemetry
        return tel is not None and getattr(tel, "metrics_enabled", False)

    def _guard_spec(self):
        res = self.resil
        return None if res is None else res.guard

    def _fault_key(self) -> Optional[WireFault]:
        wf = self.wire_fault
        return None if wf is None or wf.is_noop() else wf

    def init_metrics(self, params, topology: Topology) -> Optional[Any]:
        if not self._metrics_on():
            return None
        from repro.obs import metrics as obs_metrics
        return obs_metrics.init_node_metrics(topology.n)

    def init_guard(self, params, topology: Topology) -> Optional[Any]:
        if self._guard_spec() is None:
            return None
        from repro.resil import guards
        return guards.init_node_guard(topology.n)

    def _make_mixer(self, topology: Topology, active,
                    stale=None) -> Callable:
        raise NotImplementedError

    def _adapter(self):
        raise NotImplementedError

    def _sampler(self):
        raise NotImplementedError

    def _mixer_opts(self) -> Dict:
        """kwargs a ``_make_mixer`` implementation forwards to
        ``mixing.make_mixer``: the run's compression spec, gossip mode,
        and — once ``init_comm`` saw a schedule that needs state
        anywhere — ``stateful=True``, so every mixer of the run carries
        the same comm structure (a scan carry cannot change pytree
        structure mid-schedule). ``wire_fault`` / ``wire_guard`` are the
        resilience layer's currently-injected fault and guard spec
        (payload validation thresholds) — both None for a fault-free
        run, in which case the mixers come back completely unwrapped."""
        return {"compression": self.compression, "gossip": self.gossip,
                "stateful": True if self._force_state else None,
                "wire_fault": self._fault_key(),
                "wire_guard": self._guard_spec()}

    def init_comm(self, params, topology: Topology,
                  schedule: Schedule) -> Optional[Any]:
        from repro.core import mixing
        self.gossip = schedule.gossip
        self._force_state = bool(
            mixing.normalize_compression(self.compression) is not None
            or self.gossip == "delayed" or schedule.has_stale)
        if not self._force_state:
            return None
        n = topology.n
        step = self._step(topology, np.ones(n, bool), np.zeros(n, bool),
                          np.zeros(n, bool))
        comm = step.init_comm(params)
        if self.driver_mode == "shard":
            import jax

            from repro.launch.sharding import federation_shardings
            comm = jax.device_put(comm, federation_shardings(
                comm, self.shard_mesh(n), n))
        return comm

    # ------------------------------------------------------------- caches
    @staticmethod
    def _mask_key(active: np.ndarray):
        return None if active.all() else tuple(np.flatnonzero(~active))

    @staticmethod
    def _freeze_key(frozen: np.ndarray):
        return tuple(np.flatnonzero(frozen)) if frozen.any() else None

    @staticmethod
    def _stale_key(stale: np.ndarray):
        return tuple(np.flatnonzero(stale)) if stale.any() else None

    def _mixer(self, topo: Topology, active: np.ndarray, stale=None):
        mask = self._mask_key(active)
        sk = (self._stale_key(stale) if stale is not None else None)
        key = (topo.edge_key(), mask, sk, self._fault_key())
        if key not in self._mixers:
            if mask is None and sk is None:
                self._mixers[key] = self._make_mixer(topo, None)
            else:
                # churn path: remake the cached all-up mixer for the new
                # availability / straggler masks (same backend/wire
                # choice); mixers without a remake handle are rebuilt
                base = self._mixer(topo, np.ones_like(active))
                remake = getattr(base, "remake", None)
                self._mixers[key] = (
                    remake(active=(active if mask is not None else None),
                           stale=stale)
                    if remake is not None
                    else self._make_mixer(topo, active, stale))
        return self._mixers[key]

    def shard_mesh(self, num_nodes: int):
        """The (cached) federation mesh shard-mode steps run on — 1-D
        node mesh at ``model_parallel == 1``, 2-D ``("node", "model")``
        otherwise."""
        if self._node_mesh is None:
            from repro.launch.mesh import make_federation_mesh
            self._node_mesh = make_federation_mesh(num_nodes,
                                                   self.model_parallel)
        return self._node_mesh

    def _base_step(self, topo: Topology, active: np.ndarray,
                   stale: np.ndarray):
        from repro.core import driver
        if self.driver_mode == "shard":
            if not active.all():
                raise ValueError(
                    "shard driver cannot apply churn availability masks "
                    "(freeze/isolate need the node-stacked gather/dense "
                    "mixers — DESIGN.md §7); run churn schedules with "
                    "driver_mode='scan' or 'host'")
            if stale.any():
                raise ValueError(
                    "shard driver cannot apply straggler (stale) masks — "
                    "run stale-churn schedules with driver_mode='scan' "
                    "or 'host' (DESIGN.md §9)")
            if self._fault_key() is not None:
                raise ValueError(
                    "wire-fault injection (drop/corrupt) is unsupported "
                    "under driver_mode='shard' — the validated mixers are "
                    "node-stacked; run fault schedules with "
                    "driver_mode='scan' or 'host' (DESIGN.md §12)")
            return driver.make_shard_step(
                self.model, self.algo, self._adapter(),
                mesh=self.shard_mesh(topo.n), topology=topo,
                compression=self.compression, gossip=self.gossip,
                telemetry=self._metrics_on(), guard=self._guard_spec())
        return driver.make_step(
            self.model, self.algo,
            self._mixer(topo, active, stale if stale.any() else None),
            self._adapter(), telemetry=self._metrics_on(),
            guard=self._guard_spec())

    def _cache_key(self, topo: Topology, active: np.ndarray,
                   frozen: np.ndarray, stale: np.ndarray):
        return (self.phase, topo.edge_key(), self._mask_key(active),
                self._freeze_key(frozen), self._stale_key(stale),
                self._metrics_on(), self._fault_key(), self._guard_spec())

    def _step(self, topo: Topology, active: np.ndarray,
              frozen: np.ndarray, stale: np.ndarray):
        from repro.core import driver
        key = self._cache_key(topo, active, frozen, stale)
        if key not in self._steps:
            step = self._base_step(topo, active, stale)
            if self._freeze_key(frozen) is not None:
                # hold exactly the frozen subset; isolate stragglers
                # (down but unfrozen) keep taking local steps
                step = driver.make_frozen_step(step, ~frozen)
            self._steps[key] = step
        return self._steps[key]

    def runner(self, topo: Topology, active: np.ndarray,
               frozen: np.ndarray, stale: np.ndarray) -> Callable:
        from repro.core import driver
        key = self._cache_key(topo, active, frozen, stale)
        if key not in self._runners:
            self._runners[key] = driver.make_runner(
                self._step(topo, active, frozen, stale), self._sampler(),
                self.lr_fn, self.driver_mode)
        run = self._runners[key]
        has_comm = getattr(run, "comm", False)
        has_metrics = getattr(run, "metrics", False)
        has_guard = getattr(run, "guard", False)
        if has_comm or has_metrics or has_guard:
            ctx = None if self.phase == "plain" else self.ctx

            def aug_run(p, o, k, s0, ns, comm=None, metrics=None,
                        guard=None, _run=run, _ctx=ctx):
                return _run(p, o, k, s0, ns, _ctx, comm, metrics, guard)

            aug_run.comm = has_comm
            aug_run.metrics = has_metrics
            aug_run.guard = has_guard
            return aug_run
        if self.phase == "plain":
            return run
        return lambda p, o, k, s0, ns: run(p, o, k, s0, ns, self.ctx)


def validate_shard_schedule(schedule: Schedule, num_nodes: int,
                            model_parallel: int = 1) -> None:
    """Pre-flight for ``driver_mode="shard"``: shard_map gossip has no
    churn path and only ring/complete-graph rewire targets, so reject
    unsupported schedules *before* the run starts instead of failing
    mid-schedule when the event fires (DESIGN.md §7).

    On the 2-D federation mesh (``model_parallel > 1``) rewires are
    rejected too: a mid-run graph change would re-specialize every
    model-axis collective in the compiled step, which the 2-D driver
    does not support yet — run such schedules on the 1-D node mesh
    (``--model-parallel 1``) or node-stacked (DESIGN.md §10).
    """
    from repro.core.mixing import shard_supported_topology
    for seg in schedule.segments:
        for ev in seg.events:
            if isinstance(ev, ChurnEvent):
                raise ValueError(
                    f"schedule has churn at step {ev.step}; churn "
                    "(freeze/isolate availability masks) is unsupported "
                    "under driver_mode='shard' — run it node-stacked "
                    "with driver_mode='scan' or 'host' (DESIGN.md §7)")
            if isinstance(ev, FaultEvent) and ev.kind in ("drop", "corrupt"):
                raise ValueError(
                    f"schedule injects a wire fault ({ev.kind}) at step "
                    f"{ev.step}; wire-fault injection needs the "
                    "node-stacked validated mixers — run fault schedules "
                    "with driver_mode='scan' or 'host' (DESIGN.md §12). "
                    "Crash faults are fine under shard.")
            if isinstance(ev, RewireEvent):
                if model_parallel > 1:
                    raise ValueError(
                        f"rewire at step {ev.step} is unsupported on the "
                        "2-D (node, model) federation mesh — run this "
                        "schedule with --model-parallel 1 (the 1-D node "
                        "mesh) or driver_mode='scan' (DESIGN.md §10)")
                topo = _resolve_topology(ev, num_nodes)
                if not shard_supported_topology(topo):
                    raise ValueError(
                        f"rewire at step {ev.step} targets "
                        f"{topo.name!r}; the shard driver gossips on "
                        "ring/complete graphs only — use "
                        "driver_mode='scan' or 'host' for this schedule")


def _resolve_topology(ev: RewireEvent, n: int) -> Topology:
    topo = ev.topology
    if isinstance(topo, str):
        topo = Topology.make(topo, n)
    if topo.n != n:
        raise ValueError(f"rewire topology has {topo.n} nodes, run has {n}")
    return topo


def run_schedule(schedule: Schedule, hooks: FederationHooks, params,
                 opt_state, key, *, topology: Topology,
                 ledger: Optional[CommLedger] = None,
                 param_count: int = 0, elem_bytes: int = 4,
                 payload_elems: Optional[int] = None, index_bytes: int = 0,
                 resume_step: int = 0, capture_at: Optional[int] = None,
                 telemetry=None,
                 resil=None) -> Tuple[Any, Any, Any, Optional[Dict]]:
    """Drive the full schedule. Returns ``(params, opt_state, key,
    captured)`` where ``captured`` is the ``{"params", "opt_state",
    "key", "step"}`` snapshot taken at the ``capture_at`` boundary
    (None when not requested; plus ``"comm"`` on stateful-gossip runs).

    ``resume_step`` must satisfy ``schedule.validate_resume``; segments
    ending at or before it are skipped (topology events still replay so
    the graph state is correct when training picks back up). On a
    stateful-gossip resume the comm pytree is re-initialized from the
    restored params (zero residuals, fresh payloads) — the error-feedback
    state is not part of checkpoints.

    ``payload_elems`` / ``index_bytes`` are the ledger's compressed-wire
    accounting (``mixing.payload_elem_count`` per-node elements and the
    4-byte int32 index rider of top-k/random-k sends); left at their
    defaults the gossip charge is the dense ``param_count · elem_bytes``.

    ``telemetry`` (a :class:`repro.obs.Telemetry`, default None = fully
    off) turns on the three observability layers: every schedule/segment/
    topology/round/comm/eval occurrence becomes one JSONL event, the
    metrics-bus pytree from ``hooks.init_metrics`` rides every runner
    call and is flushed (then zeroed) at each segment boundary, and trace
    spans wrap the label rounds, runner segments (tagged ``compile`` when
    the call built a fresh runner), and evals.

    ``resil`` (a :class:`repro.resil.Resilience`, default None = fully
    off) turns on the resilience layer (DESIGN.md §12):

    * ``resil.guard`` threads the on-device health-guard counters from
      ``hooks.init_guard`` through every runner call; at each segment
      boundary the counters are summarized (one host sync, like the
      metrics bus), and any node that tripped an own-health check — or
      was attributed invalid wire payloads — is **quarantined**: its
      params freeze (identity mixing rows via the frozen-step
      machinery), the ledger charges it ``STATUS_QUARANTINED``, and a
      ``health`` run-log event records the trip;
    * ``resil.snapshot_dir`` writes a durable versioned+checksummed
      snapshot (params, opt state, PRNG key, comm pytree, homogenization
      ctx, phase) at segment boundaries every ``snapshot_every`` steps;
      when the directory already holds snapshots and ``resume_step`` is
      0, the run **auto-resumes** from the newest valid one (corrupt or
      half-written snapshots are skipped with a warning);
    * ``resil.rollback`` upgrades a guard trip to restore-and-retry: the
      segment's state updates are discarded, the offending nodes are
      quarantined, and the segment re-runs from the pre-segment state
      with the same PRNG key — at most ``max_retries`` times — so a
      poisoned mix never lands in the accepted trajectory.

    ``FaultEvent``s in the schedule drive deterministic fault injection:
    ``drop``/``corrupt`` update the wire-fault state the hooks' mixers
    are rebuilt with (per-segment-static, so injection never puts a
    step-dependent branch inside jit), and ``crash`` raises
    :class:`repro.resil.SimulatedCrash` — re-running with the same
    snapshot dir resumes from the last durable snapshot.
    """
    from contextlib import nullcontext

    from repro.obs import log
    from repro.sched.ledger import (STATUS_ACTIVE, STATUS_INACTIVE,
                                    STATUS_QUARANTINED, STATUS_STALE)

    # the hooks object is the source of truth mid-run (steps/runners key
    # their caches on hooks._metrics_on()); an explicit telemetry= arg
    # rebinds it, otherwise a fed.telemetry set by the caller survives
    tel = telemetry if telemetry is not None \
        else getattr(hooks, "telemetry", None)
    hooks.telemetry = tel

    def _ev(_event_kind, **fields):
        if tel is not None:
            tel.event(_event_kind, **fields)

    def _span(name, **args):
        return tel.span(name, **args) if tel is not None else nullcontext()

    # like telemetry, the hooks object is the mid-run source of truth
    # for resilience (step/runner caches key on hooks._guard_spec() and
    # the mixers on hooks.wire_fault)
    res = resil if resil is not None else getattr(hooks, "resil", None)
    hooks.resil = res
    hooks.wire_fault = None       # faults come only from FaultEvents below
    n = topology.n
    active = np.ones(n, bool)
    frozen = np.zeros(n, bool)    # down nodes with freeze (vs isolate) mode
    stale = np.zeros(n, bool)     # active stragglers with frozen payloads
    quarantined = np.zeros(n, bool)   # guard-tripped nodes held out by the
    #                                   resilience layer (frozen + silent)
    fired = 0                 # homogenization rounds fired so far
    with _span("init_comm", cat="init"):
        comm = hooks.init_comm(params, topology, schedule)
    metrics = hooks.init_metrics(params, topology)
    guard_state = hooks.init_guard(params, topology)

    mgr = None
    resumed_with_ctx = False
    if res is not None and getattr(res, "snapshots_on", False):
        from repro.resil.snapshot import SnapshotManager
        mgr = SnapshotManager(res.snapshot_dir, every=res.snapshot_every,
                              keep=res.keep)
        if resume_step == 0 and mgr.steps():
            like = {"params": params, "opt_state": opt_state, "key": key}
            if comm is not None:
                like["comm"] = comm
            loaded = mgr.load_latest(like)
            if loaded is not None and loaded["step"] > 0:
                schedule.validate_resume(
                    loaded["step"], with_ctx=loaded["ctx"] is not None)
                state = loaded["state"]
                params, opt_state, key = (state["params"],
                                          state["opt_state"], state["key"])
                if comm is not None:
                    comm = state["comm"]
                if loaded["ctx"] is not None:
                    hooks.restore_ctx(loaded["ctx"], loaded["phase"])
                    resumed_with_ctx = True
                resume_step = loaded["step"]
                log.info("snapshot_resume", step=resume_step,
                         phase=loaded["phase"], fired=loaded["fired"])
                _ev("resume", step=resume_step, phase=loaded["phase"],
                    fired=loaded["fired"])

    schedule.validate_resume(resume_step, with_ctx=resumed_with_ctx)
    if capture_at is not None:
        if capture_at != 0 and \
                capture_at not in {s.stop for s in schedule.segments}:
            raise ValueError(f"capture_at={capture_at} is not a segment "
                             "boundary of this schedule")
        if capture_at <= resume_step and not (capture_at == resume_step == 0):
            raise ValueError(
                f"capture_at={capture_at} lies in the span skipped by "
                f"resume_step={resume_step}; nothing would be captured")
    captured: Optional[Dict] = None
    _ev("schedule", segments=len(schedule.segments),
        steps=schedule.segments[-1].stop if schedule.segments else 0,
        rounds=schedule.num_rounds, gossip=schedule.gossip,
        nodes=n, topology=topology.name, resume_step=resume_step)
    # the wire-fault mask state FaultEvents fold into (drop stays until
    # cleared; corrupt mode is the last one injected)
    drop_nodes: set = set()
    corrupt_nodes: set = set()
    corrupt_mode = "nan"

    def _snapshot(step):
        snap = {"params": params, "opt_state": opt_state, "key": key,
                "step": step}
        if comm is not None:
            snap["comm"] = comm
        return snap

    if capture_at == 0:
        captured = _snapshot(0)

    for seg_index, seg in enumerate(schedule.segments):
        skipped = seg.stop <= resume_step
        for ev in seg.events:
            if isinstance(ev, ChurnEvent):
                active = active.copy()
                frozen = frozen.copy()
                stale = stale.copy()
                for i in (*ev.down, *ev.up):
                    if not 0 <= i < n:
                        raise ValueError(
                            f"churn event at step {ev.step} names node "
                            f"{i} outside [0, {n})")
                for i in ev.down:
                    if ev.mode == "stale":
                        # straggler-tolerant: the node stays active
                        # (trains, receives) — only its outgoing payload
                        # freezes at the last one it produced
                        stale[i] = True
                    else:
                        active[i] = False
                        frozen[i] = ev.mode == "freeze"
                        stale[i] = False
                for i in ev.up:
                    active[i] = True
                    frozen[i] = False
                    stale[i] = False
                if not active.any():
                    raise ValueError(f"churn at step {ev.step} leaves no "
                                     "active nodes")
                hooks.on_topology(topology, active, frozen, stale)
                _ev("topology", step=ev.step, change="churn", mode=ev.mode,
                    down=list(ev.down), up=list(ev.up), active=active,
                    frozen=frozen, stale=stale,
                    mixing_rows=topology.mixing_matrix(
                        None if active.all() else active))
            elif isinstance(ev, RewireEvent):
                topology = _resolve_topology(ev, n)
                hooks.on_topology(topology, active, frozen, stale)
                _ev("topology", step=ev.step, change="rewire",
                    graph=topology.name, active=active, frozen=frozen,
                    stale=stale,
                    mixing_rows=topology.mixing_matrix(
                        None if active.all() else active))
            elif isinstance(ev, FaultEvent):
                for i in ev.nodes:
                    if not 0 <= i < n:
                        raise ValueError(
                            f"fault event at step {ev.step} names node "
                            f"{i} outside [0, {n})")
                if ev.kind == "crash":
                    if ev.step > resume_step and (
                            mgr is None or not mgr.crash_seen(ev.step)):
                        # abrupt process death: no snapshot is written
                        # here — recovery rides the durable snapshot from
                        # the last boundary. The tombstone in the
                        # snapshot dir makes the crash fire exactly once
                        # across incarnations, so the resumed run passes
                        # through this step.
                        if mgr is not None:
                            mgr.mark_crash(ev.step)
                        _ev("fault", step=ev.step, kind="crash")
                        log.warning("fault_crash", step=ev.step)
                        raise SimulatedCrash(ev.step)
                    continue
                if ev.kind == "drop":
                    drop_nodes |= set(ev.nodes)
                elif ev.kind == "corrupt":
                    corrupt_nodes |= set(ev.nodes)
                    corrupt_mode = ev.mode
                elif ev.kind == "clear":
                    if ev.nodes:
                        drop_nodes -= set(ev.nodes)
                        corrupt_nodes -= set(ev.nodes)
                    else:
                        drop_nodes.clear()
                        corrupt_nodes.clear()
                hooks.wire_fault = (
                    WireFault(drop=tuple(sorted(drop_nodes)),
                              corrupt=tuple(sorted(corrupt_nodes)),
                              mode=corrupt_mode)
                    if (drop_nodes or corrupt_nodes) else None)
                _ev("fault", step=ev.step, kind=ev.kind,
                    nodes=list(ev.nodes), mode=ev.mode,
                    drop=sorted(drop_nodes), corrupt=sorted(corrupt_nodes))
                if not skipped:
                    log.warning("fault_injected", step=ev.step,
                                kind=ev.kind, nodes=list(ev.nodes))
            elif isinstance(ev, HomogenizeEvent):
                if skipped:
                    fired += 1      # round happened before the checkpoint
                    continue
                with _span("label_round", cat="round", step=ev.step,
                           round=fired):
                    label_bytes = hooks.on_round(params, fired, ev.step,
                                                 topology, active)
                stats = getattr(hooks, "last_round_stats", None)
                hooks.on_labels(fired, ev.step, stats)
                _ev("round", round=fired, step=ev.step)
                if stats:
                    _ev("labels", round=fired, step=ev.step, **stats)
                fired += 1
                if ledger is not None and label_bytes is not None:
                    per_node = np.asarray(label_bytes)
                    ledger.log_labels(fired, ev.step, per_node)
                    _ev("comm", kind="labels", round=fired, step=ev.step,
                        per_node=per_node)
        if skipped:
            continue

        hooks.on_segment(seg, seg_index)
        _ev("segment", index=seg_index, start=seg.start, stop=seg.stop,
            steps=seg.num_steps, round=fired, eval_after=seg.eval_after,
            phase=getattr(hooks, "phase", None))
        retries = 0
        while True:
            # quarantined nodes behave like freeze-churned ones: params
            # held, identity mixing rows, no traffic — but tracked in a
            # separate mask so the ledger can attribute them distinctly
            eff_active = active & ~quarantined
            eff_frozen = frozen | quarantined
            if not eff_active.any():
                raise RuntimeError(
                    f"segment [{seg.start}, {seg.stop}) has no active "
                    "nodes left after churn + quarantine")
            runner_cache = getattr(hooks, "_runners", None)
            cached_runners = (len(runner_cache)
                              if runner_cache is not None else 0)
            runner = hooks.runner(topology, eff_active, eff_frozen, stale)
            new_runner = (runner_cache is not None
                          and len(runner_cache) > cached_runners)
            run_kwargs = {}
            if getattr(runner, "comm", False):
                run_kwargs["comm"] = comm
            if getattr(runner, "metrics", False):
                run_kwargs["metrics"] = metrics
            if getattr(runner, "guard", False):
                run_kwargs["guard"] = guard_state
            with _span("segment", cat="train", start=seg.start,
                       stop=seg.stop, round=fired, compile=new_runner):
                out = runner(params, opt_state, key,
                             jnp.asarray(seg.start, jnp.int32),
                             seg.num_steps, **run_kwargs)
            new_params, new_opt, new_key, losses = out[:4]
            rest = list(out[4:])
            new_comm = rest.pop(0) if "comm" in run_kwargs else comm
            new_metrics = (rest.pop(0) if "metrics" in run_kwargs
                           else metrics)
            new_guard = rest.pop(0) if "guard" in run_kwargs else None

            to_q = np.zeros(n, bool)
            if new_guard is not None:
                # one host sync per segment, mirroring the metrics bus
                from repro.resil import guards
                summary = guards.summarize(new_guard)
                tripped = (np.asarray(guards.tripped_nodes(summary))
                           & ~quarantined)
                offenders = (np.asarray(guards.wire_offenders(summary))
                             & ~quarantined)
                if tripped.any() or offenders.any():
                    # wire attribution wins when present: the offender is
                    # the sender of invalid payloads, tripped receivers
                    # are its victims
                    to_q = offenders if offenders.any() else tripped
                    log.warning(
                        "guard_tripped", step=seg.stop,
                        tripped=np.flatnonzero(tripped).tolist(),
                        offenders=np.flatnonzero(offenders).tolist())
                new_guard = guards.reset(new_guard)

            if to_q.any() and not (eff_active & ~to_q).any():
                log.warning("quarantine_refused", step=seg.stop,
                            nodes=np.flatnonzero(to_q).tolist(),
                            reason="would leave no active nodes")
                _ev("health", step=seg.stop, action="refused",
                    tripped=to_q)
                to_q = np.zeros(n, bool)
            if to_q.any():
                quarantined = quarantined | to_q
                _ev("health", step=seg.stop, action="quarantine",
                    tripped=tripped, offenders=offenders,
                    quarantined=quarantined, retry=retries,
                    counters={k: summary[k]
                              for k in guards.GUARD_COUNTERS})
                log.warning("quarantine", step=seg.stop,
                            nodes=np.flatnonzero(to_q).tolist())
                if (res is not None and res.rollback
                        and retries < res.max_retries):
                    # divergence rollback: discard this segment's state
                    # (params/opt/key/comm were never overwritten) and
                    # re-run it — same PRNG key — with the offenders
                    # quarantined, so the poisoned mix never lands
                    retries += 1
                    guard_state = new_guard
                    _ev("rollback", step=seg.stop, retry=retries,
                        quarantined=quarantined)
                    log.warning(
                        "segment_rollback", start=seg.start,
                        stop=seg.stop, retry=retries,
                        quarantined=np.flatnonzero(quarantined).tolist())
                    continue
            params, opt_state, key = new_params, new_opt, new_key
            comm, metrics = new_comm, new_metrics
            if new_guard is not None:
                guard_state = new_guard
            break

        if ledger is not None and param_count:
            status = np.where(
                eff_frozen & ~frozen, STATUS_QUARANTINED,
                np.where(~active, STATUS_INACTIVE,
                         np.where(stale, STATUS_STALE,
                                  STATUS_ACTIVE))).astype(np.int8)
            per_step = gossip_bytes_per_step(
                topology, eff_active, param_count, elem_bytes,
                payload_elems=payload_elems, index_bytes=index_bytes,
                stale=stale if stale.any() else None)
            ledger.log_gossip(fired, seg.start, seg.stop, per_step,
                              status=status)
            _ev("comm", kind="gossip", round=fired, start=seg.start,
                stop=seg.stop, per_node=per_step * seg.num_steps,
                status=status)
        if "metrics" in run_kwargs and tel is not None \
                and metrics is not None:
            # flush + zero at the chunk boundary: the only host sync
            # telemetry adds, amortized over the whole segment
            tel.flush_metrics(seg.stop, metrics, round=fired,
                              active=eff_active, stale=stale)
            from repro.obs import metrics as obs_metrics
            metrics = obs_metrics.reset(metrics)
        if capture_at == seg.stop:
            captured = _snapshot(seg.stop)
        if mgr is not None and mgr.due(seg.stop):
            state = {"params": params, "opt_state": opt_state, "key": key}
            if comm is not None:
                state["comm"] = comm
            with _span("snapshot", cat="resil", step=seg.stop):
                mgr.save(seg.stop, state, ctx=getattr(hooks, "ctx", None),
                         phase=getattr(hooks, "phase", "plain"),
                         fired=fired)
            _ev("snapshot", step=seg.stop, fired=fired)
        if seg.eval_after:
            with _span("eval", cat="eval", step=seg.stop - 1):
                hooks.on_eval(params, seg.stop - 1, losses)
            mean_loss = (float(np.mean(np.asarray(losses)))
                         if getattr(losses, "size", 0) else None)
            _ev("eval", step=seg.stop - 1, mean_loss=mean_loss)
            if mean_loss is not None and not np.isfinite(mean_loss):
                log.warning("eval_nonfinite", step=seg.stop - 1,
                            mean_loss=mean_loss)
                _ev("health", step=seg.stop - 1, kind="eval_nonfinite",
                    mean_loss=mean_loss)

    _ev("run_end", rounds=fired)
    return params, opt_state, key, captured
