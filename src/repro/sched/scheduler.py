"""Event-driven federation scheduler — the one outer loop.

:func:`run_schedule` replays a compiled :class:`~repro.sched.schedule.
Schedule` against a set of driver *hooks*: the scheduler owns segment
iteration, event application (churn masks, graph rewires, homogenization
rounds), communication accounting, mid-run checkpoint capture, and
resume; the hooks own everything model-specific (how to build a runner
for the current phase/graph, how to run a labeling round, what to do at
an eval boundary). ``core.simulator.DecentralizedSimulator`` and
``launch.train.run_training`` both drive this loop — neither hand-rolls
the chunked scan/eval/homogenize structure anymore.

The federation state threaded through the loop:

* ``topology`` — the current gossip graph (swapped by ``RewireEvent``);
* ``active``  — the node availability mask (updated by ``ChurnEvent``);
* ``frozen``  — the subset of down nodes with ``freeze`` semantics
  (params and optimizer state held); down nodes *not* in it are
  ``isolate`` stragglers — they keep training locally but miss gossip.
  Each ChurnEvent's ``mode`` applies to its own ``down`` nodes, so
  frozen and isolated nodes coexist;
* ``stale``   — the straggler-tolerant mask (``mode="stale"`` churn):
  stale nodes stay *active* — they train and receive gossip — but
  their outgoing payload is frozen at the last one they produced, so
  neighbours mix a stale snapshot instead of waiting (DESIGN.md §9).
  The ledger charges stale senders zero bytes;
* ``comm``    — the stateful gossip mixers' comm pytree (error-feedback
  residuals + last wire payloads) when the schedule uses compression,
  delayed gossip, or stale churn: built once by ``hooks.init_comm`` and
  threaded through every runner call, like params;
* rounds fired so far — the ledger's round bucket index.

Resume replays topology events *before* the resume step (they are cheap
and parameter-free) but skips training and any homogenization round in
the skipped span — ``Schedule.validate_resume`` guarantees the first
executed segment re-fires a round when one is needed, so a checkpoint
taken at a round boundary rejoins the uninterrupted trajectory exactly
(same params → same labeling round → same sampler → same keys).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology
from repro.sched.ledger import CommLedger, gossip_bytes_per_step
from repro.sched.schedule import (ChurnEvent, HomogenizeEvent, RewireEvent,
                                  Schedule)


class FederationHooks:
    """Driver-specific callbacks for :func:`run_schedule` (subclass and
    override; the base class documents the protocol)."""

    def init_comm(self, params, topology: Topology,
                  schedule: Schedule) -> Optional[Any]:
        """Build the stateful gossip mixers' initial comm pytree for a
        schedule that needs one (compression, delayed gossip, or stale
        churn anywhere in the run — the comm structure must be constant
        across every segment, so statefulness is decided up front).
        Return None for plain synchronous gossip (the base default)."""
        return None

    def init_metrics(self, params, topology: Topology) -> Optional[Any]:
        """Build the on-device metrics-bus pytree (:mod:`repro.obs.
        metrics`) threaded through every runner call when telemetry is
        on. Return None to keep the metrics bus off (the base default)."""
        return None

    def on_topology(self, topology: Topology, active: np.ndarray,
                    frozen: np.ndarray, stale: np.ndarray) -> None:
        """The gossip graph, availability mask, or straggler mask
        changed; invalidate or re-key any mixer/step caches."""

    def on_segment(self, segment, index: int) -> None:
        """A schedule segment is about to run (telemetry hook; the base
        default does nothing)."""

    def on_round(self, params, round_index: int, step: int,
                 topology: Topology, active: np.ndarray
                 ) -> Optional[np.ndarray]:
        """Run one homogenization round from the current params; swap the
        KD sampler in. Returns (n,) per-node label payload bytes for the
        ledger (or None to skip label accounting)."""
        return None

    def on_labels(self, round_index: int, step: int,
                  stats: Optional[Dict]) -> None:
        """Label-round statistics are available (telemetry hook).
        ``stats`` is whatever the ``on_round`` implementation stashed in
        ``self.last_round_stats`` — detector thresholds, per-node
        selected counts, neighbour top-k overlap — or None when the
        round produced none. The base default does nothing."""

    def runner(self, topology: Topology, active: np.ndarray,
               frozen: np.ndarray, stale: np.ndarray) -> Callable:
        """A ``run(params, opt_state, key, step0, num_steps)`` runner for
        the current phase, graph, availability mask, frozen subset, and
        straggler (stale) mask. A runner flagged ``run.comm`` takes and
        returns the gossip comm pytree: ``run(..., comm=comm) -> (params,
        opt_state, key, losses, comm)``; one flagged ``run.metrics``
        takes and returns the metrics pytree the same way (trailing,
        after comm when both are present)."""
        raise NotImplementedError

    def on_eval(self, params, step: int, losses) -> None:
        """An eval boundary was crossed after ``step``."""


class CompiledFederationHooks(FederationHooks):
    """:class:`FederationHooks` plus the compiled-object caching both
    drivers need: mixers, steps, and runners keyed by (phase, graph,
    availability mask, freeze mask), so alternating churn masks and
    repeated graphs reuse their jitted executables, and the
    round-varying sampler payload rides in ``self.ctx`` (threaded
    through the runner for every non-plain phase — a traced argument,
    so refreshing it costs no recompile).

    ``driver_mode="shard"`` routes step building through
    ``driver.make_shard_step`` — the node axis lives on a
    ``launch.mesh.make_node_mesh`` mesh and gossip runs inside
    ``shard_map`` via the ppermute backend. Shard mode has no churn
    path: availability masks raise here (and
    :func:`validate_shard_schedule` rejects such schedules before the
    run starts), topology swaps are fine as long as the target is a
    ring/complete graph.

    Subclasses set ``model``, ``algo``, ``lr_fn``, ``driver_mode`` —
    plus ``compression`` / ``gossip`` for the compressed-wire path —
    and the phase state (``phase`` starts "plain"; ``on_round``
    overrides advance it and refresh ``ctx``), and implement:

    * ``_make_mixer(topology, active, stale=None)`` — backend /
      wire-dtype choice (``active`` is None for the all-up mask,
      ``stale`` None for no stragglers); forwards ``_mixer_opts()`` to
      ``mixing.make_mixer`` so compression / gossip / forced
      statefulness reach every mixer it builds;
    * ``_adapter()`` — the loss adapter for the current phase;
    * ``_sampler()`` — the sampler for the current phase.

    Graphs are keyed by ``Topology.edge_key()`` (the canonical edge set),
    not by name, so a rewire back to an equivalent graph — or a schedule
    replay that re-resolves its events — hits the warm cache.
    """

    model = None
    algo = None
    lr_fn = None
    driver_mode = "scan"
    model_parallel = 1        # shard mode: width of the mesh "model" axis
    compression = None        # None | "topk:frac" | "randk:frac" | (kind, f)
    gossip = "sync"           # overwritten from the schedule by init_comm

    def __init__(self):
        self.phase = "plain"
        self.ctx = None
        self._mixers: Dict = {}
        self._steps: Dict = {}
        self._runners: Dict = {}
        self._node_mesh = None
        self._force_state = False
        # telemetry: a repro.obs.Telemetry (or None). Its metrics flag
        # turns the on-device metrics bus on, so the step/runner caches
        # key on it — the same graph compiles differently with the
        # metrics carry attached.
        self.telemetry = None
        # on_round implementations stash label-round statistics here for
        # run_schedule to hand to on_labels / the run log
        self.last_round_stats: Optional[Dict] = None

    def _metrics_on(self) -> bool:
        tel = self.telemetry
        return tel is not None and getattr(tel, "metrics_enabled", False)

    def init_metrics(self, params, topology: Topology) -> Optional[Any]:
        if not self._metrics_on():
            return None
        from repro.obs import metrics as obs_metrics
        return obs_metrics.init_node_metrics(topology.n)

    def _make_mixer(self, topology: Topology, active,
                    stale=None) -> Callable:
        raise NotImplementedError

    def _adapter(self):
        raise NotImplementedError

    def _sampler(self):
        raise NotImplementedError

    def _mixer_opts(self) -> Dict:
        """kwargs a ``_make_mixer`` implementation forwards to
        ``mixing.make_mixer``: the run's compression spec, gossip mode,
        and — once ``init_comm`` saw a schedule that needs state
        anywhere — ``stateful=True``, so every mixer of the run carries
        the same comm structure (a scan carry cannot change pytree
        structure mid-schedule)."""
        return {"compression": self.compression, "gossip": self.gossip,
                "stateful": True if self._force_state else None}

    def init_comm(self, params, topology: Topology,
                  schedule: Schedule) -> Optional[Any]:
        from repro.core import mixing
        self.gossip = schedule.gossip
        self._force_state = bool(
            mixing.normalize_compression(self.compression) is not None
            or self.gossip == "delayed" or schedule.has_stale)
        if not self._force_state:
            return None
        n = topology.n
        step = self._step(topology, np.ones(n, bool), np.zeros(n, bool),
                          np.zeros(n, bool))
        comm = step.init_comm(params)
        if self.driver_mode == "shard":
            import jax

            from repro.launch.sharding import federation_shardings
            comm = jax.device_put(comm, federation_shardings(
                comm, self.shard_mesh(n), n))
        return comm

    # ------------------------------------------------------------- caches
    @staticmethod
    def _mask_key(active: np.ndarray):
        return None if active.all() else tuple(np.flatnonzero(~active))

    @staticmethod
    def _freeze_key(frozen: np.ndarray):
        return tuple(np.flatnonzero(frozen)) if frozen.any() else None

    @staticmethod
    def _stale_key(stale: np.ndarray):
        return tuple(np.flatnonzero(stale)) if stale.any() else None

    def _mixer(self, topo: Topology, active: np.ndarray, stale=None):
        mask = self._mask_key(active)
        sk = (self._stale_key(stale) if stale is not None else None)
        key = (topo.edge_key(), mask, sk)
        if key not in self._mixers:
            if mask is None and sk is None:
                self._mixers[key] = self._make_mixer(topo, None)
            else:
                # churn path: remake the cached all-up mixer for the new
                # availability / straggler masks (same backend/wire
                # choice); mixers without a remake handle are rebuilt
                base = self._mixer(topo, np.ones_like(active))
                remake = getattr(base, "remake", None)
                self._mixers[key] = (
                    remake(active=(active if mask is not None else None),
                           stale=stale)
                    if remake is not None
                    else self._make_mixer(topo, active, stale))
        return self._mixers[key]

    def shard_mesh(self, num_nodes: int):
        """The (cached) federation mesh shard-mode steps run on — 1-D
        node mesh at ``model_parallel == 1``, 2-D ``("node", "model")``
        otherwise."""
        if self._node_mesh is None:
            from repro.launch.mesh import make_federation_mesh
            self._node_mesh = make_federation_mesh(num_nodes,
                                                   self.model_parallel)
        return self._node_mesh

    def _base_step(self, topo: Topology, active: np.ndarray,
                   stale: np.ndarray):
        from repro.core import driver
        if self.driver_mode == "shard":
            if not active.all():
                raise ValueError(
                    "shard driver cannot apply churn availability masks "
                    "(freeze/isolate need the node-stacked gather/dense "
                    "mixers — DESIGN.md §7); run churn schedules with "
                    "driver_mode='scan' or 'host'")
            if stale.any():
                raise ValueError(
                    "shard driver cannot apply straggler (stale) masks — "
                    "run stale-churn schedules with driver_mode='scan' "
                    "or 'host' (DESIGN.md §9)")
            return driver.make_shard_step(
                self.model, self.algo, self._adapter(),
                mesh=self.shard_mesh(topo.n), topology=topo,
                compression=self.compression, gossip=self.gossip,
                telemetry=self._metrics_on())
        return driver.make_step(
            self.model, self.algo,
            self._mixer(topo, active, stale if stale.any() else None),
            self._adapter(), telemetry=self._metrics_on())

    def _step(self, topo: Topology, active: np.ndarray,
              frozen: np.ndarray, stale: np.ndarray):
        from repro.core import driver
        key = (self.phase, topo.edge_key(), self._mask_key(active),
               self._freeze_key(frozen), self._stale_key(stale),
               self._metrics_on())
        if key not in self._steps:
            step = self._base_step(topo, active, stale)
            if self._freeze_key(frozen) is not None:
                # hold exactly the frozen subset; isolate stragglers
                # (down but unfrozen) keep taking local steps
                step = driver.make_frozen_step(step, ~frozen)
            self._steps[key] = step
        return self._steps[key]

    def runner(self, topo: Topology, active: np.ndarray,
               frozen: np.ndarray, stale: np.ndarray) -> Callable:
        from repro.core import driver
        key = (self.phase, topo.edge_key(), self._mask_key(active),
               self._freeze_key(frozen), self._stale_key(stale),
               self._metrics_on())
        if key not in self._runners:
            self._runners[key] = driver.make_runner(
                self._step(topo, active, frozen, stale), self._sampler(),
                self.lr_fn, self.driver_mode)
        run = self._runners[key]
        has_comm = getattr(run, "comm", False)
        has_metrics = getattr(run, "metrics", False)
        if has_comm or has_metrics:
            ctx = None if self.phase == "plain" else self.ctx

            def aug_run(p, o, k, s0, ns, comm=None, metrics=None,
                        _run=run, _ctx=ctx):
                return _run(p, o, k, s0, ns, _ctx, comm, metrics)

            aug_run.comm = has_comm
            aug_run.metrics = has_metrics
            return aug_run
        if self.phase == "plain":
            return run
        return lambda p, o, k, s0, ns: run(p, o, k, s0, ns, self.ctx)


def validate_shard_schedule(schedule: Schedule, num_nodes: int,
                            model_parallel: int = 1) -> None:
    """Pre-flight for ``driver_mode="shard"``: shard_map gossip has no
    churn path and only ring/complete-graph rewire targets, so reject
    unsupported schedules *before* the run starts instead of failing
    mid-schedule when the event fires (DESIGN.md §7).

    On the 2-D federation mesh (``model_parallel > 1``) rewires are
    rejected too: a mid-run graph change would re-specialize every
    model-axis collective in the compiled step, which the 2-D driver
    does not support yet — run such schedules on the 1-D node mesh
    (``--model-parallel 1``) or node-stacked (DESIGN.md §10).
    """
    from repro.core.mixing import shard_supported_topology
    for seg in schedule.segments:
        for ev in seg.events:
            if isinstance(ev, ChurnEvent):
                raise ValueError(
                    f"schedule has churn at step {ev.step}; churn "
                    "(freeze/isolate availability masks) is unsupported "
                    "under driver_mode='shard' — run it node-stacked "
                    "with driver_mode='scan' or 'host' (DESIGN.md §7)")
            if isinstance(ev, RewireEvent):
                if model_parallel > 1:
                    raise ValueError(
                        f"rewire at step {ev.step} is unsupported on the "
                        "2-D (node, model) federation mesh — run this "
                        "schedule with --model-parallel 1 (the 1-D node "
                        "mesh) or driver_mode='scan' (DESIGN.md §10)")
                topo = _resolve_topology(ev, num_nodes)
                if not shard_supported_topology(topo):
                    raise ValueError(
                        f"rewire at step {ev.step} targets "
                        f"{topo.name!r}; the shard driver gossips on "
                        "ring/complete graphs only — use "
                        "driver_mode='scan' or 'host' for this schedule")


def _resolve_topology(ev: RewireEvent, n: int) -> Topology:
    topo = ev.topology
    if isinstance(topo, str):
        topo = Topology.make(topo, n)
    if topo.n != n:
        raise ValueError(f"rewire topology has {topo.n} nodes, run has {n}")
    return topo


def run_schedule(schedule: Schedule, hooks: FederationHooks, params,
                 opt_state, key, *, topology: Topology,
                 ledger: Optional[CommLedger] = None,
                 param_count: int = 0, elem_bytes: int = 4,
                 payload_elems: Optional[int] = None, index_bytes: int = 0,
                 resume_step: int = 0, capture_at: Optional[int] = None,
                 telemetry=None) -> Tuple[Any, Any, Any, Optional[Dict]]:
    """Drive the full schedule. Returns ``(params, opt_state, key,
    captured)`` where ``captured`` is the ``{"params", "opt_state",
    "key", "step"}`` snapshot taken at the ``capture_at`` boundary
    (None when not requested; plus ``"comm"`` on stateful-gossip runs).

    ``resume_step`` must satisfy ``schedule.validate_resume``; segments
    ending at or before it are skipped (topology events still replay so
    the graph state is correct when training picks back up). On a
    stateful-gossip resume the comm pytree is re-initialized from the
    restored params (zero residuals, fresh payloads) — the error-feedback
    state is not part of checkpoints.

    ``payload_elems`` / ``index_bytes`` are the ledger's compressed-wire
    accounting (``mixing.payload_elem_count`` per-node elements and the
    4-byte int32 index rider of top-k/random-k sends); left at their
    defaults the gossip charge is the dense ``param_count · elem_bytes``.

    ``telemetry`` (a :class:`repro.obs.Telemetry`, default None = fully
    off) turns on the three observability layers: every schedule/segment/
    topology/round/comm/eval occurrence becomes one JSONL event, the
    metrics-bus pytree from ``hooks.init_metrics`` rides every runner
    call and is flushed (then zeroed) at each segment boundary, and trace
    spans wrap the label rounds, runner segments (tagged ``compile`` when
    the call built a fresh runner), and evals.
    """
    from contextlib import nullcontext

    from repro.sched.ledger import (STATUS_ACTIVE, STATUS_INACTIVE,
                                    STATUS_STALE)

    # the hooks object is the source of truth mid-run (steps/runners key
    # their caches on hooks._metrics_on()); an explicit telemetry= arg
    # rebinds it, otherwise a fed.telemetry set by the caller survives
    tel = telemetry if telemetry is not None \
        else getattr(hooks, "telemetry", None)
    hooks.telemetry = tel

    def _ev(_event_kind, **fields):
        if tel is not None:
            tel.event(_event_kind, **fields)

    def _span(name, **args):
        return tel.span(name, **args) if tel is not None else nullcontext()

    n = topology.n
    schedule.validate_resume(resume_step)
    if capture_at is not None:
        if capture_at != 0 and \
                capture_at not in {s.stop for s in schedule.segments}:
            raise ValueError(f"capture_at={capture_at} is not a segment "
                             "boundary of this schedule")
        if capture_at <= resume_step and not (capture_at == resume_step == 0):
            raise ValueError(
                f"capture_at={capture_at} lies in the span skipped by "
                f"resume_step={resume_step}; nothing would be captured")
    active = np.ones(n, bool)
    frozen = np.zeros(n, bool)    # down nodes with freeze (vs isolate) mode
    stale = np.zeros(n, bool)     # active stragglers with frozen payloads
    fired = 0                 # homogenization rounds fired so far
    with _span("init_comm", cat="init"):
        comm = hooks.init_comm(params, topology, schedule)
    metrics = hooks.init_metrics(params, topology)
    captured: Optional[Dict] = None
    _ev("schedule", segments=len(schedule.segments),
        steps=schedule.segments[-1].stop if schedule.segments else 0,
        rounds=schedule.num_rounds, gossip=schedule.gossip,
        nodes=n, topology=topology.name, resume_step=resume_step)

    def _snapshot(step):
        snap = {"params": params, "opt_state": opt_state, "key": key,
                "step": step}
        if comm is not None:
            snap["comm"] = comm
        return snap

    if capture_at == 0:
        captured = _snapshot(0)

    for seg_index, seg in enumerate(schedule.segments):
        skipped = seg.stop <= resume_step
        for ev in seg.events:
            if isinstance(ev, ChurnEvent):
                active = active.copy()
                frozen = frozen.copy()
                stale = stale.copy()
                for i in (*ev.down, *ev.up):
                    if not 0 <= i < n:
                        raise ValueError(
                            f"churn event at step {ev.step} names node "
                            f"{i} outside [0, {n})")
                for i in ev.down:
                    if ev.mode == "stale":
                        # straggler-tolerant: the node stays active
                        # (trains, receives) — only its outgoing payload
                        # freezes at the last one it produced
                        stale[i] = True
                    else:
                        active[i] = False
                        frozen[i] = ev.mode == "freeze"
                        stale[i] = False
                for i in ev.up:
                    active[i] = True
                    frozen[i] = False
                    stale[i] = False
                if not active.any():
                    raise ValueError(f"churn at step {ev.step} leaves no "
                                     "active nodes")
                hooks.on_topology(topology, active, frozen, stale)
                _ev("topology", step=ev.step, change="churn", mode=ev.mode,
                    down=list(ev.down), up=list(ev.up), active=active,
                    frozen=frozen, stale=stale,
                    mixing_rows=topology.mixing_matrix(
                        None if active.all() else active))
            elif isinstance(ev, RewireEvent):
                topology = _resolve_topology(ev, n)
                hooks.on_topology(topology, active, frozen, stale)
                _ev("topology", step=ev.step, change="rewire",
                    graph=topology.name, active=active, frozen=frozen,
                    stale=stale,
                    mixing_rows=topology.mixing_matrix(
                        None if active.all() else active))
            elif isinstance(ev, HomogenizeEvent):
                if skipped:
                    fired += 1      # round happened before the checkpoint
                    continue
                with _span("label_round", cat="round", step=ev.step,
                           round=fired):
                    label_bytes = hooks.on_round(params, fired, ev.step,
                                                 topology, active)
                stats = getattr(hooks, "last_round_stats", None)
                hooks.on_labels(fired, ev.step, stats)
                _ev("round", round=fired, step=ev.step)
                if stats:
                    _ev("labels", round=fired, step=ev.step, **stats)
                fired += 1
                if ledger is not None and label_bytes is not None:
                    per_node = np.asarray(label_bytes)
                    ledger.log_labels(fired, ev.step, per_node)
                    _ev("comm", kind="labels", round=fired, step=ev.step,
                        per_node=per_node)
        if skipped:
            continue

        hooks.on_segment(seg, seg_index)
        _ev("segment", index=seg_index, start=seg.start, stop=seg.stop,
            steps=seg.num_steps, round=fired, eval_after=seg.eval_after,
            phase=getattr(hooks, "phase", None))
        runner_cache = getattr(hooks, "_runners", None)
        cached_runners = len(runner_cache) if runner_cache is not None else 0
        runner = hooks.runner(topology, active, frozen, stale)
        new_runner = (runner_cache is not None
                      and len(runner_cache) > cached_runners)
        if ledger is not None and param_count:
            status = np.where(
                ~active, STATUS_INACTIVE,
                np.where(stale, STATUS_STALE, STATUS_ACTIVE)).astype(np.int8)
            per_step = gossip_bytes_per_step(
                topology, active, param_count, elem_bytes,
                payload_elems=payload_elems, index_bytes=index_bytes,
                stale=stale if stale.any() else None)
            ledger.log_gossip(fired, seg.start, seg.stop, per_step,
                              status=status)
            _ev("comm", kind="gossip", round=fired, start=seg.start,
                stop=seg.stop, per_node=per_step * seg.num_steps,
                status=status)
        run_kwargs = {}
        if getattr(runner, "comm", False):
            run_kwargs["comm"] = comm
        if getattr(runner, "metrics", False):
            run_kwargs["metrics"] = metrics
        with _span("segment", cat="train", start=seg.start, stop=seg.stop,
                   round=fired, compile=new_runner):
            out = runner(params, opt_state, key,
                         jnp.asarray(seg.start, jnp.int32), seg.num_steps,
                         **run_kwargs)
        params, opt_state, key, losses = out[:4]
        rest = list(out[4:])
        if "comm" in run_kwargs:
            comm = rest.pop(0)
        if "metrics" in run_kwargs:
            metrics = rest.pop(0)
            if tel is not None and metrics is not None:
                # flush + zero at the chunk boundary: the only host sync
                # telemetry adds, amortized over the whole segment
                tel.flush_metrics(seg.stop, metrics, round=fired,
                                  active=active, stale=stale)
                from repro.obs import metrics as obs_metrics
                metrics = obs_metrics.reset(metrics)
        if capture_at == seg.stop:
            captured = _snapshot(seg.stop)
        if seg.eval_after:
            with _span("eval", cat="eval", step=seg.stop - 1):
                hooks.on_eval(params, seg.stop - 1, losses)
            _ev("eval", step=seg.stop - 1,
                mean_loss=(float(np.mean(np.asarray(losses)))
                           if getattr(losses, "size", 0) else None))

    _ev("run_end", rounds=fired)
    return params, opt_state, key, captured
