"""Event-driven federation scheduler — the one outer loop.

:func:`run_schedule` replays a compiled :class:`~repro.sched.schedule.
Schedule` against a set of driver *hooks*: the scheduler owns segment
iteration, event application (churn masks, graph rewires, homogenization
rounds), communication accounting, mid-run checkpoint capture, and
resume; the hooks own everything model-specific (how to build a runner
for the current phase/graph, how to run a labeling round, what to do at
an eval boundary). ``core.simulator.DecentralizedSimulator`` and
``launch.train.run_training`` both drive this loop — neither hand-rolls
the chunked scan/eval/homogenize structure anymore.

The federation state threaded through the loop:

* ``topology`` — the current gossip graph (swapped by ``RewireEvent``);
* ``active``  — the node availability mask (updated by ``ChurnEvent``);
* ``frozen``  — the subset of down nodes with ``freeze`` semantics
  (params and optimizer state held); down nodes *not* in it are
  ``isolate`` stragglers — they keep training locally but miss gossip.
  Each ChurnEvent's ``mode`` applies to its own ``down`` nodes, so
  frozen and isolated nodes coexist;
* rounds fired so far — the ledger's round bucket index.

Resume replays topology events *before* the resume step (they are cheap
and parameter-free) but skips training and any homogenization round in
the skipped span — ``Schedule.validate_resume`` guarantees the first
executed segment re-fires a round when one is needed, so a checkpoint
taken at a round boundary rejoins the uninterrupted trajectory exactly
(same params → same labeling round → same sampler → same keys).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology
from repro.sched.ledger import CommLedger, gossip_bytes_per_step
from repro.sched.schedule import (ChurnEvent, HomogenizeEvent, RewireEvent,
                                  Schedule)


class FederationHooks:
    """Driver-specific callbacks for :func:`run_schedule` (subclass and
    override; the base class documents the protocol)."""

    def on_topology(self, topology: Topology, active: np.ndarray,
                    frozen: np.ndarray) -> None:
        """The gossip graph or availability mask changed; invalidate or
        re-key any mixer/step caches."""

    def on_round(self, params, round_index: int, step: int,
                 topology: Topology, active: np.ndarray
                 ) -> Optional[np.ndarray]:
        """Run one homogenization round from the current params; swap the
        KD sampler in. Returns (n,) per-node label payload bytes for the
        ledger (or None to skip label accounting)."""
        return None

    def runner(self, topology: Topology, active: np.ndarray,
               frozen: np.ndarray) -> Callable:
        """A ``run(params, opt_state, key, step0, num_steps)`` runner for
        the current phase, graph, availability mask, and frozen subset."""
        raise NotImplementedError

    def on_eval(self, params, step: int, losses) -> None:
        """An eval boundary was crossed after ``step``."""


class CompiledFederationHooks(FederationHooks):
    """:class:`FederationHooks` plus the compiled-object caching both
    drivers need: mixers, steps, and runners keyed by (phase, graph,
    availability mask, freeze mask), so alternating churn masks and
    repeated graphs reuse their jitted executables, and the
    round-varying sampler payload rides in ``self.ctx`` (threaded
    through the runner for every non-plain phase — a traced argument,
    so refreshing it costs no recompile).

    ``driver_mode="shard"`` routes step building through
    ``driver.make_shard_step`` — the node axis lives on a
    ``launch.mesh.make_node_mesh`` mesh and gossip runs inside
    ``shard_map`` via the ppermute backend. Shard mode has no churn
    path: availability masks raise here (and
    :func:`validate_shard_schedule` rejects such schedules before the
    run starts), topology swaps are fine as long as the target is a
    ring/complete graph.

    Subclasses set ``model``, ``algo``, ``lr_fn``, ``driver_mode`` and
    the phase state (``phase`` starts "plain"; ``on_round`` overrides
    advance it and refresh ``ctx``), and implement:

    * ``_make_mixer(topology, active)`` — backend / wire-dtype choice
      (``active`` is None for the all-up mask);
    * ``_adapter()`` — the loss adapter for the current phase;
    * ``_sampler()`` — the sampler for the current phase.

    Graphs are keyed by ``Topology.edge_key()`` (the canonical edge set),
    not by name, so a rewire back to an equivalent graph — or a schedule
    replay that re-resolves its events — hits the warm cache.
    """

    model = None
    algo = None
    lr_fn = None
    driver_mode = "scan"

    def __init__(self):
        self.phase = "plain"
        self.ctx = None
        self._mixers: Dict = {}
        self._steps: Dict = {}
        self._runners: Dict = {}
        self._node_mesh = None

    def _make_mixer(self, topology: Topology, active) -> Callable:
        raise NotImplementedError

    def _adapter(self):
        raise NotImplementedError

    def _sampler(self):
        raise NotImplementedError

    # ------------------------------------------------------------- caches
    @staticmethod
    def _mask_key(active: np.ndarray):
        return None if active.all() else tuple(np.flatnonzero(~active))

    @staticmethod
    def _freeze_key(frozen: np.ndarray):
        return tuple(np.flatnonzero(frozen)) if frozen.any() else None

    def _mixer(self, topo: Topology, active: np.ndarray):
        mask = self._mask_key(active)
        key = (topo.edge_key(), mask)
        if key not in self._mixers:
            if mask is None:
                self._mixers[key] = self._make_mixer(topo, None)
            else:
                # churn path: remake the cached all-up mixer for the new
                # availability mask (same backend/wire choice); mixers
                # without a remake handle are rebuilt from scratch
                base = self._mixer(topo, np.ones_like(active))
                remake = getattr(base, "remake", None)
                self._mixers[key] = (remake(active=active)
                                     if remake is not None
                                     else self._make_mixer(topo, active))
        return self._mixers[key]

    def shard_mesh(self, num_nodes: int):
        """The (cached) 1-D node mesh shard-mode steps run on."""
        if self._node_mesh is None:
            from repro.launch.mesh import make_node_mesh
            self._node_mesh = make_node_mesh(num_nodes)
        return self._node_mesh

    def _base_step(self, topo: Topology, active: np.ndarray):
        from repro.core import driver
        if self.driver_mode == "shard":
            if not active.all():
                raise ValueError(
                    "shard driver cannot apply churn availability masks "
                    "(freeze/isolate need the node-stacked gather/dense "
                    "mixers — DESIGN.md §7); run churn schedules with "
                    "driver_mode='scan' or 'host'")
            return driver.make_shard_step(
                self.model, self.algo, self._adapter(),
                mesh=self.shard_mesh(topo.n), topology=topo)
        return driver.make_step(self.model, self.algo,
                                self._mixer(topo, active), self._adapter())

    def _step(self, topo: Topology, active: np.ndarray,
              frozen: np.ndarray):
        from repro.core import driver
        key = (self.phase, topo.edge_key(), self._mask_key(active),
               self._freeze_key(frozen))
        if key not in self._steps:
            step = self._base_step(topo, active)
            if key[-1] is not None:
                # hold exactly the frozen subset; isolate stragglers
                # (down but unfrozen) keep taking local steps
                step = driver.make_frozen_step(step, ~frozen)
            self._steps[key] = step
        return self._steps[key]

    def runner(self, topo: Topology, active: np.ndarray,
               frozen: np.ndarray) -> Callable:
        from repro.core import driver
        key = (self.phase, topo.edge_key(), self._mask_key(active),
               self._freeze_key(frozen))
        if key not in self._runners:
            self._runners[key] = driver.make_runner(
                self._step(topo, active, frozen), self._sampler(),
                self.lr_fn, self.driver_mode)
        run = self._runners[key]
        if self.phase == "plain":
            return run
        return lambda p, o, k, s0, ns: run(p, o, k, s0, ns, self.ctx)


def validate_shard_schedule(schedule: Schedule, num_nodes: int) -> None:
    """Pre-flight for ``driver_mode="shard"``: shard_map gossip has no
    churn path and only ring/complete-graph rewire targets, so reject
    unsupported schedules *before* the run starts instead of failing
    mid-schedule when the event fires (DESIGN.md §7)."""
    from repro.core.mixing import shard_supported_topology
    for seg in schedule.segments:
        for ev in seg.events:
            if isinstance(ev, ChurnEvent):
                raise ValueError(
                    f"schedule has churn at step {ev.step}; churn "
                    "(freeze/isolate availability masks) is unsupported "
                    "under driver_mode='shard' — run it node-stacked "
                    "with driver_mode='scan' or 'host' (DESIGN.md §7)")
            if isinstance(ev, RewireEvent):
                topo = _resolve_topology(ev, num_nodes)
                if not shard_supported_topology(topo):
                    raise ValueError(
                        f"rewire at step {ev.step} targets "
                        f"{topo.name!r}; the shard driver gossips on "
                        "ring/complete graphs only — use "
                        "driver_mode='scan' or 'host' for this schedule")


def _resolve_topology(ev: RewireEvent, n: int) -> Topology:
    topo = ev.topology
    if isinstance(topo, str):
        topo = Topology.make(topo, n)
    if topo.n != n:
        raise ValueError(f"rewire topology has {topo.n} nodes, run has {n}")
    return topo


def run_schedule(schedule: Schedule, hooks: FederationHooks, params,
                 opt_state, key, *, topology: Topology,
                 ledger: Optional[CommLedger] = None,
                 param_count: int = 0, elem_bytes: int = 4,
                 resume_step: int = 0, capture_at: Optional[int] = None
                 ) -> Tuple[Any, Any, Any, Optional[Dict]]:
    """Drive the full schedule. Returns ``(params, opt_state, key,
    captured)`` where ``captured`` is the ``{"params", "opt_state",
    "key", "step"}`` snapshot taken at the ``capture_at`` boundary
    (None when not requested).

    ``resume_step`` must satisfy ``schedule.validate_resume``; segments
    ending at or before it are skipped (topology events still replay so
    the graph state is correct when training picks back up).
    """
    n = topology.n
    schedule.validate_resume(resume_step)
    if capture_at is not None:
        if capture_at != 0 and \
                capture_at not in {s.stop for s in schedule.segments}:
            raise ValueError(f"capture_at={capture_at} is not a segment "
                             "boundary of this schedule")
        if capture_at <= resume_step and not (capture_at == resume_step == 0):
            raise ValueError(
                f"capture_at={capture_at} lies in the span skipped by "
                f"resume_step={resume_step}; nothing would be captured")
    active = np.ones(n, bool)
    frozen = np.zeros(n, bool)    # down nodes with freeze (vs isolate) mode
    fired = 0                 # homogenization rounds fired so far
    captured: Optional[Dict] = None
    if capture_at == 0:
        captured = {"params": params, "opt_state": opt_state, "key": key,
                    "step": 0}

    for seg in schedule.segments:
        skipped = seg.stop <= resume_step
        for ev in seg.events:
            if isinstance(ev, ChurnEvent):
                active = active.copy()
                frozen = frozen.copy()
                for i in (*ev.down, *ev.up):
                    if not 0 <= i < n:
                        raise ValueError(
                            f"churn event at step {ev.step} names node "
                            f"{i} outside [0, {n})")
                for i in ev.down:
                    active[i] = False
                    frozen[i] = ev.mode == "freeze"
                for i in ev.up:
                    active[i] = True
                    frozen[i] = False
                if not active.any():
                    raise ValueError(f"churn at step {ev.step} leaves no "
                                     "active nodes")
                hooks.on_topology(topology, active, frozen)
            elif isinstance(ev, RewireEvent):
                topology = _resolve_topology(ev, n)
                hooks.on_topology(topology, active, frozen)
            elif isinstance(ev, HomogenizeEvent):
                if skipped:
                    fired += 1      # round happened before the checkpoint
                    continue
                label_bytes = hooks.on_round(params, fired, ev.step,
                                             topology, active)
                fired += 1
                if ledger is not None and label_bytes is not None:
                    ledger.log_labels(fired, ev.step,
                                      np.asarray(label_bytes))
        if skipped:
            continue

        runner = hooks.runner(topology, active, frozen)
        if ledger is not None and param_count:
            ledger.log_gossip(
                fired, seg.start, seg.stop,
                gossip_bytes_per_step(topology, active, param_count,
                                      elem_bytes))
        params, opt_state, key, losses = runner(
            params, opt_state, key, jnp.asarray(seg.start, jnp.int32),
            seg.num_steps)
        if capture_at == seg.stop:
            captured = {"params": params, "opt_state": opt_state,
                        "key": key, "step": seg.stop}
        if seg.eval_after:
            hooks.on_eval(params, seg.stop - 1, losses)

    return params, opt_state, key, captured
