"""Pytree ⇄ flat-npz checkpointing with a structure manifest.

No external deps: leaves are flattened with '/'-joined key paths into one
``.npz``; the treedef is rebuilt from the key paths on restore. Handles the
node-stacked simulation params and per-arch model params alike.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # ml_dtypes extension types (bfloat16, ...)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: str, params: Any, step: int = 0,
                    extra: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    meta = {"step": step, "keys": sorted(flat), "extra": extra or {}}
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (same treedef)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    with open(_meta_path(path)) as f:
        meta = json.load(f)
    flat_like = _flatten(like)
    if sorted(flat_like) != meta["keys"]:
        missing = set(meta["keys"]) ^ set(flat_like)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(_path_str(q) for q in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    import jax.numpy as jnp
    new_leaves = [jnp.asarray(npz[k]).astype(l.dtype).reshape(l.shape)
                  for k, l in zip(paths, leaves_like)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta["step"]
