"""Pytree ⇄ flat-npz checkpointing with a structure manifest.

No external deps: leaves are flattened with '/'-joined key paths into one
``.npz``; the treedef is rebuilt from the key paths on restore. Handles the
node-stacked simulation params and per-arch model params alike.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, Tuple

import jax
import numpy as np

# Bump when the on-disk layout changes incompatibly. Files written before
# versioning existed (no "version" key) are rejected with a clear error —
# silent misloads of skewed layouts are exactly what this guards against.
SCHEMA_VERSION = 1


def checkpoint_checksum(flat: Dict[str, np.ndarray]) -> int:
    """CRC32 over every array's dtype, shape, and raw bytes in sorted key
    order — cheap integrity cover for the whole npz payload."""
    crc = 0
    for key in sorted(flat):
        arr = np.ascontiguousarray(flat[key])
        head = f"{key}:{arr.dtype.str}:{arr.shape}".encode()
        crc = zlib.crc32(arr.tobytes(), zlib.crc32(head, crc))
    return crc & 0xFFFFFFFF


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # ml_dtypes extension types (bfloat16, ...)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: str, params: Any, step: int = 0,
                    extra: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    meta = {"version": SCHEMA_VERSION, "step": step, "keys": sorted(flat),
            "checksum": checkpoint_checksum(flat), "extra": extra or {}}
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (same treedef).

    Rejects loudly (``ValueError``) on: a missing/mismatched schema
    version, a stored-vs-recomputed checksum mismatch (bit rot or a
    truncated write), or a key-structure mismatch against ``like``."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    with open(_meta_path(path)) as f:
        meta = json.load(f)
    if meta.get("version") != SCHEMA_VERSION:
        raise ValueError(
            f"checkpoint {path!r} schema version "
            f"{meta.get('version')!r} != supported {SCHEMA_VERSION}; "
            "refusing to load a version-skewed or pre-versioning file")
    stored = {k: npz[k] for k in npz.files}
    crc = checkpoint_checksum(stored)
    if meta.get("checksum") != crc:
        raise ValueError(
            f"checkpoint {path!r} checksum mismatch: meta records "
            f"{meta.get('checksum')!r}, arrays hash to {crc} — the npz "
            "is corrupt or was modified after save")
    flat_like = _flatten(like)
    if sorted(flat_like) != meta["keys"]:
        missing = set(meta["keys"]) ^ set(flat_like)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(_path_str(q) for q in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    import jax.numpy as jnp
    new_leaves = [jnp.asarray(npz[k]).astype(l.dtype).reshape(l.shape)
                  for k, l in zip(paths, leaves_like)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta["step"]
