from repro.checkpoint.checkpoint import (  # noqa: F401
    SCHEMA_VERSION, checkpoint_checksum, load_checkpoint, save_checkpoint)
