# Launch layer: meshes, sharding rules, input specs, dry-run, drivers.
# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
from repro.launch.mesh import make_production_mesh, node_axes_for  # noqa: F401
