"""Production meshes (TPU v5e pods).

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512
chips as (pod=2, data=16, model=16). Defined as functions so importing the
module never touches jax device state (device count is locked at first
init — the dry-run sets XLA_FLAGS before importing jax).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BANDWIDTH = 819e9             # B/s
ICI_LINK_BANDWIDTH = 50e9         # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 512 if multi_pod else 256
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def _largest_divisor(num_nodes: int, limit: int) -> int:
    """Largest divisor of ``num_nodes`` that is ≤ ``limit`` (≥ 1)."""
    return max(d for d in range(1, max(min(limit, num_nodes), 1) + 1)
               if num_nodes % d == 0)


def make_node_mesh(num_nodes: int):
    """1-D mesh for the sharded decentralized driver (``driver_mode=
    "shard"``): one ``"node"`` axis over the largest device count that
    divides ``num_nodes``, so every device holds a contiguous block of
    ``num_nodes // size`` nodes. Degenerates to a single-device mesh
    (``shard_map`` still runs, the block holds every node) — which is
    what the tier-1 suite exercises; CI's forced-8-device job and
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` give the real
    multi-device placement.

    When ``num_nodes`` has no divisor matching the device count (e.g. a
    prime node count larger than the device pool), the mesh quietly uses
    fewer devices than available — a warning names the chosen size so a
    7-node run on 8 devices doesn't silently serialize onto one.
    """
    if num_nodes < 1:
        raise ValueError(f"num_nodes must be >= 1, got {num_nodes}")
    import numpy as np
    devices = jax.devices()
    size = _largest_divisor(num_nodes, len(devices))
    if size < min(len(devices), num_nodes):
        import warnings
        warnings.warn(
            f"make_node_mesh: num_nodes={num_nodes} has no divisor matching "
            f"the {len(devices)}-device pool; using a {size}-device node "
            f"mesh ({num_nodes // size} node(s) per device). Pick a node "
            "count that divides by the device count to use every device.",
            RuntimeWarning, stacklevel=2)
    return jax.sharding.Mesh(np.asarray(devices[:size]), ("node",))


def make_federation_mesh(num_nodes: int, model_parallel: int = 1):
    """2-D ``("node", "model")`` mesh for the sharded driver: the node
    axis places node blocks exactly like :func:`make_node_mesh`; the
    model axis shards each replica's parameters (FSDP-style, see
    ``launch/sharding.federation_specs``). ``model_parallel=1`` returns
    the plain 1-D node mesh — today's path, byte-for-byte.

    The device grid factors as ``(node_size, model_parallel)``:
    ``node_size`` is the largest divisor of ``num_nodes`` that fits in
    ``len(devices) // model_parallel``. Gossip collectives run over
    ``"node"`` only; ``"model"`` carries the all-gathers/psums inside
    one replica (DESIGN.md §10).
    """
    if model_parallel == 1:
        return make_node_mesh(num_nodes)
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    if num_nodes < 1:
        raise ValueError(f"num_nodes must be >= 1, got {num_nodes}")
    import numpy as np
    devices = jax.devices()
    if model_parallel > len(devices):
        raise ValueError(
            f"model_parallel={model_parallel} exceeds the device count "
            f"({len(devices)}) — shrink --model-parallel or force more "
            "host devices (XLA_FLAGS=--xla_force_host_platform_device_"
            "count=N)")
    node_size = _largest_divisor(num_nodes, len(devices) // model_parallel)
    grid = np.asarray(devices[:node_size * model_parallel]).reshape(
        node_size, model_parallel)
    return jax.sharding.Mesh(grid, ("node", "model"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def node_axes_for(mesh, scope: str):
    """Which mesh axes form the decentralized gossip graph.

    scope='replica': every data-parallel index is a node (paper's ring-16 /
    ring-32). scope='pod': one node per pod — used by the architectures too
    large to hold per-data-replica parameters (DESIGN.md §5); FSDP then
    shards over 'data' inside the node.
    """
    names = mesh.axis_names
    if scope == "replica":
        return tuple(a for a in ("pod", "data") if a in names)
    if scope == "pod":
        return ("pod",) if "pod" in names else ()
    raise ValueError(scope)


def num_nodes(mesh, scope: str) -> int:
    n = 1
    for a in node_axes_for(mesh, scope):
        n *= mesh.shape[a]
    return n
