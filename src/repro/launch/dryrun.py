"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

For each combination this produces (and caches as JSON under
``experiments/dryrun/``):
  * ``memory_analysis`` — argument/output/temp bytes per device,
  * ``cost_analysis``   — per-device HLO FLOPs and bytes accessed,
  * per-collective byte counts parsed from the post-SPMD optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), which cost_analysis does not report,
  * the roofline terms derived from the three (see benchmarks/roofline.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import os
# MUST precede any jax import: jax locks the device count on first init.
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, ASSIGNED_ARCHS, SHAPES, get_config,
                           shape_supported)
from repro.configs.base import TrainConfig
from repro.launch import input_specs as ispec
from repro.launch import sharding as shd
from repro.launch.mesh import (HBM_BANDWIDTH, ICI_LINK_BANDWIDTH,
                               PEAK_FLOPS_BF16, make_production_mesh,
                               num_nodes)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import build_model
from repro.obs import log as obs_log

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred|"
                       r"c64|c128)\[([0-9,]*)\]")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Split an HLO module dump into {computation_name: body_text}."""
    comps: Dict[str, str] = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and "{" in line and "(" in line:
            head = line.strip().replace("ENTRY ", "")
            cand = head.split("(", 1)[0].strip().lstrip("%")
            if cand:
                name, buf = cand, []
                continue
        if name is not None:
            if line.startswith("}"):
                comps[name] = "\n".join(buf)
                name = None
            else:
                buf.append(line)
    return comps


_TRIP_RE = re.compile(r"constant\((\d+)\)")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+).*?"
                       r"body=%?([\w\.\-]+)")


def _line_bytes(stripped: str, op: str) -> float:
    lhs = stripped.split(f" {op}")[0].split("=", 1)
    region = lhs[1] if len(lhs) > 1 else lhs[0]
    nbytes = 0.0
    for m in _SHAPE_RE.finditer(region):
        dt, dims = m.group(1), m.group(2)
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        nbytes += size * _DTYPE_BYTES[dt]
    return nbytes


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    Shapes in the optimized module are per-partition, so totals are
    per-device traffic estimates. Collectives inside ``while`` bodies
    (lax.scan over layers) are multiplied by the loop trip count — parsed
    as the largest integer constant in the loop condition — otherwise a
    61-layer scanned stack would count its per-layer all-reduces once.
    """
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"entry": hlo_text}
    multiplier: Dict[str, float] = {}
    for text in comps.values():
        for line in text.splitlines():
            if " while(" not in line:
                continue
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            trips = [int(t) for t in _TRIP_RE.findall(comps.get(cond, ""))]
            if trips:
                multiplier[body] = float(max(trips))

    out = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0.0
    for cname, text in comps.items():
        mult = multiplier.get(cname, 1.0)
        for line in text.splitlines():
            stripped = line.strip()
            for c in _COLLECTIVES:
                if f" {c}(" in stripped or f"{c}-start(" in stripped:
                    out[c] += _line_bytes(stripped, c) * mult
                    out["count"] += mult
                    break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _analyze(lowered, compiled, n_chips: int) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    hlo_text = compiled.as_text()
    colls = collective_bytes(hlo_text)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BANDWIDTH
    collective_s = colls["total"] / ICI_LINK_BANDWIDTH
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": colls["total"],
        "collectives": colls,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "n_chips": n_chips,
        "_hlo": hlo_text,      # popped + gzipped by the caller
    }


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, wire_dtype: str = "float32",
            cfg_overrides: Dict[str, Any] | None = None,
            label: str = "", sharded_out: bool = False) -> Dict[str, Any]:
    """``wire_dtype`` / ``cfg_overrides`` are the §Perf iteration knobs;
    the baseline table uses wire_dtype='float32' (paper-faithful
    full-precision gossip) and the per-arch default configs."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch, shape=shape_name)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi_pod else "single",
                           "variant": label or "baseline"}
    if not shape_supported(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention architecture: no sub-quadratic "
                        "variant for 524k context (DESIGN.md)")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = build_model(cfg)
    from repro.models import transformer as _tfm
    from repro.models import ssm as _ssm
    _tfm.RESIDUAL_CONSTRAINT = None      # reset any prior §Perf hooks
    _ssm.HEAD_CONSTRAINT = None
    t0 = time.time()
    with mesh:
        if shape.mode == "train":
            nodes = num_nodes(mesh, cfg.node_scope)
            tcfg = TrainConfig(num_nodes=nodes)
            if sharded_out and cfg.node_scope == "pod":
                # §Perf: pin the residual stream batch-sharded inside the
                # layer scan (same GSPMD batch-replication drift as prefill;
                # pod scope only — in replica scope 'data' is the node axis
                # and per-node activations are already minimal).
                from jax.sharding import NamedSharding, PartitionSpec as P
                _tfm.RESIDUAL_CONSTRAINT = (
                    lambda h: jax.lax.with_sharding_constraint(
                        h, NamedSharding(mesh, P("data", None, None))))
            step = make_train_step(model, tcfg, nodes, wire_dtype=wire_dtype)
            p_spec = ispec.stacked_params_specs(model, nodes)
            opt_spec = jax.eval_shape(step.init_opt, p_spec)
            batch_spec = ispec.train_specs(cfg, shape, nodes)
            p_sh = shd.param_shardings(p_spec, mesh, cfg.node_scope)
            opt_sh = shd.param_shardings(opt_spec, mesh, cfg.node_scope)
            b_sh = shd.batch_shardings(batch_spec, mesh, cfg.node_scope)
            lowered = jax.jit(
                step, in_shardings=(p_sh, opt_sh, b_sh, None),
                out_shardings=(p_sh, opt_sh, None),
            ).lower(p_spec, opt_spec, batch_spec,
                    jax.ShapeDtypeStruct((), jnp.float32))
            rec["num_nodes"] = nodes
        elif shape.mode == "prefill":
            step = make_prefill_step(model)
            p_spec = ispec.params_specs(model)
            batch_spec = ispec.prefill_specs(cfg, shape)
            p_sh = shd.serve_param_shardings(p_spec, mesh)
            b_sh = shd.serve_batch_shardings(batch_spec, mesh)
            out_sh = None
            if sharded_out:
                # §Perf: without an output constraint GSPMD replicates the
                # logits, which back-propagates replication through the
                # whole stack — shard logits batch over the data axes, and
                # pin the residual stream batch-sharded inside the layer
                # scan (GSPMD drifts to batch-replicated carries otherwise).
                # NOTE: hooks MUST be installed before ANY trace of `step`
                # (jax.eval_shape populates the jit trace cache — a trace
                # taken with hooks unset would be silently reused).
                from jax.sharding import NamedSharding, PartitionSpec as P
                axes = tuple(a for a in ("pod", "data")
                             if a in mesh.axis_names)
                ax = axes if len(axes) > 1 else axes[0]
                _tfm.RESIDUAL_CONSTRAINT = (
                    lambda h: jax.lax.with_sharding_constraint(
                        h, NamedSharding(mesh, P(ax, None, None))))
                _ssm.HEAD_CONSTRAINT = (
                    lambda t: jax.lax.with_sharding_constraint(
                        t, NamedSharding(mesh, P(ax, None, "model", None))))
                logits_spec = jax.eval_shape(step, p_spec, batch_spec)
                out_sh = shd.serve_batch_shardings(logits_spec, mesh)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh),
                              out_shardings=out_sh,
                              ).lower(p_spec, batch_spec)
        else:  # decode
            step = make_decode_step(model)
            p_spec = ispec.params_specs(model)
            tok_spec, state_spec, extras = ispec.decode_specs(cfg, shape, model)
            p_sh = shd.serve_param_shardings(p_spec, mesh)
            t_sh = shd.serve_batch_shardings(tok_spec, mesh)
            s_sh = shd.serve_state_shardings(state_spec, mesh)
            e_sh = tuple(shd.serve_batch_shardings(e, mesh) for e in extras)
            lowered = jax.jit(
                step, in_shardings=(p_sh, t_sh, s_sh) + e_sh,
                out_shardings=(None, s_sh),
            ).lower(p_spec, tok_spec, state_spec, *extras)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
    rec.update(_analyze(lowered, compiled, n_chips))
    # model-level FLOPs: 6·N_active·tokens (fwd+bwd) or 2·N_active·tokens
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    factor = 6 if shape.mode == "train" else 2
    rec["model_flops_total"] = factor * n_active * tokens
    rec["model_flops_per_device"] = rec["model_flops_total"] / n_chips
    hw = rec["hlo_flops_per_device"]
    rec["useful_flop_ratio"] = (rec["model_flops_per_device"] / hw
                                if hw else 0.0)
    rec["status"] = "ok"
    if verbose:
        obs_log.info("dryrun.ok", arch=arch, shape=shape_name,
                     mesh=rec["mesh"],
                     compile_s=round(rec["compile_s"], 1),
                     compute_ms=round(rec["compute_s"] * 1e3, 2),
                     memory_ms=round(rec["memory_s"] * 1e3, 2),
                     collective_ms=round(rec["collective_s"] * 1e3, 2),
                     dominant=rec["dominant"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (or --all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape_name}_{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    obs_log.info("dryrun.cached", tag=tag)
                    continue
                try:
                    rec = run_one(arch, shape_name, multi)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e)[:2000]}
                    failures.append(tag)
                    obs_log.warning("dryrun.failed", tag=tag, error=repr(e))
                hlo = rec.pop("_hlo", None)
                if hlo is not None:
                    import gzip
                    with gzip.open(os.path.join(args.out, tag + ".hlo.gz"),
                                   "wt") as hf:
                        hf.write(hlo)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        raise SystemExit(f"dry-run failures: {failures}")
    obs_log.info("dryrun.done",
                 status="all requested combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
