"""Decentralized LLM training driver.

Runs the full IDKD pipeline on token data: node-stacked params, per-node
private corpus shards (Dirichlet over topics), QG-DSGDm-N gossip steps,
and periodic IDKD homogenization rounds with top-k sparse soft labels on a
public corpus. On CPU this drives reduced configs end-to-end; on a TPU
cluster the same functions run under the production mesh (dryrun.py proves
the latter lowers + compiles for every assigned arch × shape).

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 40 --nodes 8 --idkd
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import IDKDConfig, ModelConfig, TrainConfig
from repro.core import distill, labeling
from repro.core.topology import Topology
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import make_lm_data
from repro.launch.steps import (consensus_params, make_ring_mixer,
                                make_train_step, stack_params)
from repro.models import build_model


def idkd_label_round(model, params_stacked, public_tokens, private_tokens,
                     idkd_cfg: IDKDConfig, topology: Topology,
                     backend: str = "sparse"):
    """LLM IDKD round via the unified labeling engine: per-sequence
    detector confidences + top-k soft labels on the public corpus,
    ROC-calibrated threshold, sparse neighbour label exchange.

    Returns (sparse_labels, weights (n, P), id_mask, thresholds). The
    labels stay sparse end to end — neighbour averaging concatenates
    payloads along the k axis (k_out = (max_deg+1)·k) instead of the
    seed's densify→average→resparsify detour through (n, P, S, V).
    """
    n = params_stacked and jax.tree.leaves(params_stacked)[0].shape[0]

    @jax.jit
    def node_logits(p, toks):
        return jax.vmap(lambda pp, tt: model.forward(pp, {"tokens": tt})[0]
                        )(p, toks)

    pub = jnp.broadcast_to(jnp.asarray(public_tokens)[None],
                           (n,) + public_tokens.shape)
    logits_pub = node_logits(params_stacked, pub)          # (n, P, S, V)
    priv = jnp.asarray(private_tokens)                      # (n, Vp, S)
    logits_priv = node_logits(params_stacked, priv)
    # val = the node's private corpus (ID); cal=None = the public corpus
    out = labeling.label_round(logits_pub, logits_priv, None,
                               topology, idkd_cfg, backend=backend)
    return out.labels, out.weights, out.id_masks, out.thresholds


def make_kd_train_step(model, tcfg: TrainConfig, num_nodes: int,
                       idkd_cfg: IDKDConfig):
    """Train step whose loss adds sparse-KD on homogenized public batches."""
    from repro.core.algorithms import make_algorithm
    algo = make_algorithm(tcfg.algorithm, momentum=tcfg.momentum,
                          weight_decay=tcfg.weight_decay)
    mixer = make_ring_mixer(num_nodes)

    def node_loss(p, batch):
        base, _ = model.loss(p, {"tokens": batch["tokens"],
                                 "labels": batch["labels"]})
        logits, _ = model.forward(p, {"tokens": batch["pub_tokens"]})
        kd = distill.sparse_kd_loss(
            logits, distill.SparseLabels(batch["pub_vals"],
                                         batch["pub_idx"]),
            idkd_cfg.temperature) / (idkd_cfg.temperature ** 2)
        kd = jnp.sum(kd.mean(-1) * batch["pub_w"]) / \
            jnp.maximum(jnp.sum(batch["pub_w"]), 1.0)
        return base + idkd_cfg.kd_weight * kd

    def step(params, opt_state, batch, lr):
        losses, grads = jax.vmap(jax.value_and_grad(node_loss))(params, batch)
        params, opt_state = algo.step(params, grads, opt_state, lr, mixer)
        return params, opt_state, {"loss": jnp.mean(losses)}

    step.init_opt = algo.init
    return step


def run_training(cfg: ModelConfig, tcfg: TrainConfig, *, seq_len: int = 64,
                 n_seqs: int = 512, n_public: int = 64, log_every: int = 10,
                 use_idkd: bool = False, verbose: bool = True
                 ) -> Dict[str, Any]:
    """End-to-end reduced-scale decentralized LM training (CPU-friendly)."""
    n = tcfg.num_nodes
    model = build_model(cfg)
    topo = Topology.make(tcfg.topology, n)
    tokens, topics = make_lm_data(cfg.vocab_size, seq_len + 1, n_seqs,
                                  seed=tcfg.seed)
    parts = dirichlet_partition(topics, n, tcfg.alpha,
                                np.random.default_rng(tcfg.seed))
    public_tokens, _ = make_lm_data(cfg.vocab_size, seq_len, n_public,
                                    num_topics=10, seed=tcfg.seed + 99)
    params = stack_params(model.init(jax.random.PRNGKey(tcfg.seed)), n)
    idkd_cfg = tcfg.idkd or IDKDConfig(label_topk=8)

    plain_step = jax.jit(make_train_step(model, tcfg, n))
    kd_step = jax.jit(make_kd_train_step(model, tcfg, n, idkd_cfg))
    opt_state = plain_step.init_opt(params)

    rngs = [np.random.default_rng(tcfg.seed + 5 * i) for i in range(n)]
    pub_payload: Optional[Dict[str, Any]] = None
    history = []
    t0 = time.time()
    for step_i in range(tcfg.steps):
        if (use_idkd and step_i == idkd_cfg.start_step):
            m_priv = max(1, min(16, min(len(p) for p in parts)))
            priv = np.stack([tokens[parts[i][:m_priv], :seq_len]
                             for i in range(n)])
            backend = idkd_cfg.label_backend
            if backend not in ("fused", "sparse"):
                # the LM KD step consumes sparse payloads; the dense
                # oracle backend is not an option at vocab scale
                if verbose:
                    print(f"[idkd] label_backend={backend!r} unsupported "
                          "for LM stacks; using 'sparse'")
                backend = "sparse"
            sparse, w, id_mask, thr = idkd_label_round(
                model, params, public_tokens, priv, idkd_cfg, topo,
                backend=backend)
            pub_payload = {"vals": np.asarray(sparse.values),
                           "idx": np.asarray(sparse.indices),
                           "w": np.asarray(w)}
            if verbose:
                print(f"[idkd] step {step_i}: kept "
                      f"{float(np.asarray(id_mask).mean()):.2f} of public "
                      f"set; thresholds {np.asarray(thr).round(3)}")
        idx = np.stack([r.choice(parts[i], size=tcfg.batch_size,
                                 replace=len(parts[i]) < tcfg.batch_size)
                        for i, r in enumerate(rngs)])
        batch = {"tokens": jnp.asarray(tokens[idx][:, :, :-1]),
                 "labels": jnp.asarray(tokens[idx][:, :, 1:])}
        lr = tcfg.lr
        if pub_payload is None:
            params, opt_state, metrics = plain_step(params, opt_state, batch,
                                                    lr)
        else:
            pb = np.stack([r.integers(0, len(public_tokens),
                                      size=min(4, len(public_tokens)))
                           for r in rngs])
            batch["pub_tokens"] = jnp.asarray(public_tokens[pb])
            nidx = np.arange(n)[:, None]
            batch["pub_vals"] = jnp.asarray(pub_payload["vals"][nidx, pb])
            batch["pub_idx"] = jnp.asarray(pub_payload["idx"][nidx, pb])
            batch["pub_w"] = jnp.asarray(pub_payload["w"][nidx, pb])
            params, opt_state, metrics = kd_step(params, opt_state, batch, lr)
        if step_i % log_every == 0 or step_i == tcfg.steps - 1:
            history.append(float(metrics["loss"]))
            if verbose:
                print(f"[train] step {step_i}: loss {history[-1]:.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    return {"params": consensus_params(params), "loss_history": history,
            "model": model}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--idkd", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config — TPU scale")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    tcfg = TrainConfig(num_nodes=args.nodes, steps=args.steps, lr=0.1,
                       alpha=args.alpha, batch_size=8,
                       idkd=IDKDConfig(start_step=args.steps // 2,
                                       label_topk=8))
    out = run_training(cfg, tcfg, use_idkd=args.idkd)
    print(f"final loss: {out['loss_history'][-1]:.4f}")


if __name__ == "__main__":
    main()
