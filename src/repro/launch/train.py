"""Decentralized LLM training driver.

Runs the full IDKD pipeline on token data: node-stacked params, per-node
private corpus shards (Dirichlet over topics), QG-DSGDm-N gossip steps,
and periodic IDKD homogenization rounds with top-k sparse soft labels on a
public corpus. On CPU this drives reduced configs end-to-end; on a TPU
cluster the same functions run under the production mesh (dryrun.py proves
the latter lowers + compiles for every assigned arch × shape).

The step loop is the unified on-device driver (``core.driver``): one
``make_step`` per phase (plain LM / LM + sparse-KD), per-node batch
sampling under jit, and the inner loop compiled as a ``lax.scan`` between
log boundaries. Params-gossip and the IDKD label exchange share one
``tcfg.topology`` graph (the seed gossiped on a hardwired ring while
labels moved on ``tcfg.topology``).

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 40 --nodes 8 --idkd
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import IDKDConfig, ModelConfig, TrainConfig
from repro.core import driver, labeling
from repro.core.algorithms import make_algorithm
from repro.core.mixing import Mixer, make_mixer
from repro.core.topology import Topology
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import make_lm_data
from repro.launch.steps import consensus_params, stack_params
from repro.models import build_model


def make_gossip_mixer(tcfg: TrainConfig, wire_dtype: str = "native"
                      ) -> Tuple[Topology, Mixer]:
    """The (topology, mixer) pair ``run_training`` gossips params on.

    Built from ``tcfg.topology`` — the same graph object the IDKD label
    exchange uses, so params-gossip and label-exchange always agree.
    ``wire_dtype`` applies to every phase, KD included (the seed's KD step
    silently built an f32-wire mixer, losing the §Perf bf16-wire halving).
    """
    topo = Topology.make(tcfg.topology, tcfg.num_nodes)
    return topo, make_mixer(topo, wire_dtype=wire_dtype)


def idkd_label_round(model, params_stacked, public_tokens, private_tokens,
                     idkd_cfg: IDKDConfig, topology: Topology,
                     backend: str = "sparse"):
    """LLM IDKD round via the unified labeling engine: per-sequence
    detector confidences + top-k soft labels on the public corpus,
    ROC-calibrated threshold, sparse neighbour label exchange.

    Returns (sparse_labels, weights (n, P), id_mask, thresholds). The
    labels stay sparse end to end — neighbour averaging concatenates
    payloads along the k axis (k_out = (max_deg+1)·k) instead of the
    seed's densify→average→resparsify detour through (n, P, S, V).
    """
    n = params_stacked and jax.tree.leaves(params_stacked)[0].shape[0]

    @jax.jit
    def node_logits(p, toks):
        return jax.vmap(lambda pp, tt: model.forward(pp, {"tokens": tt})[0]
                        )(p, toks)

    pub = jnp.broadcast_to(jnp.asarray(public_tokens)[None],
                           (n,) + public_tokens.shape)
    logits_pub = node_logits(params_stacked, pub)          # (n, P, S, V)
    priv = jnp.asarray(private_tokens)                      # (n, Vp, S)
    logits_priv = node_logits(params_stacked, priv)
    # val = the node's private corpus (ID); cal=None = the public corpus
    out = labeling.label_round(logits_pub, logits_priv, None,
                               topology, idkd_cfg, backend=backend)
    return out.labels, out.weights, out.id_masks, out.thresholds


def run_training(cfg: ModelConfig, tcfg: TrainConfig, *, seq_len: int = 64,
                 n_seqs: int = 512, n_public: int = 64, log_every: int = 10,
                 use_idkd: bool = False, verbose: bool = True,
                 wire_dtype: str = "native", driver_mode: str = "scan"
                 ) -> Dict[str, Any]:
    """End-to-end reduced-scale decentralized LM training (CPU-friendly)."""
    n = tcfg.num_nodes
    model = build_model(cfg)
    topo, mixer = make_gossip_mixer(tcfg, wire_dtype)
    algo = make_algorithm(tcfg.algorithm, momentum=tcfg.momentum,
                          weight_decay=tcfg.weight_decay)
    tokens, topics = make_lm_data(cfg.vocab_size, seq_len + 1, n_seqs,
                                  seed=tcfg.seed)
    parts = dirichlet_partition(topics, n, tcfg.alpha,
                                np.random.default_rng(tcfg.seed))
    public_tokens, _ = make_lm_data(cfg.vocab_size, seq_len, n_public,
                                    num_topics=10, seed=tcfg.seed + 99)
    params = stack_params(model.init(jax.random.PRNGKey(tcfg.seed)), n)
    idkd_cfg = tcfg.idkd or IDKDConfig(label_topk=8)

    plain_step = driver.make_step(model, algo, mixer, driver.lm_adapter)
    kd_step = driver.make_step(model, algo, mixer,
                               driver.lm_sparse_kd_adapter(idkd_cfg))
    opt_state = plain_step.init_opt(params)

    priv_parts = driver.pad_partitions(parts)
    sampler = driver.make_lm_sampler(priv_parts, tokens, tcfg.batch_size)
    lr_fn = lambda s: jnp.asarray(tcfg.lr, jnp.float32)   # noqa: E731
    runner = driver.make_runner(plain_step, sampler, lr_fn, driver_mode)
    key = jax.random.PRNGKey(tcfg.seed + 1)

    kd_fires = use_idkd and 0 <= idkd_cfg.start_step < tcfg.steps
    history = []
    t0 = time.time()
    for a, b in driver.eval_boundaries(
            tcfg.steps, log_every,
            idkd_cfg.start_step if kd_fires else None):
        if kd_fires and a == idkd_cfg.start_step:
            m_priv = max(1, min(16, min(len(p) for p in parts)))
            priv = np.stack([tokens[parts[i][:m_priv], :seq_len]
                             for i in range(n)])
            backend = idkd_cfg.label_backend
            if backend not in ("fused", "sparse"):
                # the LM KD step consumes sparse payloads; the dense
                # oracle backend is not an option at vocab scale
                if verbose:
                    print(f"[idkd] label_backend={backend!r} unsupported "
                          "for LM stacks; using 'sparse'")
                backend = "sparse"
            sparse, w, id_mask, thr = idkd_label_round(
                model, params, public_tokens, priv, idkd_cfg, topo,
                backend=backend)
            sampler = driver.make_lm_kd_sampler(
                priv_parts, tokens, tcfg.batch_size, public_tokens,
                sparse.values, sparse.indices, w,
                pub_batch=min(4, len(public_tokens)))
            runner = driver.make_runner(kd_step, sampler, lr_fn,
                                        driver_mode)
            if verbose:
                print(f"[idkd] step {a}: kept "
                      f"{float(np.asarray(id_mask).mean()):.2f} of public "
                      f"set; thresholds {np.asarray(thr).round(3)}")
        params, opt_state, key, losses = runner(
            params, opt_state, key, jnp.asarray(a, jnp.int32), b - a)
        last = b - 1
        if last % log_every == 0 or last == tcfg.steps - 1:
            history.append(float(losses[-1]))
            if verbose:
                print(f"[train] step {last}: loss {history[-1]:.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    return {"params": consensus_params(params), "loss_history": history,
            "model": model, "topology": topo}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--idkd", action="store_true")
    ap.add_argument("--wire-dtype", default="native",
                    choices=["native", "float32"])
    ap.add_argument("--driver", default="scan", choices=["scan", "host"])
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config — TPU scale")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    tcfg = TrainConfig(num_nodes=args.nodes, steps=args.steps, lr=0.1,
                       alpha=args.alpha, batch_size=8,
                       topology=args.topology,
                       idkd=IDKDConfig(start_step=args.steps // 2,
                                       label_topk=8))
    out = run_training(cfg, tcfg, use_idkd=args.idkd,
                       wire_dtype=args.wire_dtype, driver_mode=args.driver)
    print(f"final loss: {out['loss_history'][-1]:.4f}")


if __name__ == "__main__":
    main()
