"""Decentralized LLM training driver.

Runs the full IDKD pipeline on token data: node-stacked params, per-node
private corpus shards (Dirichlet over topics), QG-DSGDm-N gossip steps,
and periodic IDKD homogenization rounds with top-k sparse soft labels on a
public corpus. On CPU this drives reduced configs end-to-end; on a TPU
cluster the same functions run under the production mesh (dryrun.py proves
the latter lowers + compiles for every assigned arch × shape).

The step loop is the unified on-device driver (``core.driver``): one
``make_step`` per phase (plain LM / LM + sparse-KD), per-node batch
sampling under jit, and the inner loop compiled as a ``lax.scan`` between
log boundaries. The outer loop is the federation scheduler
(``repro.sched``): homogenization rounds fire every
``IDKDConfig.every_k_steps`` (``num_rounds`` of them), churn / rewire
events remake the gossip mixer mid-run, and all traffic — wire-dtype
aware params-gossip plus the sparse label payloads — lands in one
communication ledger. Params-gossip and the IDKD label exchange share
one ``tcfg.topology`` graph (the seed gossiped on a hardwired ring while
labels moved on ``tcfg.topology``).

``--driver shard`` runs the federation under ``shard_map`` over a node
mesh (DESIGN.md §7): per-device node blocks, ppermute params-gossip,
shard-local label scoring with a top-k-only exchange. Develop/test
multi-device behaviour on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Adding
``--model-parallel N`` factors the device grid into a 2-D
``("node", "model")`` mesh (DESIGN.md §10): each replica's params and
optimizer state shard over N devices (FSDP-style), gossip stays
node-axis-only, and streaming label rounds run vocab-sharded.

``--compression topk --compression-frac 0.01`` sparsifies the gossip
wire (error-feedback top-k / random-k, DESIGN.md §9), ``--gossip
delayed`` switches to one-step-stale mixing, and ``--churn-mode stale``
turns ``--churn`` windows into straggler-tolerant rounds — the slow
node's neighbours keep mixing its last payload instead of stalling. All
three run under both the node-stacked and the shard drivers and land
compression-aware bytes in the ledger.

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 40 --nodes 8 --idkd [--rounds 2] [--churn 3@20-30]
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sched
from repro.configs import get_config
from repro.configs.base import IDKDConfig, ModelConfig, TrainConfig
from repro.core import distill, driver, labeling
from repro.core.algorithms import make_algorithm
from repro.core.mixing import (Mixer, make_mixer, normalize_compression,
                               payload_elem_count)
from repro.core.topology import Topology
from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import make_lm_data
from repro.launch.steps import consensus_params, stack_params
from repro.models import build_model
from repro.obs import log as obs_log
from repro.resil import SimulatedCrash


def make_gossip_mixer(tcfg: TrainConfig, wire_dtype: str = "native",
                      topology: Optional[Topology] = None,
                      active=None, stale=None, compression=None,
                      gossip: str = "sync", stateful=None,
                      wire_fault=None,
                      wire_guard=None) -> Tuple[Topology, Mixer]:
    """The (topology, mixer) pair the launch path gossips params on —
    ``_LMFederation``'s mixer construction point.

    Built from ``tcfg.topology`` (or an explicit ``topology``, e.g. after
    a rewire event) — the same graph object the IDKD label exchange uses,
    so params-gossip and label-exchange always agree. ``wire_dtype``
    applies to every phase, KD included (the seed's KD step silently
    built an f32-wire mixer, losing the §Perf bf16-wire halving);
    ``active`` is the churn mask. ``stale`` / ``compression`` /
    ``gossip`` / ``stateful`` are the compressed-wire controls
    (DESIGN.md §9) and ``wire_fault`` / ``wire_guard`` the resilience
    layer's fault-injection controls (DESIGN.md §12), all forwarded
    verbatim to ``mixing.make_mixer``.
    """
    topo = topology or Topology.make(tcfg.topology, tcfg.num_nodes)
    return topo, make_mixer(topo, wire_dtype=wire_dtype, active=active,
                            stale=stale, compression=compression,
                            gossip=gossip, stateful=stateful,
                            wire_fault=wire_fault, wire_guard=wire_guard)


def idkd_label_round(model, params_stacked, public_tokens, private_tokens,
                     idkd_cfg: IDKDConfig, topology: Topology,
                     backend: str = "sparse", active=None, mesh=None):
    """LLM IDKD round via the unified labeling engine: per-sequence
    detector confidences + top-k soft labels on the public corpus,
    ROC-calibrated threshold, sparse neighbour label exchange.

    Returns (sparse_labels, weights (n, P), id_mask, thresholds). The
    labels stay sparse end to end — neighbour averaging concatenates
    payloads along the k axis (k_out = (max_deg+1)·k) instead of the
    seed's densify→average→resparsify detour through (n, P, S, V).
    ``active`` masks churned-out nodes from the exchange. With ``mesh``
    (the shard driver's node mesh) the round runs sharded: score/select
    shard-local, the exchange ppermutes only top-k payloads across the
    node axis.

    With ``idkd_cfg.stream_labels`` (the default) the round is
    *streaming* (DESIGN.md §8): the public corpus goes through
    ``labeling.streaming_label_round`` / ``shard_streaming_label_round``
    in ``stream_microbatch``-sized chunks of the fused head-select pass,
    so the (n, P, S, V) public logit stack — the dominant HBM cost of a
    round at LLM vocab — never materializes. ``stream_labels=False``
    keeps the one-shot oracle path.
    """
    pub = jnp.asarray(public_tokens)
    priv = jnp.asarray(private_tokens)                      # (n, Vp, S)
    # multi-codebook heads (MusicGen) have no single (d, V) unembedding
    # for head_select to tile — they keep the one-shot path
    streamable = getattr(model.cfg, "num_codebooks", 0) <= 1
    if idkd_cfg.stream_labels and streamable \
            and backend in ("fused", "sparse"):
        if mesh is not None:
            if active is not None:
                raise ValueError("sharded label rounds have no churn "
                                 "path; run churn schedules node-stacked")
            out = labeling.shard_streaming_label_round(
                model, params_stacked, pub, priv, topology, idkd_cfg,
                mesh=mesh)
        else:
            out = labeling.streaming_label_round(
                model, params_stacked, pub, priv, topology, idkd_cfg,
                active=active)
        return out.labels, out.weights, out.id_masks, out.thresholds

    n = params_stacked and jax.tree.leaves(params_stacked)[0].shape[0]

    @jax.jit
    def node_logits(p, toks):
        return jax.vmap(lambda pp, tt: model.forward(pp, {"tokens": tt})[0]
                        )(p, toks)

    pub_b = jnp.broadcast_to(pub[None], (n,) + pub.shape)
    logits_pub = node_logits(params_stacked, pub_b)        # (n, P, S, V)
    logits_priv = node_logits(params_stacked, priv)
    # val = the node's private corpus (ID); cal=None = the public corpus
    if mesh is not None:
        if active is not None:
            raise ValueError("sharded label rounds have no churn path; "
                             "run churn schedules node-stacked")
        out = labeling.shard_label_round(logits_pub, logits_priv,
                                         topology, idkd_cfg, mesh=mesh)
    else:
        out = labeling.label_round(logits_pub, logits_priv, None,
                                   topology, idkd_cfg, backend=backend,
                                   active=active)
    return out.labels, out.weights, out.id_masks, out.thresholds


class _LMFederation(sched.CompiledFederationHooks):
    """Scheduler hooks for the LM launch path: plain and sparse-KD steps
    per (graph, availability mask), labeling rounds refreshing the KD
    sampler ctx, per-round label byte accounting (cache machinery lives
    on :class:`sched.CompiledFederationHooks`)."""

    def __init__(self, *, model, algo, tcfg: TrainConfig,
                 idkd_cfg: IDKDConfig, cfg: ModelConfig, tokens, parts,
                 public_tokens, seq_len: int, wire_dtype: str,
                 driver_mode: str, verbose: bool, model_parallel: int = 1):
        super().__init__()
        self.model_parallel = model_parallel
        self.model = model
        self.algo = algo
        self.tcfg = tcfg
        self.idkd_cfg = idkd_cfg
        self.cfg = cfg
        self.tokens = tokens
        self.parts = parts
        self.public_tokens = public_tokens
        self.seq_len = seq_len
        self.wire_dtype = wire_dtype
        self.driver_mode = driver_mode
        self.verbose = verbose
        self.lr_fn = lambda s: jnp.asarray(tcfg.lr, jnp.float32)
        self.priv_parts = driver.pad_partitions(parts)
        self.plain_sampler = driver.make_lm_sampler(
            self.priv_parts, tokens, tcfg.batch_size)
        self.kd_sampler = None
        # compressed-wire spec ((kind, frac) or None) read off the config;
        # self.gossip is overwritten from the schedule by init_comm
        self.compression = tcfg.compression_spec

    def _make_mixer(self, topo: Topology, active, stale=None):
        return make_gossip_mixer(self.tcfg, self.wire_dtype,
                                 topology=topo, active=active, stale=stale,
                                 **self._mixer_opts())[1]

    def _adapter(self):
        return (driver.lm_adapter if self.phase == "plain"
                else driver.lm_sparse_kd_adapter(self.idkd_cfg))

    def _sampler(self):
        return (self.plain_sampler if self.phase == "plain"
                else self.kd_sampler)

    def restore_ctx(self, ctx: Dict, phase: str) -> None:
        """Mid-phase resume from a durable snapshot: rebuild the sparse
        LM-KD sampler from the snapshot's flat ctx payload instead of
        re-running the label round."""
        ctx = {k: jnp.asarray(v) for k, v in ctx.items()}
        self.ctx = ctx
        if self.kd_sampler is None:
            self.kd_sampler = driver.make_lm_kd_sampler(
                self.priv_parts, self.tokens, self.tcfg.batch_size,
                self.public_tokens, ctx["pub_vals"], ctx["pub_idx"],
                ctx["pub_w"], pub_batch=min(4, len(self.public_tokens)))
        self.phase = phase

    def on_round(self, params, round_index: int, step: int, topo: Topology,
                 active: np.ndarray) -> np.ndarray:
        cfg = self.idkd_cfg
        n = self.tcfg.num_nodes
        m_priv = max(1, min(16, min(len(p) for p in self.parts)))
        priv = np.stack([self.tokens[self.parts[i][:m_priv], :self.seq_len]
                         for i in range(n)])
        backend = cfg.label_backend
        if backend not in ("fused", "sparse"):
            # the LM KD step consumes sparse payloads; the dense
            # oracle backend is not an option at vocab scale
            obs_log.warning("idkd.backend_fallback", requested=backend,
                            using="sparse")
            backend = "sparse"
        sparse, w, id_mask, thr = idkd_label_round(
            self.model, params, self.public_tokens, priv, cfg, topo,
            backend=backend, active=None if active.all() else active,
            mesh=(self.shard_mesh(n) if self.driver_mode == "shard"
                  else None))
        self.ctx = driver.lm_kd_ctx(sparse.values, sparse.indices, w)
        if self.kd_sampler is None:
            self.kd_sampler = driver.make_lm_kd_sampler(
                self.priv_parts, self.tokens, self.tcfg.batch_size,
                self.public_tokens, sparse.values, sparse.indices, w,
                pub_batch=min(4, len(self.public_tokens)))
        self.phase = "kd"
        id_fraction = float(np.asarray(id_mask).mean())
        counts = np.asarray(id_mask).sum(axis=1)
        if self.verbose:
            obs_log.info("idkd.round", step=step, round=round_index,
                         id_fraction=round(id_fraction, 4),
                         thresholds=np.asarray(thr).round(3).tolist())
        # telemetry: run_schedule forwards this to on_labels + the
        # "labels" run-log event right after on_round returns
        mean_ov, per_edge = labeling.neighbor_topk_overlap(
            np.asarray(sparse.indices), topo)
        self.last_round_stats = {
            "thresholds": np.asarray(thr), "selected": counts,
            "id_fraction": id_fraction, "detector": cfg.detector,
            "topk_overlap": mean_ov, "topk_overlap_per_edge": per_edge}
        k_wire = min(cfg.label_topk or labeling.DEFAULT_TOPK,
                     self.cfg.vocab_size)
        return np.array([distill.label_bytes(int(c) * self.seq_len,
                                             self.cfg.vocab_size, k_wire)
                         for c in counts], np.float64)


def run_training(cfg: ModelConfig, tcfg: TrainConfig, *, seq_len: int = 64,
                 n_seqs: int = 512, n_public: int = 64, log_every: int = 10,
                 use_idkd: bool = False, verbose: bool = True,
                 wire_dtype: str = "native", driver_mode: str = "scan",
                 events: Sequence = (),
                 schedule: Optional[sched.Schedule] = None,
                 model_parallel: int = 1,
                 telemetry=None, resil=None) -> Dict[str, Any]:
    """End-to-end reduced-scale decentralized LM training (CPU-friendly).

    ``events`` (churn / rewire) and a custom ``schedule`` feed the
    federation scheduler; by default the schedule is compiled from
    ``tcfg`` (log boundaries + the IDKD rounds ``tcfg.idkd`` asks for).
    ``model_parallel > 1`` (shard driver only) runs each replica sharded
    over the second (``"model"``) axis of the 2-D federation mesh
    (DESIGN.md §10): FSDP-style parameter/optimizer sharding,
    vocab-sharded streaming label rounds, node-axis-only gossip.

    ``telemetry`` (a :class:`repro.obs.Telemetry`) turns on the run-log /
    metrics-bus / trace-span layers for this run (DESIGN.md §11); the
    trajectory is bitwise identical with it on or off.

    ``resil`` (a :class:`repro.resil.Resilience`) turns on the
    resilience layer (DESIGN.md §12): health guards + quarantine,
    durable snapshots with auto-resume, rollback-on-divergence. A
    ``crash`` FaultEvent in the schedule raises
    :class:`repro.resil.SimulatedCrash` out of this function — rerun
    with the same ``resil.snapshot_dir`` to resume.
    """
    n = tcfg.num_nodes
    model = build_model(cfg)
    # the one graph params-gossip and the label exchange share; the hooks
    # build (and cache) the actual mixers per availability mask
    topo = Topology.make(tcfg.topology, tcfg.num_nodes)
    algo = make_algorithm(tcfg.algorithm, momentum=tcfg.momentum,
                          weight_decay=tcfg.weight_decay)
    tokens, topics = make_lm_data(cfg.vocab_size, seq_len + 1, n_seqs,
                                  seed=tcfg.seed)
    parts = dirichlet_partition(topics, n, tcfg.alpha,
                                np.random.default_rng(tcfg.seed))
    public_tokens, _ = make_lm_data(cfg.vocab_size, seq_len, n_public,
                                    num_topics=10, seed=tcfg.seed + 99)
    params = stack_params(model.init(jax.random.PRNGKey(tcfg.seed)), n)
    idkd_cfg = tcfg.idkd or IDKDConfig(label_topk=8)

    kd_fires = use_idkd and 0 <= idkd_cfg.start_step < tcfg.steps
    if schedule is None:
        rounds = (sched.idkd_round_steps(idkd_cfg, tcfg.steps)
                  if kd_fires else ())
        schedule = sched.compile_schedule(tcfg.steps, log_every,
                                          round_steps=rounds, events=events,
                                          gossip=tcfg.gossip)
    elif events:
        raise ValueError("pass events to compile_schedule, not alongside "
                         "a prebuilt schedule")
    if schedule.gossip != tcfg.gossip:
        raise ValueError(
            f"schedule gossip mode {schedule.gossip!r} disagrees with "
            f"TrainConfig.gossip={tcfg.gossip!r}; pass gossip= to "
            "compile_schedule (or drop the prebuilt schedule)")
    if schedule.round_steps and not use_idkd:
        raise ValueError("schedule contains homogenization rounds but "
                         "use_idkd=False")

    if model_parallel != 1 and driver_mode != "shard":
        raise ValueError("model_parallel > 1 shards each replica over "
                         "the 2-D federation mesh and needs "
                         "driver_mode='shard' (DESIGN.md §10)")
    fed = _LMFederation(model=model, algo=algo, tcfg=tcfg,
                        idkd_cfg=idkd_cfg, cfg=cfg, tokens=tokens,
                        parts=parts, public_tokens=public_tokens,
                        seq_len=seq_len, wire_dtype=wire_dtype,
                        driver_mode=driver_mode, verbose=verbose,
                        model_parallel=model_parallel)
    opt_state = algo.init(params)
    key = jax.random.PRNGKey(tcfg.seed + 1)

    if driver_mode == "shard":
        # shard-mode pre-flight: fail before training, not mid-schedule
        from repro.core.mixing import shard_supported_topology
        from repro.launch.sharding import federation_shardings
        if wire_dtype != "native":
            raise ValueError("driver_mode='shard' moves shards in their "
                             f"storage dtype; wire_dtype={wire_dtype!r} "
                             "needs the node-stacked runners")
        if not shard_supported_topology(topo):
            raise ValueError(
                f"driver_mode='shard' gossips on ring/complete graphs "
                f"only; topology {topo.name!r} needs driver_mode="
                "'scan' or 'host'")
        sched.validate_shard_schedule(schedule, n, model_parallel)
        mesh = fed.shard_mesh(n)
        params = jax.device_put(
            params, federation_shardings(params, mesh, n))
        opt_state = jax.device_put(
            opt_state, federation_shardings(opt_state, mesh, n))

    nparams = sum(x.size for x in jax.tree.leaves(params)) // n
    comp = normalize_compression(tcfg.compression_spec)
    payload_elems = (payload_elem_count(params, comp, node_stacked=True)
                     if comp is not None else None)
    index_bytes = 4 if comp is not None else 0
    comp_kind, comp_frac = comp if comp is not None else ("none", 0.0)
    ledger = sched.CommLedger(n, meta={
        "topology": topo.name, "wire_dtype": wire_dtype,
        "param_count": int(nparams),
        "compression": comp_kind, "compression_frac": comp_frac,
        "gossip": schedule.gossip})

    history = []
    t0 = time.time()

    def on_eval(params, step, losses):
        history.append(float(losses[-1]))
        if verbose:
            obs_log.info("train.eval", step=step,
                         loss=round(history[-1], 4),
                         elapsed_s=round(time.time() - t0, 1))

    fed.on_eval = on_eval
    params, opt_state, key, _ = sched.run_schedule(
        schedule, fed, params, opt_state, key, topology=topo,
        ledger=ledger, param_count=int(nparams),
        elem_bytes=sched.wire_elem_bytes(wire_dtype, cfg.dtype),
        payload_elems=payload_elems, index_bytes=index_bytes,
        telemetry=telemetry, resil=resil)
    return {"params": consensus_params(params), "loss_history": history,
            "model": model, "topology": topo, "ledger": ledger.as_dict(),
            "schedule": schedule}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--idkd", action="store_true")
    ap.add_argument("--rounds", type=int, default=1,
                    help="IDKD homogenization rounds (spaced every-k)")
    ap.add_argument("--every-k", type=int, default=0,
                    help="steps between rounds (default: fit them evenly "
                         "into the post-start span)")
    ap.add_argument("--churn", default="",
                    help="churn spec node@down-up[,...], e.g. 3@20-30")
    ap.add_argument("--churn-mode", default="freeze",
                    choices=list(sched.CHURN_MODES),
                    help="what --churn means: freeze (hold params), "
                         "isolate (train but no gossip), or stale "
                         "(straggler — neighbours mix its last payload)")
    ap.add_argument("--wire-dtype", default="native",
                    choices=["native", "float32"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "randk"],
                    help="gossip wire compression (DESIGN.md §9)")
    ap.add_argument("--compression-frac", type=float, default=0.01,
                    help="fraction of each leaf kept per send (top-k / "
                         "random-k)")
    ap.add_argument("--gossip", default="sync",
                    choices=list(sched.GOSSIP_MODES),
                    help="sync mixes this step's params; delayed mixes "
                         "the previous step's payload (one-step-stale)")
    ap.add_argument("--driver", default="scan",
                    choices=["scan", "host", "shard"])
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="width of the 2-D federation mesh's 'model' "
                         "axis (shard driver only): each replica's "
                         "params/optimizer shard over this many devices "
                         "while gossip stays node-axis-only "
                         "(DESIGN.md §10)")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config — TPU scale")
    ap.add_argument("--telemetry", default="", metavar="DIR",
                    help="write run.jsonl (events + metrics-bus flushes) "
                         "under DIR (DESIGN.md §11); off when empty")
    ap.add_argument("--trace", action="store_true",
                    help="also export Chrome trace_event spans to "
                         "DIR/trace.json (Perfetto-loadable; needs "
                         "--telemetry)")
    ap.add_argument("--faults", default="", metavar="SPEC",
                    help="deterministic fault injection: comma-separated "
                         "kind@step[/nodes][/mode] events, e.g. "
                         "'corrupt@8/2/nan,crash@14,clear@16' "
                         "(DESIGN.md §12)")
    ap.add_argument("--guards", action="store_true",
                    help="turn on the on-device health guard: non-finite "
                         "loss/grad/param detection + wire validation, "
                         "tripped nodes quarantined at the segment "
                         "boundary")
    ap.add_argument("--snapshot-dir", default="", metavar="DIR",
                    help="write durable checkpointed snapshots under DIR "
                         "at segment boundaries; if DIR already holds "
                         "snapshots the run auto-resumes from the newest "
                         "valid one")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="min steps between durable snapshots (0 = every "
                         "segment boundary)")
    ap.add_argument("--rollback", action="store_true",
                    help="on a guard trip, restore the pre-segment state "
                         "and re-run with the offender quarantined "
                         "(implies --guards)")
    args = ap.parse_args()
    if args.trace and not args.telemetry:
        ap.error("--trace needs --telemetry DIR for the output location")
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    start = args.steps // 2
    every_k = args.every_k or sched.fit_every_k(args.steps, start,
                                                args.rounds)
    tcfg = TrainConfig(num_nodes=args.nodes, steps=args.steps, lr=0.1,
                       alpha=args.alpha, batch_size=8,
                       topology=args.topology,
                       compression=args.compression,
                       compression_frac=args.compression_frac,
                       gossip=args.gossip,
                       idkd=IDKDConfig(start_step=start, label_topk=8,
                                       every_k_steps=every_k,
                                       num_rounds=args.rounds))
    events = (sched.parse_churn(args.churn, args.nodes, args.steps,
                                mode=args.churn_mode)
              if args.churn else ())
    if args.faults:
        events = (*events, *sched.parse_faults(args.faults, args.nodes,
                                               args.steps))
    resil = None
    if args.guards or args.rollback or args.snapshot_dir:
        from repro.resil import GuardSpec, Resilience
        resil = Resilience(
            guard=(GuardSpec() if args.guards or args.rollback else None),
            snapshot_dir=args.snapshot_dir or None,
            snapshot_every=args.snapshot_every,
            rollback=args.rollback)
    telemetry = None
    if args.telemetry:
        from repro.obs import Telemetry
        telemetry = Telemetry(args.telemetry, trace=args.trace,
                              meta={"arch": args.arch, "steps": args.steps,
                                    "nodes": args.nodes,
                                    "topology": args.topology,
                                    "driver": args.driver,
                                    "idkd": args.idkd})
    try:
        out = run_training(cfg, tcfg, use_idkd=args.idkd,
                           wire_dtype=args.wire_dtype,
                           driver_mode=args.driver, events=events,
                           model_parallel=args.model_parallel,
                           telemetry=telemetry, resil=resil)
    except SimulatedCrash as e:
        # injected crash: a clean exit so harnesses (the CI chaos job)
        # can re-invoke with the same --snapshot-dir and auto-resume
        obs_log.warning("simulated_crash_exit", step=e.step,
                        snapshot_dir=args.snapshot_dir or None)
        print(f"simulated crash at step {e.step}; re-run with the same "
              "--snapshot-dir to resume from the last durable snapshot")
        return
    finally:
        if telemetry is not None:
            telemetry.close()
    print(f"final loss: {out['loss_history'][-1]:.4f}")
    led = out["ledger"]
    print(f"comm ledger: {led['gossip_bytes']/1e6:.2f} MB gossip + "
          f"{led['label_bytes']/1e6:.3f} MB labels over "
          f"{len(led['per_round'])} round bucket(s)")
    if args.telemetry:
        print(f"telemetry: {args.telemetry}/run.jsonl"
              + (f" + {args.telemetry}/trace.json" if args.trace else ""))


if __name__ == "__main__":
    main()
