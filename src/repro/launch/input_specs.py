"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``train_specs``  → node-stacked batch {tokens, labels, [frontend stubs]}.
``prefill_specs``→ request batch for one prefill.
``decode_specs`` → (tokens, decode state[, conditioning]) for one decode
                   step against a ``shape.seq_len``-token cache.

Modality frontends are stubs per the assignment: the VLM's SigLIP tower is
represented by precomputed patch embeddings, MusicGen's EnCodec/T5 by token
streams + conditioning embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def _token_shape(cfg: ModelConfig, batch: int, seq: int) -> Tuple[int, ...]:
    if cfg.num_codebooks > 1:
        return (batch, seq, cfg.num_codebooks)
    return (batch, seq)


def _frontend_specs(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict[str, Any]:
    """Stubbed modality-frontend inputs (batch dims prefixed by ``lead``)."""
    out = {}
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        out["patch_embeddings"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_prefix_tokens, cfg.d_model), dt)
    if cfg.cross_attention:
        out["conditioning"] = jax.ShapeDtypeStruct(
            lead + (cfg.cross_attn_len, cfg.d_model), dt)
    return out


def train_specs(cfg: ModelConfig, shape: ShapeConfig, num_nodes: int
                ) -> Dict[str, Any]:
    assert shape.mode == "train"
    per_node = shape.global_batch // num_nodes
    assert per_node * num_nodes == shape.global_batch, \
        f"global_batch {shape.global_batch} not divisible by {num_nodes} nodes"
    tok = _token_shape(cfg, per_node, shape.seq_len)
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((num_nodes,) + tok, jnp.int32),
        "labels": jax.ShapeDtypeStruct((num_nodes,) + tok, jnp.int32),
    }
    specs.update(_frontend_specs(cfg, (num_nodes, per_node)))
    return specs


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    tok = _token_shape(cfg, shape.global_batch, shape.seq_len)
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct(tok, jnp.int32)}
    specs.update(_frontend_specs(cfg, (shape.global_batch,)))
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, model
                 ) -> Tuple[Any, Any, Tuple[Any, ...]]:
    """Returns (tokens_spec, state_spec, extras) for one decode step with a
    ``shape.seq_len`` context."""
    B = shape.global_batch
    tok = jax.ShapeDtypeStruct(_token_shape(cfg, B, 1), jnp.int32)
    state = jax.eval_shape(
        lambda: model.init_decode_state(B, shape.seq_len))
    extras = ()
    if cfg.cross_attention:
        extras = (jax.ShapeDtypeStruct(
            (B, cfg.cross_attn_len, cfg.d_model), jnp.dtype(cfg.dtype)),)
    return tok, state, extras


def params_specs(model) -> Any:
    """Abstract (un-stacked) parameter shapes — no allocation."""
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def stacked_params_specs(model, num_nodes: int) -> Any:
    base = params_specs(model)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((num_nodes,) + s.shape, s.dtype), base)
