"""Jit-compatible distributed step functions.

``make_train_step`` builds the decentralized QG-DSGDm-N training step on
node-stacked params: per-node fwd/bwd via vmap (the node axis is sharded
over the mesh's gossip axes, so "vmap over nodes" is SPMD across node
groups), then ring-gossip mixing expressed as ``jnp.roll`` along the node
axis — which XLA lowers to ``collective-permute`` between neighbouring
node groups. **No cross-node all-reduce of gradients exists in the HLO**:
that is the decentralized point (verified by tests/test_dryrun_small.py).

``make_prefill_step`` / ``make_decode_step`` serve the consensus model.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.algorithms import make_algorithm


def make_ring_mixer(num_nodes: int, wire_dtype: str = "native"):
    """Gossip mixing on node-stacked pytrees via rolls (→ ppermute).

    Metropolis weights for a ring: 1/3 self + 1/3 each neighbour
    (n == 2 degenerates to 1/2, 1/2; n == 1 to identity).

    ``wire_dtype`` controls what goes over the ICI links:
      * "native"  — roll the parameter in its storage dtype (bf16 params →
        bf16 ppermute traffic), accumulate the weighted sum in f32.
        §Perf iteration 1: halves gossip bytes vs the f32 wire.
      * "float32" — upcast before the roll (paper-faithful full-precision
        mixing; the baseline recorded in EXPERIMENTS.md)."""
    if num_nodes <= 1:
        return lambda t: t

    def mix(tree):
        def leaf(x):
            xw = x.astype(jnp.float32) if wire_dtype == "float32" else x
            fwd = jnp.roll(xw, 1, axis=0).astype(jnp.float32)
            if num_nodes == 2:
                y = 0.5 * x.astype(jnp.float32) + 0.5 * fwd
            else:
                bwd = jnp.roll(xw, -1, axis=0).astype(jnp.float32)
                y = (x.astype(jnp.float32) + fwd + bwd) / 3.0
            return y.astype(x.dtype)
        return jax.tree.map(leaf, tree)

    return mix


def stack_params(params, num_nodes: int):
    """Replicate a single-model pytree into node-stacked form."""
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (num_nodes,) + t.shape), params)


def consensus_params(stacked):
    """Node-average (the model the paper evaluates)."""
    return jax.tree.map(
        lambda t: jnp.mean(t.astype(jnp.float32), axis=0).astype(t.dtype),
        stacked)


def make_train_step(model, tcfg: TrainConfig, num_nodes: int,
                    wire_dtype: str = "native") -> Callable:
    algo = make_algorithm(tcfg.algorithm, momentum=tcfg.momentum,
                          weight_decay=tcfg.weight_decay)
    mixer = make_ring_mixer(num_nodes, wire_dtype)

    def node_loss(p, batch):
        loss, _ = model.loss(p, batch)
        return loss

    def train_step(params, opt_state, batch, lr):
        """params/opt_state: node-stacked pytrees; batch: (N, B, ...)."""
        losses, grads = jax.vmap(jax.value_and_grad(node_loss))(params, batch)
        params, opt_state = algo.step(params, grads, opt_state, lr, mixer)
        return params, opt_state, {"loss": jnp.mean(losses)}

    def init_opt(params):
        return algo.init(params)

    train_step.init_opt = init_opt
    return train_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, tokens, state, *extras):
        memory = extras[0] if extras else None
        logits, new_state = model.decode_step(params, tokens, state,
                                              memory=memory)
        return logits, new_state

    return decode_step
