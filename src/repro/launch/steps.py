"""Jit-compatible distributed step functions.

``make_train_step`` is a thin wrapper over the unified driver
(``core.driver.make_step`` with the LM loss adapter): per-node fwd/bwd via
vmap on node-stacked params (the node axis is sharded over the mesh's
gossip axes, so "vmap over nodes" is SPMD across node groups), then
topology gossip from ``core.mixing.make_mixer`` — on the default ring this
is ``jnp.roll`` along the node axis, which XLA lowers to
``collective-permute`` between neighbouring node groups. **No cross-node
all-reduce of gradients exists in the HLO**: that is the decentralized
point (verified by tests/test_dryrun_small.py).

``make_prefill_step`` / ``make_decode_step`` serve the consensus model.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.algorithms import make_algorithm
from repro.core.driver import lm_adapter, make_step
from repro.core.mixing import make_mixer
from repro.core.topology import Topology
from repro.obs import log as obs_log


def stack_params(params, num_nodes: int):
    """Replicate a single-model pytree into node-stacked form."""
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (num_nodes,) + t.shape), params)


def consensus_params(stacked):
    """Node-average (the model the paper evaluates)."""
    return jax.tree.map(
        lambda t: jnp.mean(t.astype(jnp.float32), axis=0).astype(t.dtype),
        stacked)


def make_train_step(model, tcfg: TrainConfig, num_nodes: int,
                    wire_dtype: str = "native") -> Callable:
    """Decentralized LM train step on ``tcfg.topology`` (metrics-dict
    contract kept for dryrun/serve; new code uses ``core.driver``)."""
    algo = make_algorithm(tcfg.algorithm, momentum=tcfg.momentum,
                          weight_decay=tcfg.weight_decay)
    mixer = make_mixer(Topology.make(tcfg.topology, num_nodes),
                       wire_dtype=wire_dtype)
    obs_log.debug("steps.make_train_step", algorithm=tcfg.algorithm,
                  topology=tcfg.topology, nodes=num_nodes,
                  wire_dtype=wire_dtype)
    inner = make_step(model, algo, mixer, lm_adapter)

    def train_step(params, opt_state, batch, lr):
        """params/opt_state: node-stacked pytrees; batch: (N, B, ...)."""
        params, opt_state, loss = inner(params, opt_state, batch, lr)
        return params, opt_state, {"loss": loss}

    train_step.init_opt = inner.init_opt
    return train_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, tokens, state, *extras):
        memory = extras[0] if extras else None
        logits, new_state = model.decode_step(params, tokens, state,
                                              memory=memory)
        return logits, new_state

    return decode_step
