"""Sharding rules for node-stacked parameters and activations.

Parameters are node-stacked: leaf shape = (N, [L,] ...) where N is the
gossip-node axis and L the scanned-layer axis. Rules:

* node dim 0   → the node mesh axes (('pod','data') for replica scope,
                 ('pod',) for pod scope).
* 'experts'    → expert dim over 'model' (expert parallelism).
* other ≥2D weights → 'model' on the largest trailing dim divisible by the
                 axis size (Megatron-style TP: column for wi/wq, row for wo);
                 pod scope additionally shards another trailing dim over
                 'data' (FSDP) when divisible.
* small leaves (biases, norm scales, 1-trailing-dim) → replicated beyond
  the node axis.

These are the *baseline* rules; §Perf iterates on them.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _divisible(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


def node_leaf_spec(leaf, num_nodes: int, axis: str = "node") -> P:
    """PartitionSpec for one leaf of a node-stacked pytree under the
    sharded driver's 1-D node mesh: the leading node axis shards over
    ``axis``; everything else (scalar optimizer counters, per-sample
    payloads without a node dim) replicates."""
    if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == num_nodes:
        return P(axis)
    return P()


def node_stacked_specs(tree, num_nodes: int, axis: str = "node"):
    """Per-leaf PartitionSpec pytree for ``shard_map`` in/out_specs."""
    return jax.tree.map(
        lambda leaf: node_leaf_spec(leaf, num_nodes, axis), tree)


def node_stacked_shardings(tree, mesh, num_nodes: int, axis: str = "node"):
    """NamedSharding pytree for ``jax.device_put`` of node-stacked state
    (params / optimizer state / sampler ctx) onto the node mesh."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh,
                                   node_leaf_spec(leaf, num_nodes, axis)),
        tree)


def _param_skip_dims(path: str, ndim: int) -> int:
    """Leading dims of a node-stacked param leaf that are NOT shardable
    weight dims: dim 0 is the node axis; scanned-layer stacking
    (``layers_*`` subtrees, stacked codebooks) adds one more."""
    skip = 2 if ("layers_" in path or "embed_cb" in path
                 or ("head" in path and ndim > 3)) else 1
    return min(skip, max(ndim - 1, 1))


def federation_specs(tree, num_nodes: int, mesh, axis: str = "node"):
    """Per-leaf PartitionSpec pytree for the 2-D federation mesh
    (``("node", "model")``, from ``make_federation_mesh``).

    Node-stacked leaves (leading dim == num_nodes) put dim 0 on the node
    axis and — when the mesh has a non-trivial ``"model"`` axis — shard
    the largest divisible trailing weight dim over ``"model"`` (FSDP-
    style storage; for embedding/LM-head leaves the vocab dim is the
    largest, so they come out vocab-sharded). Scalars, norms, biases and
    non-node-stacked leaves replicate beyond the node axis. On a 1-D
    node mesh this reduces exactly to ``node_stacked_specs``.
    """
    model_size = dict(mesh.shape).get("model", 1)
    if model_size <= 1:
        return node_stacked_specs(tree, num_nodes, axis)

    def one(path, leaf):
        if getattr(leaf, "ndim", 0) < 1 or leaf.shape[0] != num_nodes:
            return P()
        ps = _path_str(path)
        return leaf_spec(ps, leaf.shape, mesh, (axis,), "replica",
                         skip_dims=_param_skip_dims(ps, len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, tree)


def federation_shardings(tree, mesh, num_nodes: int, axis: str = "node"):
    """NamedSharding pytree for ``jax.device_put`` of node-stacked state
    onto the (1-D or 2-D) federation mesh."""
    specs = federation_specs(tree, num_nodes, mesh, axis)
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), specs,
                        is_leaf=lambda s: isinstance(s, P))


def spec_model_dim(spec: P) -> Optional[int]:
    """Index of the dim a PartitionSpec shards over ``"model"`` (None if
    the leaf is model-replicated)."""
    for i, s in enumerate(spec):
        if s == "model" or (isinstance(s, tuple) and "model" in s):
            return i
    return None


def gather_model_tree(tree, specs, axis: str = "model"):
    """Inside ``shard_map``: all-gather every model-sharded leaf back to
    full width along its sharded dim (tiled, so the result is the
    unsharded leaf). Model-replicated leaves pass through untouched."""
    def one(x, spec):
        d = spec_model_dim(spec)
        if d is None:
            return x
        return jax.lax.all_gather(x, axis, axis=d, tiled=True)
    return jax.tree.map(one, tree, specs,
                        is_leaf=lambda s: isinstance(s, P))


def slice_model_tree(tree, specs, model_size: int, axis: str = "model"):
    """Inside ``shard_map``: slice full-width leaves back down to this
    model-shard's slice — the inverse of :func:`gather_model_tree`."""
    idx = jax.lax.axis_index(axis)

    def one(x, spec):
        d = spec_model_dim(spec)
        if d is None:
            return x
        width = x.shape[d] // model_size
        return jax.lax.dynamic_slice_in_dim(x, idx * width, width, axis=d)
    return jax.tree.map(one, tree, specs,
                        is_leaf=lambda s: isinstance(s, P))


def leaf_spec(path: str, shape: Tuple[int, ...], mesh, node_axes,
              scope: str, skip_dims: int = 1) -> P:
    """PartitionSpec for one node-stacked param leaf.

    ``skip_dims``: leading dims that are NOT shardable weight dims —
    dim 0 is the node axis; scanned-layer stacking adds one more
    (callers pass 2 for layers_* subtrees).
    """
    model_size = mesh.shape["model"]
    data_size = mesh.shape.get("data", 1)
    spec: list = [None] * len(shape)
    if node_axes:
        spec[0] = node_axes if len(node_axes) > 1 else node_axes[0]
    trailing = list(range(skip_dims, len(shape)))
    if len(trailing) >= 2:
        if "experts" in path and len(trailing) >= 3:
            import os
            e_dim = trailing[0]
            both = (os.environ.get("REPRO_SHARD_EXPERTS") == "both"
                    and scope == "pod")  # 'data' is the node axis otherwise
            if both and _divisible(shape[e_dim], model_size * data_size):
                # §Perf variant: experts over model × data (1 expert/chip at
                # E=256 on a 256-chip pod) — no weight FSDP gathers, the
                # dispatch all-to-all spans the full pod.
                spec[e_dim] = ("data", "model")
            elif _divisible(shape[e_dim], model_size):
                spec[e_dim] = "model"
                # FSDP the expert weights' d_model dim in pod scope
                if scope == "pod" and _divisible(shape[trailing[1]],
                                                 data_size):
                    spec[trailing[1]] = "data"
        else:
            # 'model' on the largest divisible trailing dim
            cand = sorted(trailing, key=lambda i: -shape[i])
            m_dim = next((i for i in cand
                          if _divisible(shape[i], model_size)), None)
            if m_dim is not None:
                spec[m_dim] = "model"
            if scope == "pod":
                d_dim = next((i for i in cand
                              if i != m_dim and _divisible(shape[i],
                                                           data_size)), None)
                if d_dim is not None:
                    spec[d_dim] = "data"
    return P(*spec)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def param_shardings(params_shape, mesh, scope: str):
    """NamedSharding pytree for node-stacked params (from eval_shape)."""
    from repro.launch.mesh import node_axes_for
    node_axes = node_axes_for(mesh, scope)

    def one(path, leaf):
        ps = _path_str(path)
        skip = _param_skip_dims(ps, len(leaf.shape))
        return NamedSharding(mesh, leaf_spec(ps, leaf.shape, mesh, node_axes,
                                             scope, skip_dims=skip))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(batch_shape, mesh, scope: str):
    """Node-stacked batch (N, B, ...): node dim over node axes; pod scope
    additionally shards the per-node batch over 'data'."""
    from repro.launch.mesh import node_axes_for
    node_axes = node_axes_for(mesh, scope)

    def one(leaf):
        spec: list = [None] * len(leaf.shape)
        if node_axes:
            spec[0] = node_axes if len(node_axes) > 1 else node_axes[0]
        if scope == "pod" and len(leaf.shape) > 1 and \
                leaf.shape[1] % mesh.shape.get("data", 1) == 0 and \
                mesh.shape.get("data", 1) > 1:
            spec[1] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def serve_param_shardings(params_shape, mesh):
    """Serving uses the consensus model — no node axis; TP over 'model',
    FSDP over 'data' where divisible."""
    def one(path, leaf):
        ps = _path_str(path)
        skip = 1 if "layers_" in ps else 0
        skip = min(skip, max(len(leaf.shape) - 1, 0))
        spec = leaf_spec(ps, (1,) + tuple(leaf.shape), mesh, (), "pod",
                         skip_dims=skip + 1)
        return NamedSharding(mesh, P(*tuple(spec)[1:]))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def serve_batch_shardings(batch_shape, mesh):
    """Request batch: batch dim over ('pod','data') when divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def one(leaf):
        spec: list = [None] * len(leaf.shape)
        if leaf.shape and leaf.shape[0] % total == 0 and total > 1:
            spec[0] = axes if len(axes) > 1 else axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def serve_state_shardings(state_shape, mesh):
    """Decode caches: (L, B, cap, heads, dim) — B over data axes when
    divisible, head/state dims over 'model' when divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    model_size = mesh.shape["model"]

    def one(leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % total == 0 and total > 1:
            spec[1] = axes if len(axes) > 1 else axes[0]
        # shard a later dim over model (prefer the largest divisible)
        if len(shape) >= 3:
            cand = sorted(range(2, len(shape)), key=lambda i: -shape[i])
            m = next((i for i in cand if shape[i] % model_size == 0
                      and shape[i] >= model_size), None)
            if m is not None:
                spec[m] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, state_shape)
