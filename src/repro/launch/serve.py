"""Batched serving driver: continuous-batching decode over the consensus
model (the paper's deployment artifact is the node-averaged model).

On CPU this drives reduced configs; the production-mesh serve_step for
every arch × decode shape is proven by dryrun.py.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 8 --gen-len 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.obs import log as obs_log


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Static-batch server with slot reuse (continuous batching lite):
    finished slots are refilled from the queue between steps; decode state
    slots are reset by re-prefilling the incoming request's prompt."""

    def __init__(self, cfg: ModelConfig, batch_slots: int = 4,
                 context: int = 128, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.slots = batch_slots
        self.context = context
        self.state = self.model.init_decode_state(batch_slots, context)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self._decode = jax.jit(
            lambda p, t, s: self.model.decode_step(p, t, s))

    def _feed_prompt(self, slot: int, req: Request):
        """Prefill via decode steps on one slot (slot-wise isolation keeps
        the batch static; production prefill uses prefill_step)."""
        for tok in req.prompt:
            t = np.zeros((self.slots, 1), np.int32)
            t[slot, 0] = tok
            _, self.state = self._decode(self.params, jnp.asarray(t),
                                         self.state)

    def submit_all(self, requests: List[Request], greedy: bool = True
                   ) -> Dict[int, List[int]]:
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        # NOTE: per-slot sequential prefill is the CPU-reduced path; slots
        # share the decode step so state lengths must advance together.
        # We therefore run one request per slot wave.
        while queue or any(r is not None for r in self.active):
            wave = [queue.pop(0) if queue else None
                    for _ in range(self.slots)]
            self.state = self.model.init_decode_state(self.slots, self.context)
            # batched prefill: feed prompts in lockstep (pad with zeros)
            max_p = max((len(r.prompt) for r in wave if r), default=0)
            logits = None
            for i in range(max_p):
                t = np.zeros((self.slots, 1), np.int32)
                for s, r in enumerate(wave):
                    if r is not None and i < len(r.prompt):
                        t[s, 0] = r.prompt[i]
                logits, self.state = self._decode(self.params,
                                                  jnp.asarray(t), self.state)
            max_new = max((r.max_new for r in wave if r), default=0)
            cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1)) if \
                logits is not None else np.zeros(self.slots, np.int64)
            for step in range(max_new):
                t = cur.reshape(self.slots, 1).astype(np.int32)
                for s, r in enumerate(wave):
                    if r is not None and step < r.max_new:
                        r.generated.append(int(cur[s]))
                logits, self.state = self._decode(self.params,
                                                  jnp.asarray(t), self.state)
                cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for r in wave:
                if r is not None:
                    r.done = True
                    results[r.rid] = r.generated
            self.active = [None] * self.slots
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    if cfg.num_codebooks > 1 or cfg.arch_type in ("vlm",):
        raise SystemExit("serve driver demo targets token-only archs")
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len
                                    ).astype(np.int32), args.gen_len)
            for i in range(args.requests)]
    t0 = time.time()
    server = BatchedServer(cfg, batch_slots=args.slots)
    out = server.submit_all(reqs)
    dt = time.time() - t0
    total_toks = sum(len(v) for v in out.values())
    obs_log.info("serve.done", requests=len(out), tokens=total_toks,
                 elapsed_s=round(dt, 1),
                 tok_per_s=round(total_toks / dt, 1))
    for rid in sorted(out)[:3]:
        obs_log.info("serve.req", rid=rid, head=list(out[rid][:10]))


if __name__ == "__main__":
    main()
