"""Config system for the IDKD framework.

Two layers of configuration:

* :class:`ModelConfig` — a single composable description that can express
  every assigned architecture family (dense / MoE / SSM / hybrid / VLM /
  audio) plus the paper's own ResNet20-EvoNorm classifier.
* :class:`ShapeConfig` — one of the four assigned input shapes
  (train_4k / prefill_32k / decode_32k / long_500k).

Configs are plain frozen dataclasses so they hash, print, and diff cleanly;
``reduced()`` derives the CPU smoke-test variant of any full config
(≤2 layers, d_model ≤ 512, ≤4 experts) required by the assignment.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (GShard-style top-k routing)."""

    num_experts: int = 0                # routed experts
    num_experts_per_tok: int = 0        # top-k
    moe_d_ff: int = 0                   # per-expert hidden width
    num_shared_experts: int = 0         # DeepSeek-style always-on experts
    dense_residual_ff: int = 0          # Arctic-style parallel dense MLP
    first_k_dense: int = 0              # leading dense layers (DeepSeek: 3)
    capacity_factor: float = 1.25
    router_type: str = "softmax"        # "softmax" | "sigmoid" (DeepSeek-v3)
    router_aux_coef: float = 0.01       # load-balance aux loss weight
    dispatch_groups: int = 1            # §Perf: GShard-style local dispatch
                                        # groups (= data shards). A global
                                        # argsort is unshardable — GSPMD
                                        # all-gathers every token; per-group
                                        # sorting keeps dispatch local and
                                        # turns the traffic into all-to-alls

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 0                # 0 => full-rank q projection
    kv_lora_rank: int = 0               # 0 => MLA disabled
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer sub-config."""

    state_size: int = 0                 # N (d_state)
    head_dim: int = 64                  # P
    expand: int = 2                     # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256               # SSD chunk length
    ngroups: int = 1                    # B/C groups (GVA-style)
    split_proj: bool = False            # §Perf: split the fused in-proj into
                                        # per-stream (z/x/B/C/dt) projections
                                        # so every output dim is individually
                                        # TP-shardable (no re-gather at the
                                        # fused-tensor split points)

    @property
    def enabled(self) -> bool:
        return self.state_size > 0


@dataclass(frozen=True)
class ModelConfig:
    """A composable decoder-stack description covering all assigned archs."""

    name: str = "model"
    arch_type: str = "dense"            # dense|moe|ssm|hybrid|vlm|audio|cnn
    source: str = ""                    # citation for the config numbers

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                   # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0             # 0 => full attention
    global_attn_every: int = 0          # hybrid SWA: every k-th layer global
    prefix_lm_prefix: int = 0           # bidirectional prefix length (VLM)
    cross_attention: bool = False       # audio: cross-attend to conditioning
    cross_attn_len: int = 0             # conditioning sequence length

    # MLP
    mlp_type: str = "swiglu"            # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    norm_in_f32: bool = True            # §Perf knob: f32 norm math makes XLA
                                        # hoist the convert above the TP
                                        # all-reduce (f32 wire); False keeps
                                        # the wire in bf16

    # embeddings / heads
    tie_embeddings: bool = False
    num_codebooks: int = 0              # audio: parallel codebook streams
    num_prefix_tokens: int = 0          # VLM patch / Hymba meta tokens
    mtp_depth: int = 0                  # DeepSeek multi-token-prediction

    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid_parallel: bool = False       # Hymba: attn ∥ SSM heads in-block

    # numerics / distribution
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"       # what jax.checkpoint saves per layer:
                                        # "nothing" (recompute all — min HBM),
                                        # "dots" (save matmul outputs — avoids
                                        # recomputing TP all-reduces),
                                        # "everything" (remat as a no-op).
                                        # "full" is a legacy alias of
                                        # "nothing".
    scan_layers: bool = True
    node_scope: str = "replica"         # gossip node = data replica | "pod"
                                        # ("pod" for models too large to hold
                                        #  per-replica parameters)
    use_pallas: bool = False            # TPU path; CPU uses the jnp oracle
    attn_chunk: int = 512               # chunked-attention KV block

    # CNN (paper-faithful ResNet repro) ------------------------------------
    cnn_stages: Tuple[int, ...] = ()    # blocks per stage, e.g. (3,3,3)
    cnn_width: int = 16
    image_size: int = 32
    image_channels: int = 3
    num_classes: int = 10
    conv_backend: str = "lax"           # "lax" | "im2col". im2col lowers
                                        # convs to patch-gather + matmul,
                                        # dodging the XLA:CPU conv
                                        # pathologies (vmapped kernels ~4x,
                                        # conv-in-while ~5x — DESIGN.md §5)
                                        # so conv models can opt into the
                                        # scan/shard runners on CPU

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True when decode state is sub-linear in context (long_500k ok)."""
        return self.ssm.enabled or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        head_dim = min(self.resolved_head_dim, 64)
        moe = self.moe
        if moe.enabled:
            moe = dataclasses.replace(
                moe,
                num_experts=min(moe.num_experts, 4),
                num_experts_per_tok=min(moe.num_experts_per_tok, 2),
                moe_d_ff=min(moe.moe_d_ff, 128),
                num_shared_experts=min(moe.num_shared_experts, 1),
                dense_residual_ff=min(moe.dense_residual_ff, 128),
                first_k_dense=min(moe.first_k_dense, 1),
            )
        mla = self.mla
        if mla.enabled:
            mla = dataclasses.replace(
                mla, q_lora_rank=min(mla.q_lora_rank, 64),
                kv_lora_rank=min(mla.kv_lora_rank, 32),
                qk_nope_head_dim=min(mla.qk_nope_head_dim, 32),
                qk_rope_head_dim=min(mla.qk_rope_head_dim, 16),
                v_head_dim=min(mla.v_head_dim, 32))
        ssm = self.ssm
        if ssm.enabled:
            ssm = dataclasses.replace(
                ssm, state_size=min(ssm.state_size, 16),
                head_dim=min(ssm.head_dim, 16), chunk_size=32)
        return self.replace(
            num_layers=min(self.num_layers, 2),
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            cross_attn_len=min(self.cross_attn_len, 8),
            mtp_depth=min(self.mtp_depth, 1),
            moe=moe, mla=mla, ssm=ssm,
            cnn_stages=tuple(min(b, 1) for b in self.cnn_stages),
            cnn_width=min(self.cnn_width, 8),
            image_size=min(self.image_size, 8),
            attn_chunk=64,
            dtype="float32",
            remat=False,
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for comm-cost + MODEL_FLOPS)."""
        if self.arch_type == "cnn":
            # rough resnet count: conv stacks + fc
            n = 3 * 3 * self.image_channels * self.cnn_width
            w = self.cnn_width
            for si, blocks in enumerate(self.cnn_stages):
                wo = self.cnn_width * (2 ** si)
                for b in range(blocks):
                    wi = w if b == 0 else wo
                    n += 9 * wi * wo + 9 * wo * wo
                    if wi != wo:
                        n += wi * wo
                w = wo
            n += w * self.num_classes
            return n
        d = self.d_model
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.num_codebooks:
            n += (self.num_codebooks - 1) * self.vocab_size * d  # extra heads+embeds
        per_layer = 0
        hd = self.resolved_head_dim
        if self.mla.enabled:
            m = self.mla
            qd = m.qk_nope_head_dim + m.qk_rope_head_dim
            if m.q_lora_rank:
                per_layer += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qd
            else:
                per_layer += d * self.num_heads * qd
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d
        elif not self.is_attention_free:
            per_layer += d * hd * (self.num_heads + 2 * self.num_kv_heads)
            per_layer += self.num_heads * hd * d
        if self.ssm.enabled:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.ngroups * s.state_size
            per_layer += d * (2 * d_in + 2 * s.ngroups * s.state_size + nheads)
            per_layer += conv_dim * s.conv_width
            per_layer += d_in * d + 2 * nheads
        if self.moe.enabled:
            m = self.moe
            moe_layers = self.num_layers - m.first_k_dense
            dense_layers = m.first_k_dense
            glu = 3 if self.mlp_type in ("swiglu", "geglu") else 2
            n += moe_layers * (
                m.num_experts * glu * d * m.moe_d_ff
                + m.num_shared_experts * glu * d * m.moe_d_ff
                + m.dense_residual_ff * glu * d
                + d * m.num_experts)
            n += dense_layers * glu * d * self.d_ff
            n += self.num_layers * per_layer
            return n
        glu = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        if self.d_ff:
            per_layer += glu * d * self.d_ff
        return n + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k only)."""
        if not self.moe.enabled:
            return self.param_count()
        m = self.moe
        d = self.d_model
        glu = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        total = self.param_count()
        routed_all = (self.num_layers - m.first_k_dense) * m.num_experts * glu * d * m.moe_d_ff
        routed_active = (self.num_layers - m.first_k_dense) * m.num_experts_per_tok * glu * d * m.moe_d_ff
        return total - routed_all + routed_active


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class IDKDConfig:
    """Hyper-parameters of the paper's Algorithm 1."""

    temperature: float = 10.0       # best distillation temperature (paper §4.2)
    start_step: int = 0             # "local convergence" trigger
    every_k_steps: int = 100        # label-exchange period: rounds fire at
                                    # start_step + j*every_k_steps for
                                    # j < num_rounds (sched.idkd_round_steps)
    num_rounds: int = 1             # homogenization rounds in the schedule
                                    # (1 = the paper's single round at
                                    # start_step; the federation scheduler
                                    # re-labels every round)
    kd_weight: float = 1.0          # weight of soft-CE on D_ID (applied in
                                    # every KD adapter, cls and LM alike)
    label_topk: int = 0             # 0 => dense soft labels (paper);
                                    # >0 => top-k sparse (LLM-scale codec)
    detector: str = "msp"
    label_backend: str = "dense"    # labeling engine backend (DESIGN.md §2):
                                    # "dense" (jnp oracle) | "fused"
                                    # (msp_select kernel pass) | "sparse"
                                    # (top-k wire format end-to-end)
    stream_labels: bool = True      # sparse/fused label rounds stream the
                                    # public set in microbatches through the
                                    # fused head-select pass — peak memory
                                    # O(microbatch·C) + O(n·P·k), never the
                                    # (n, P, C) logit stack (DESIGN.md §8);
                                    # False = the one-shot oracle path
    stream_microbatch: int = 256    # public samples per streaming chunk
                                    # (the simulator's pre-streaming host
                                    # batching used the same 256)
    select_block_rows: int = 8      # row-block of the msp_select /
                                    # head_select kernels (8 rows × 257k
                                    # vocab ≈ 8 MB VMEM in f32)


@dataclass(frozen=True)
class TrainConfig:
    """Decentralized training run description."""

    algorithm: str = "qg-dsgdm-n"   # dsgd|dsgdm|qg-dsgdm-n|relaysgd|d2|centralized
    topology: str = "ring"
    num_nodes: int = 16
    alpha: float = 0.1              # Dirichlet non-IID skew parameter
    lr: float = 0.5
    momentum: float = 0.9
    weight_decay: float = 1e-4
    batch_size: int = 32            # per-node
    steps: int = 300
    lr_decay_milestones: Tuple[float, float] = (0.6, 0.8)
    lr_decay_factor: float = 0.1
    seed: int = 4                   # paper seeds: 4, 34, 5
    idkd: Optional[IDKDConfig] = None

    # compressed / compute-overlapped gossip (DESIGN.md §9)
    compression: str = "none"       # none | topk | randk (sparsified wire
                                    # with per-node error feedback)
    compression_frac: float = 0.01  # kept fraction of each leaf's elements
    gossip: str = "sync"            # sync | delayed (one-step-stale mixing)

    @property
    def compression_spec(self):
        """The ``mixing.make_mixer``-ready spec: None, or (kind, frac)."""
        if self.compression in (None, "", "none"):
            return None
        return (self.compression, self.compression_frac)
