"""qwen3-1.7b — dense decoder with qk-norm and GQA.

Source: Qwen3 family [hf:Qwen/Qwen3-8B model card; 1.7B variant]. 28 layers,
d_model=2048, 16 heads (GQA kv=8, head_dim=128), d_ff=6144, vocab 151936,
per-head RMS qk-norm, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B (family card; 1.7B)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)
