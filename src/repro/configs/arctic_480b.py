"""arctic-480b — dense-MoE hybrid: 128-expert top-2 MoE ∥ dense residual MLP.

Source: Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]. 35 layers,
d_model=7168, 56 heads (GQA kv=8), expert d_ff=4864, vocab 32000,
128 experts top-2 with a dense residual branch in parallel.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    node_scope="pod",      # 480B params: one gossip node per pod (DESIGN §5)
    moe=MoEConfig(
        num_experts=128,
        num_experts_per_tok=2,
        moe_d_ff=4864,
        dense_residual_ff=4864,    # Arctic's parallel dense branch
        capacity_factor=1.25,
        router_type="softmax",
    ),
)
