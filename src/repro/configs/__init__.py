"""Config registry: ``get_config(arch_id)`` + the assigned shape table."""
from __future__ import annotations

from repro.configs.base import (IDKDConfig, MLAConfig, ModelConfig, MoEConfig,
                                SHAPES, ShapeConfig, SSMConfig, TrainConfig)
from repro.configs import (arctic_480b, deepseek_v3_671b, hymba_1_5b,
                           mamba2_780m, mistral_nemo_12b, musicgen_medium,
                           paligemma_3b, phi3_mini_3_8b, qwen1_5_0_5b,
                           qwen3_1_7b, resnet20_cifar)

ARCHS = {
    "mamba2-780m": mamba2_780m.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "musicgen-medium": musicgen_medium.CONFIG,
    "paligemma-3b": paligemma_3b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "mistral-nemo-12b": mistral_nemo_12b.CONFIG,
    "phi3-mini-3.8b": phi3_mini_3_8b.CONFIG,
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "qwen3-1.7b": qwen3_1_7b.CONFIG,
    # the paper's own architecture
    "resnet20-cifar": resnet20_cifar.CONFIG,
}

# Variants substituted for specific input shapes (documented in DESIGN.md).
LONG_CONTEXT_VARIANTS = {
    "mistral-nemo-12b": mistral_nemo_12b.LONG_CONFIG,
}

ASSIGNED_ARCHS = [k for k in ARCHS if k != "resnet20-cifar"]


def get_config(arch_id: str, shape: str | None = None) -> ModelConfig:
    """Resolve an ``--arch`` id (optionally specialized for a shape)."""
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[arch_id]
    if shape == "long_500k" and arch_id in LONG_CONTEXT_VARIANTS:
        cfg = LONG_CONTEXT_VARIANTS[arch_id]
    return cfg


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rule: long_500k needs sub-quadratic attention/decode state."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


__all__ = ["ARCHS", "ASSIGNED_ARCHS", "SHAPES", "get_config", "shape_supported",
           "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "ShapeConfig",
           "IDKDConfig", "TrainConfig", "LONG_CONTEXT_VARIANTS"]
