"""mistral-nemo-12b — dense 128k-context GQA decoder.

Source: [hf:mistralai/Mistral-Nemo-Base-2407]. 40 layers, d_model=5120,
32 heads (GQA kv=8, head_dim=128), d_ff=14336, vocab 131072 (Tekken),
rope_theta 1e6. ``long_500k`` is served through the sliding-window variant
(``LONG_CONFIG``, window 4096) — a beyond-paper configuration documented in
DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    rope_theta=1e6,
)

# Sliding-window variant used only for the long_500k decode shape.
LONG_CONFIG = CONFIG.replace(name="mistral-nemo-12b-sw4096", sliding_window=4096)
