"""hymba-1.5b — hybrid-head model: attention and Mamba heads in parallel.

Source: NVIDIA Hymba [arXiv:2411.13676]. 32 layers, d_model=1600, 25 heads
(GQA kv=5), d_ff=5504, vocab 32001, SSM state 16; sliding-window attention
in most layers with a few global layers; 128 learnable meta tokens prepended.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    source="arXiv:2411.13676 (Hymba-1.5B)",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    sliding_window=1024,
    global_attn_every=16,          # layers 0, 16 (+ last) use global attn
    num_prefix_tokens=128,         # meta tokens
    hybrid_parallel=True,
    ssm=SSMConfig(state_size=16, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, ngroups=1),
)
