"""qwen1.5-0.5b — dense decoder with QKV bias and tied embeddings.

Source: [hf:Qwen/Qwen1.5-0.5B]. 24 layers, d_model=1024, 16 heads (kv=16),
d_ff=2816, vocab 151936, qkv bias, tied input/output embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
)
