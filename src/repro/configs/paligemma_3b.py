"""paligemma-3b — Gemma-2B decoder consuming SigLIP patch embeddings.

Source: PaliGemma [arXiv:2407.07726]. Language backbone: 18 layers,
d_model=2048, 8 heads (GQA kv=1, head_dim=256), d_ff=16384 (GeGLU),
vocab 257216. The SigLIP vision tower + projector are a STUBBED frontend
per the assignment — ``input_specs`` provides 256 precomputed patch
embeddings, attended with PaliGemma's prefix-LM mask (bidirectional over
image + text prefix, causal over the suffix).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    source="arXiv:2407.07726 (PaliGemma-3B / Gemma-2B backbone)",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    mlp_type="geglu",
    tie_embeddings=True,
    num_prefix_tokens=256,        # SigLIP patch embeddings (stub frontend)
    prefix_lm_prefix=256,         # bidirectional attention over the prefix
)
