"""resnet20-evonorm — the paper's own model (faithful repro backbone).

Source: IDKD paper §4.1 — ResNet20 (He et al., 2016) with BatchNorm replaced
by EvoNorm (Liu et al., 2020a) because BN fails under non-IID decentralized
training (Hsieh et al., 2020). 3 stages × 3 basic blocks, width 16.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="resnet20-evonorm",
    arch_type="cnn",
    source="IDKD paper §4.1 (ResNet20 + EvoNorm-B0)",
    cnn_stages=(3, 3, 3),
    cnn_width=16,
    image_size=32,
    image_channels=3,
    num_classes=10,
    dtype="float32",
    scan_layers=False,
    remat=False,
)

# Small variant for fast CPU experiments (same family, fewer blocks).
SMALL_CONFIG = CONFIG.replace(name="resnet8-evonorm", cnn_stages=(1, 1, 1))
