"""mamba2-780m — pure Mamba-2 (SSD, state-space duality) language model.

Source: Dao & Gu, "Transformers are SSMs" [arXiv:2405.21060], 780m scale.
48 layers, d_model=1536, attention-free, d_state=128, vocab 50280 (GPT-NeoX).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    source="arXiv:2405.21060 (Mamba-2 780m)",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                       # attention-free, no MLP: Mamba2 block only
    vocab_size=50_280,
    tie_embeddings=True,
    norm_type="rmsnorm",
    ssm=SSMConfig(state_size=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, ngroups=1),
)
