"""musicgen-medium — decoder-only transformer over EnCodec audio tokens.

Source: MusicGen [arXiv:2306.05284], medium (1.5B). 48 layers, d_model=1536,
24 heads (MHA, kv=24), d_ff=6144, vocab 2048 per codebook, 4 codebooks with
the delay interleaving pattern. The EnCodec tokenizer and the T5 text
conditioner are modality frontends and are STUBBED per the assignment:
``input_specs`` supplies the token streams and the conditioning embeddings.
Cross-attention to the conditioning sequence is implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    source="arXiv:2306.05284 (MusicGen-medium)",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    cross_attention=True,
    cross_attn_len=64,             # stubbed T5 conditioning length
    mlp_type="gelu",
    norm_type="layernorm",
)
