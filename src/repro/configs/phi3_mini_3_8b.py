"""phi3-mini-3.8b — dense RoPE/SwiGLU/GQA decoder.

Source: Phi-3 [arXiv:2404.14219]. 32 layers, d_model=3072, 32 heads
(kv=32, MHA), d_ff=8192, vocab 32064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    source="arXiv:2404.14219 (Phi-3-mini)",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
)
