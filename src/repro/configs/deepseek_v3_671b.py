"""deepseek-v3-671b — MLA + 256-expert top-8 MoE with shared expert + MTP.

Source: DeepSeek-V3 [arXiv:2412.19437]. 61 layers (first 3 dense),
d_model=7168, 128 heads with multi-head latent attention (q_lora 1536,
kv_lora 512, nope 128 / rope 64 / v 128), routed expert d_ff=2048,
1 shared + 256 routed experts top-8 with sigmoid routing, vocab 129280,
one multi-token-prediction (MTP) depth.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    source="arXiv:2412.19437 (DeepSeek-V3)",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,              # MLA: kv heads == q heads post-expansion
    d_ff=18432,                    # dense layers' FFN width
    vocab_size=129_280,
    mtp_depth=1,
    node_scope="pod",   # 671B params: one gossip node per pod (DESIGN §5)
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        num_experts_per_tok=8,
        moe_d_ff=2048,
        num_shared_experts=1,
        first_k_dense=3,
        capacity_factor=1.25,
        router_type="sigmoid",     # aux-loss-free bias balancing
    ),
)
