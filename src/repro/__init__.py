"""JAX/Pallas reproduction of IDKD decentralized learning.

One piece of process-wide configuration lives here: the partitionable
threefry PRNG. The legacy lowering (``jax_threefry_partitionable=False``,
still the default on this JAX version) lets XLA's SPMD partitioner
produce *different random values for the same key* depending on how the
surrounding computation is sharded — a sampler traced into the jitted
scan runner draws different batches on a ``(node=4,)`` mesh than on a
``(node=4, model=2)`` one, silently breaking trajectory equivalence
across mesh shapes. The partitionable implementation is
sharding-invariant (and the upstream default going forward); the 2-D
federation-mesh equivalence tests rely on it (DESIGN.md §10).
"""
import jax

jax.config.update("jax_threefry_partitionable", True)
