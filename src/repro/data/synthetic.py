"""Synthetic datasets standing in for CIFAR/ImageNette/TinyImageNet/LSUN.

The container is offline (repro band 2), so the paper's datasets are
simulated with the properties that matter to IDKD:

* :func:`make_classification_data` — class-conditional image data
  (per-class mean pattern + noise). Nodes that see few classes overfit
  them, reproducing the paper's non-IID failure mode.
* :func:`make_public_data` — the unlabeled public set D_P: a mixture of
  *aligned* samples (drawn from the same class generators, higher noise —
  the TinyImageNet-like part the MSP detector should keep) and *OoD*
  samples (different generators or uniform noise — the part it should
  drop). ``kind`` ∈ {aligned, shifted, noise} mirrors Table 4's
  TinyImageNet / LSUN / Uniform-Noise public-set choices.
* :func:`make_lm_data` — topic-conditional token sequences for the LLM
  examples (topics play the role of classes for Dirichlet partitioning).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class ClassificationData:
    train_x: np.ndarray     # (N, H, W, C) float32
    train_y: np.ndarray     # (N,) int64
    val_x: np.ndarray
    val_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    class_means: np.ndarray  # (num_classes, H, W, C) — the generators


def _class_means(rng, num_classes, image_size, channels, scale=1.0):
    """Per-class mean images as sparse combinations of a SHARED feature
    dictionary. Sharing features across classes creates the gradient
    interference that makes non-IID training genuinely destructive (CIFAR
    classes share low-level features the same way) — with independent
    Gaussian blobs the per-class gradients are near-orthogonal and the
    paper's failure mode barely materializes."""
    K = 6
    D = rng.normal(size=(K, 4, 4, channels)).astype(np.float32)
    W = rng.normal(size=(num_classes, K)).astype(np.float32)
    W /= np.linalg.norm(W, axis=1, keepdims=True)
    base = np.einsum("ck,khwj->chwj", W, D)
    reps = image_size // 4
    up = np.repeat(np.repeat(base, reps, axis=1), reps, axis=2)
    return (up * scale).astype(np.float32)


def make_classification_data(num_classes: int = 10, image_size: int = 16,
                             channels: int = 3, n_train: int = 4096,
                             n_val: int = 512, n_test: int = 1024,
                             noise: float = 0.6, seed: int = 0
                             ) -> ClassificationData:
    rng = np.random.default_rng(seed)
    means = _class_means(rng, num_classes, image_size, channels)

    def sample(n):
        y = rng.integers(0, num_classes, size=n)
        x = means[y] + rng.normal(scale=noise,
                                  size=(n, image_size, image_size, channels)
                                  ).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int64)

    tx, ty = sample(n_train)
    vx, vy = sample(n_val)
    sx, sy = sample(n_test)
    return ClassificationData(tx, ty, vx, vy, sx, sy, means)


def make_public_data(data: ClassificationData, n_public: int = 2048,
                     kind: str = "aligned", aligned_frac: float = 0.5,
                     noise: float = 0.9, seed: int = 1) -> np.ndarray:
    """Unlabeled public set D_P. ``kind``:
    * 'aligned' — aligned_frac drawn from the same class generators
      (higher noise) + the rest OoD   [≈ TinyImageNet]
    * 'shifted' — all samples from *perturbed* generators [≈ LSUN]
    * 'noise'   — uniform noise                             [≈ Uniform-Noise]
    """
    rng = np.random.default_rng(seed)
    C, H, W, ch = data.class_means.shape
    if kind == "noise":
        return rng.uniform(-2, 2, size=(n_public, H, W, ch)).astype(np.float32)
    if kind == "shifted":
        shift = rng.normal(scale=0.8, size=data.class_means.shape
                           ).astype(np.float32)
        means = data.class_means + shift
        y = rng.integers(0, C, size=n_public)
        x = means[y] + rng.normal(scale=noise, size=(n_public, H, W, ch))
        return x.astype(np.float32)
    # aligned
    n_id = int(n_public * aligned_frac)
    y = rng.integers(0, C, size=n_id)
    x_id = data.class_means[y] + rng.normal(scale=noise, size=(n_id, H, W, ch))
    ood_means = _class_means(rng, C, H, ch)  # fresh generators => OoD
    y2 = rng.integers(0, C, size=n_public - n_id)
    x_ood = ood_means[y2] + rng.normal(scale=noise,
                                       size=(n_public - n_id, H, W, ch))
    x = np.concatenate([x_id, x_ood]).astype(np.float32)
    rng.shuffle(x)
    return x


def make_lm_data(vocab: int, seq_len: int, n_seqs: int, num_topics: int = 10,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Topic-conditional unigram LM corpus: (tokens (N, S), topic (N,)).
    Each topic concentrates on a distinct vocab slice, so Dirichlet
    partitioning by topic produces genuinely non-IID token statistics."""
    rng = np.random.default_rng(seed)
    topics = rng.integers(0, num_topics, size=n_seqs)
    # topic t prefers tokens in its slice with prob 0.8
    slice_size = max(vocab // num_topics, 1)
    tokens = np.empty((n_seqs, seq_len), np.int32)
    for i, t in enumerate(topics):
        lo = (t * slice_size) % vocab
        in_slice = rng.random(seq_len) < 0.8
        tok = np.where(
            in_slice,
            lo + rng.integers(0, slice_size, size=seq_len),
            rng.integers(0, vocab, size=seq_len))
        tokens[i] = tok % vocab
    return tokens, topics.astype(np.int64)
