from repro.data.dirichlet import dirichlet_partition, partition_stats  # noqa: F401
from repro.data.synthetic import (make_classification_data,  # noqa: F401
                                  make_lm_data, make_public_data)
from repro.data.pipeline import HomogenizedSampler, NodeSampler  # noqa: F401
