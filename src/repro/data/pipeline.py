"""Batching pipeline for decentralized training.

``NodeSampler`` draws per-node minibatches from the Dirichlet partition;
``HomogenizedSampler`` draws from D_T^i ∪ D_ID (private hard-label samples
mixed with distilled soft-label public samples) after an IDKD round —
Algorithm 1 line 15.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


class NodeSampler:
    """Per-node IID sampling *within* each node's (non-IID) partition."""

    def __init__(self, parts: List[np.ndarray], batch_size: int, seed: int):
        self.parts = parts
        self.batch_size = batch_size
        self.rngs = [np.random.default_rng(seed + 17 * i)
                     for i in range(len(parts))]

    def sample(self) -> np.ndarray:
        """(n_nodes, batch) global indices into the training arrays."""
        return np.stack([
            rng.choice(part, size=self.batch_size,
                       replace=len(part) < self.batch_size)
            for rng, part in zip(self.rngs, self.parts)])


class HomogenizedSampler:
    """Samples the union set: with prob proportional to sizes, a batch
    element comes from the private set (hard label) or the distilled
    public subset (soft label + weight).

    Optionally owns the post-round label payload (``public_labels``):
    either a dense ``(n, P, C)`` array or a sparse top-k
    ``(values (n, P, k), indices (n, P, k))`` pair — the sparse payload
    is gathered per batch and handed to the KD step without ever being
    densified to ``(n, P, C)``.
    """

    def __init__(self, parts: List[np.ndarray], public_weights: np.ndarray,
                 batch_size: int, seed: int, public_labels=None):
        # public_weights: (n_nodes, P) — 1 where sample in node's D_ID union
        self.parts = parts
        self.batch_size = batch_size
        self.rngs = [np.random.default_rng(seed + 31 * i)
                     for i in range(len(parts))]
        self.refresh(public_weights, public_labels)

    def refresh(self, public_weights: np.ndarray, public_labels=None) -> None:
        """Swap in a new homogenization round's D_ID selection and label
        payload. This is the repeated-round path for *host-side* numpy
        consumers of the pipeline (the jitted drivers refresh rounds by
        threading a ctx pytree through the runner instead —
        ``driver.homogenized_ctx``); the per-node RNG streams keep
        advancing across a refresh, so draws are never replayed."""
        self.public_weights = np.asarray(public_weights)
        self.public_idx = [np.flatnonzero(w > 0) for w in self.public_weights]
        if public_labels is not None:
            if isinstance(public_labels, (tuple, list)):
                # sparse payload: a (values, indices) named/plain tuple
                public_labels = (np.asarray(public_labels[0]),
                                 np.asarray(public_labels[1]))
            else:
                # dense (n, P, C) array of any array flavour
                public_labels = np.asarray(public_labels)
        self.public_labels = public_labels

    @property
    def sparse(self) -> bool:
        return isinstance(self.public_labels, tuple)

    def gather_public(self, pub_idx: np.ndarray):
        """Per-batch public label payload for (n, B) public indices:
        dense (n, B, C), or (values (n, B, k), indices (n, B, k))."""
        if self.public_labels is None:
            raise ValueError("sampler was built without label payloads")
        nidx = np.arange(len(self.parts))[:, None]
        if self.sparse:
            vals, idx = self.public_labels
            return vals[nidx, pub_idx], idx[nidx, pub_idx]
        return self.public_labels[nidx, pub_idx]

    def gather_weights(self, pub_idx: np.ndarray) -> np.ndarray:
        """Per-batch public sample weights: (n, B)."""
        nidx = np.arange(len(self.parts))[:, None]
        return self.public_weights[nidx, pub_idx]

    def sample(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (private_idx (n, B), public_idx (n, B), is_public (n, B)).
        Unused slots hold index 0 with is_public mask selecting the source."""
        n = len(self.parts)
        B = self.batch_size
        priv = np.zeros((n, B), np.int64)
        pub = np.zeros((n, B), np.int64)
        is_pub = np.zeros((n, B), bool)
        for i, rng in enumerate(self.rngs):
            n_priv = len(self.parts[i])
            n_pub = len(self.public_idx[i])
            p_pub = n_pub / max(n_priv + n_pub, 1)
            mask = rng.random(B) < p_pub
            is_pub[i] = mask & (n_pub > 0)
            priv[i] = rng.choice(self.parts[i], size=B,
                                 replace=n_priv < B)
            if n_pub:
                pub[i] = rng.choice(self.public_idx[i], size=B,
                                    replace=n_pub < B)
        return priv, pub, is_pub
