"""Dirichlet non-IID partitioner (paper §4.1).

Samples per-class node proportions from Dir(α·1) and assigns the class's
samples to nodes accordingly (non-overlapping; never reshuffled afterwards,
exactly as the paper describes). α=1 ≈ mild skew; α=0.05 ⇒ most nodes see
only a few classes.
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_nodes: int, alpha: float,
                        rng: np.random.Generator,
                        min_per_node: int = 2) -> List[np.ndarray]:
    """Returns a list of index arrays, one per node (disjoint, covering)."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    node_indices: List[list] = [[] for _ in range(num_nodes)]
    for attempt in range(100):
        node_indices = [[] for _ in range(num_nodes)]
        for c in classes:
            idx = np.flatnonzero(labels == c)
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(num_nodes, alpha))
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for node, part in enumerate(np.split(idx, cuts)):
                node_indices[node].extend(part.tolist())
        sizes = [len(ix) for ix in node_indices]
        if min(sizes) >= min_per_node:
            break
    out = []
    for ix in node_indices:
        arr = np.asarray(ix, dtype=np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out


def partition_stats(labels: np.ndarray, parts: List[np.ndarray],
                    num_classes: int) -> np.ndarray:
    """(n_nodes, n_classes) normalized class histograms of a partition."""
    hists = []
    for ix in parts:
        h = np.bincount(labels[ix], minlength=num_classes).astype(np.float64)
        hists.append(h / max(h.sum(), 1.0))
    return np.stack(hists)
