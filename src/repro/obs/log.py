"""Structured, level-gated logger for the repro stack.

One shared ``logging`` logger (``"repro"``) replaces the scattered
``print()`` calls in the launch/core modules. Messages are structured
events — an event name plus ``key=value`` fields — so grep-ability
survives the move away from free-form prints.

Level resolution (first match wins):
  * ``REPRO_LOG_LEVEL`` env var (``DEBUG``/``INFO``/``WARNING``/...),
  * quiet (``WARNING``) when running under pytest (``PYTEST_CURRENT_TEST``
    or ``PYTEST_VERSION`` in the environment),
  * ``INFO`` otherwise.

Usage::

    from repro.obs import log
    log.info("train_eval", step=120, loss=2.31)
    # stderr: [repro I] train_eval step=120 loss=2.31
"""
from __future__ import annotations

import logging
import os
import sys
from typing import Any

_LOGGER_NAME = "repro"
_configured = False


def _default_level() -> int:
    env = os.environ.get("REPRO_LOG_LEVEL", "").upper()
    if env:
        return getattr(logging, env, logging.INFO)
    if "PYTEST_CURRENT_TEST" in os.environ or "PYTEST_VERSION" in os.environ:
        return logging.WARNING
    return logging.INFO


def get_logger() -> logging.Logger:
    """The process-wide ``repro`` logger, configured on first use."""
    global _configured
    logger = logging.getLogger(_LOGGER_NAME)
    if not _configured:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(
            logging.Formatter("[%(name)s %(levelname).1s] %(message)s"))
        logger.addHandler(handler)
        logger.propagate = False
        logger.setLevel(_default_level())
        _configured = True
    return logger


def set_level(level) -> None:
    """Override the log level (accepts ``logging`` ints or name strings)."""
    if isinstance(level, str):
        level = getattr(logging, level.upper())
    get_logger().setLevel(level)


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    s = str(v)
    return repr(s) if " " in s else s


def _emit(level: int, event: str, fields: dict) -> None:
    logger = get_logger()
    if not logger.isEnabledFor(level):
        return
    msg = event + "".join(f" {k}={_fmt(v)}" for k, v in fields.items())
    logger.log(level, msg)


def debug(event: str, **fields) -> None:
    _emit(logging.DEBUG, event, fields)


def info(event: str, **fields) -> None:
    _emit(logging.INFO, event, fields)


def warning(event: str, **fields) -> None:
    _emit(logging.WARNING, event, fields)
