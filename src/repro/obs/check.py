"""CI schema check for telemetry artifacts.

    PYTHONPATH=src python -m repro.obs.check DIR [--require-trace]

Validates ``DIR/run.jsonl`` against :data:`repro.obs.runlog.EVENT_SCHEMA`
and, when present (or ``--require-trace``), ``DIR/trace.json`` against
the Chrome trace_event shape Perfetto loads. Exits non-zero on any
malformed artifact or when the run log is missing.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.obs import RUNLOG_NAME, TRACE_NAME, validate_runlog, validate_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("dir", help="telemetry output directory")
    ap.add_argument("--require-trace", action="store_true",
                    help="fail when trace.json is absent")
    args = ap.parse_args(argv)

    out = Path(args.dir)
    runlog = out / RUNLOG_NAME
    if not runlog.exists():
        print(f"[obs.check] FAIL: {runlog} not found", file=sys.stderr)
        return 1
    try:
        counts = validate_runlog(runlog)
    except ValueError as e:
        print(f"[obs.check] FAIL: {e}", file=sys.stderr)
        return 1
    total = sum(counts.values())
    kinds = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"[obs.check] {runlog}: {total} events OK ({kinds})")

    trace = out / TRACE_NAME
    if trace.exists():
        try:
            n = validate_trace(trace)
        except ValueError as e:
            print(f"[obs.check] FAIL: {e}", file=sys.stderr)
            return 1
        print(f"[obs.check] {trace}: {n} trace events OK")
    elif args.require_trace:
        print(f"[obs.check] FAIL: {trace} not found", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
