"""Structured run events: append-only JSONL with a checked schema.

Every scheduler-visible occurrence in a federation run — schedule
segments, churn/rewire/stale transitions, label rounds, ledger traffic,
metric flushes, evals — is one JSON object per line in ``run.jsonl``.
The file alone reconstructs the run: per-node consensus distance and EF
residual come from ``metrics`` events, detector thresholds and selected
counts from ``labels`` events, wire bytes from ``comm`` events (the
:class:`repro.sched.ledger.CommLedger` rows folded into the stream).

Event kinds and their required fields live in :data:`EVENT_SCHEMA`;
:func:`validate_runlog` is the CI schema check
(``python -m repro.obs.check DIR``).
"""
from __future__ import annotations

import json
import time
from collections import Counter
from pathlib import Path
from typing import Any, Dict, Optional

# kind -> required field names (beyond "ev" and "t"). Extra fields are
# always allowed; the schema pins the minimum a reader can rely on.
EVENT_SCHEMA: Dict[str, tuple] = {
    "run_meta": (),                              # free-form run header
    "schedule": ("segments", "steps"),           # compiled schedule shape
    "segment": ("index", "start", "stop"),       # one runner invocation
    "topology": ("step", "active"),              # churn / rewire / stale
    "round": ("round", "step"),                  # homogenization fired
    "labels": ("round", "step"),                 # label-round statistics
    "comm": ("kind", "round", "per_node"),       # ledger row (gossip/labels)
    "metrics": ("step", "loss", "consensus"),    # metrics-bus flush
    "eval": ("step",),                           # scheduler eval boundary
    "accuracy": ("step",),                       # host-side eval metrics
    "fault": ("step", "kind"),                   # injected fault state change
    "health": ("step",),                         # guard trip / quarantine /
                                                 #   non-finite eval
    "rollback": ("step", "retry"),               # segment re-run after guard
                                                 #   divergence
    "snapshot": ("step",),                       # durable snapshot written
    "resume": ("step",),                         # auto-resume from snapshot
    "run_end": (),                               # run summary footer
}


def _jsonable(v: Any) -> Any:
    """Coerce numpy / jax scalars and arrays into plain JSON values."""
    if hasattr(v, "tolist"):                     # np.ndarray, jax.Array
        return v.tolist()
    if hasattr(v, "item") and not isinstance(v, (int, float, bool, str)):
        return v.item()
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, Path):
        return str(v)
    return v


class RunLog:
    """Append-only JSONL event stream (line-buffered; valid mid-run)."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "a", buffering=1)
        self._t0 = time.perf_counter()

    def emit(self, ev: str, **fields) -> None:
        if ev not in EVENT_SCHEMA:
            raise ValueError(f"unknown run-log event kind {ev!r}; "
                             f"add it to EVENT_SCHEMA")
        missing = [k for k in EVENT_SCHEMA[ev] if k not in fields]
        if missing:
            raise ValueError(f"event {ev!r} missing required fields "
                             f"{missing}")
        rec = {"ev": ev, "t": round(time.perf_counter() - self._t0, 6)}
        rec.update({k: _jsonable(v) for k, v in fields.items()})
        self._f.write(json.dumps(rec) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def validate_runlog(path) -> Counter:
    """Parse + schema-check a run.jsonl; returns Counter of event kinds.

    Raises ``ValueError`` on malformed JSON, unknown event kinds, or
    missing required fields — the CI gate for telemetry artifacts.
    """
    counts: Counter = Counter()
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: bad JSON: {e}") from e
            ev = rec.get("ev")
            if ev not in EVENT_SCHEMA:
                raise ValueError(f"{path}:{lineno}: unknown event {ev!r}")
            if "t" not in rec:
                raise ValueError(f"{path}:{lineno}: missing timestamp 't'")
            missing = [k for k in EVENT_SCHEMA[ev] if k not in rec]
            if missing:
                raise ValueError(f"{path}:{lineno}: event {ev!r} missing "
                                 f"required fields {missing}")
            counts[ev] += 1
    if not counts:
        raise ValueError(f"{path}: empty run log")
    return counts


def read_events(path, kind: Optional[str] = None):
    """All events (optionally one kind) as a list of dicts — test helper."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if kind is None or rec.get("ev") == kind:
                out.append(rec)
    return out
