"""Trace spans: Chrome ``trace_event`` JSON around scheduler phases.

A :class:`TraceRecorder` collects complete ("ph": "X") spans with
microsecond wall-clock timestamps and exports the standard
``{"traceEvents": [...]}`` document that chrome://tracing and Perfetto
(https://ui.perfetto.dev) load directly. Spans wrap scheduler segments,
label rounds, evals, and comm/compile boundaries; the first invocation
of a freshly built runner is tagged ``compile=True`` so XLA compilation
cost is visible as a distinct slice.

For device-level detail, :func:`start_jax_profiler` hands off to
``jax.profiler`` (TensorBoard/Perfetto-compatible output) when the
installed jax supports it; the hand-off is best-effort and never fails
a run.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Dict, List, Optional


class TraceRecorder:
    """In-memory span recorder exporting Chrome trace_event JSON."""

    def __init__(self, pid: int = 0):
        self.pid = pid if pid else os.getpid()
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter_ns()

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    @contextmanager
    def span(self, name: str, cat: str = "sched", **args):
        start = self._now_us()
        try:
            yield self
        finally:
            end = self._now_us()
            self.events.append({
                "name": name, "cat": cat, "ph": "X",
                "ts": round(start, 3), "dur": round(end - start, 3),
                "pid": self.pid, "tid": 0,
                "args": {k: _arg(v) for k, v in args.items()},
            })

    def instant(self, name: str, cat: str = "sched", **args) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "g",
            "ts": round(self._now_us(), 3), "pid": self.pid, "tid": 0,
            "args": {k: _arg(v) for k, v in args.items()},
        })

    def export(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        doc = {"traceEvents": self.events, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


def _arg(v: Any) -> Any:
    if hasattr(v, "tolist"):
        return v.tolist()
    if isinstance(v, (int, float, bool, str)) or v is None:
        return v
    return str(v)


def validate_trace(path) -> int:
    """Check a trace JSON is Perfetto-loadable; returns the event count.

    Loadable here means: a JSON object with a ``traceEvents`` list whose
    entries each carry ``name``/``ph``/``ts`` (and ``dur`` for complete
    events) — the minimum the trace_event spec requires.
    """
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents list")
    for i, ev in enumerate(events):
        for k in ("name", "ph", "ts", "pid"):
            if k not in ev:
                raise ValueError(f"{path}: traceEvents[{i}] missing {k!r}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"{path}: traceEvents[{i}] complete event "
                             f"without dur")
    if not events:
        raise ValueError(f"{path}: empty trace")
    return len(events)


def start_jax_profiler(log_dir) -> bool:
    """Best-effort ``jax.profiler.start_trace`` hand-off (device detail)."""
    try:
        import jax
        jax.profiler.start_trace(str(log_dir))
        return True
    except Exception:
        return False


def stop_jax_profiler() -> None:
    try:
        import jax
        jax.profiler.stop_trace()
    except Exception:
        pass
