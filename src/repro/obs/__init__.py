"""Federation telemetry: metrics bus + structured events + trace spans.

Three layers, all off by default (a run with ``telemetry=None`` executes
byte-for-byte the code it always did):

  1. **metrics bus** (:mod:`repro.obs.metrics`) — a pytree carried
     through the jitted runners, accumulating per-node loss / grad norm /
     consensus distance / EF residual with zero host syncs;
  2. **run events** (:mod:`repro.obs.runlog`) — schema-checked JSONL
     (``run.jsonl``) of segments, churn, label rounds, ledger traffic,
     metric flushes, evals;
  3. **trace spans** (:mod:`repro.obs.trace`) — Chrome trace_event JSON
     (``trace.json``, Perfetto-loadable) around scheduler phases, with an
     optional ``jax.profiler`` hand-off.

:class:`Telemetry` is the facade the simulator / launch driver / tests
hold; the scheduler only ever calls ``event`` / ``span`` /
``flush_metrics`` on it.
"""
from __future__ import annotations

from contextlib import nullcontext
from pathlib import Path
from typing import Optional

from repro.obs import log  # noqa: F401 (re-export)
from repro.obs.runlog import (EVENT_SCHEMA, RunLog, read_events,
                              validate_runlog)
from repro.obs.trace import (TraceRecorder, start_jax_profiler,
                             stop_jax_profiler, validate_trace)

RUNLOG_NAME = "run.jsonl"
TRACE_NAME = "trace.json"


class Telemetry:
    """One run's telemetry sinks + the metrics-bus enable flag.

    ``out_dir=None`` keeps everything in memory (metrics bus only —
    useful for overhead benches); otherwise ``run.jsonl`` streams as the
    run progresses and ``trace.json`` is written by :meth:`close`.
    """

    def __init__(self, out_dir=None, *, metrics: bool = True,
                 events: bool = True, trace: bool = False,
                 jax_profile: bool = False, meta: Optional[dict] = None):
        self.out_dir = Path(out_dir) if out_dir is not None else None
        self.metrics_enabled = bool(metrics)
        self.runlog: Optional[RunLog] = None
        self.tracer: Optional[TraceRecorder] = None
        self._profiling = False
        if self.out_dir is not None and events:
            self.runlog = RunLog(self.out_dir / RUNLOG_NAME)
        if trace:
            self.tracer = TraceRecorder()
        if meta:
            self.event("run_meta", **meta)
        if jax_profile and self.out_dir is not None:
            self._profiling = start_jax_profiler(
                self.out_dir / "jax_profile")

    # -- sinks ---------------------------------------------------------------
    def event(self, ev: str, **fields) -> None:
        if self.runlog is not None:
            self.runlog.emit(ev, **fields)

    def span(self, name: str, cat: str = "sched", **args):
        if self.tracer is not None:
            return self.tracer.span(name, cat, **args)
        return nullcontext()

    def flush_metrics(self, step: int, metrics, **extra) -> None:
        """device_get + summarize the metrics pytree into one event."""
        if metrics is None:
            return
        from repro.obs import metrics as obs_metrics
        summary = obs_metrics.summarize(metrics)
        self.event("metrics", step=step, **summary, **extra)

    def close(self) -> None:
        if self._profiling:
            stop_jax_profiler()
            self._profiling = False
        if self.tracer is not None and self.out_dir is not None:
            self.tracer.export(self.out_dir / TRACE_NAME)
        if self.runlog is not None:
            self.runlog.close()

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["Telemetry", "RunLog", "TraceRecorder", "EVENT_SCHEMA",
           "RUNLOG_NAME", "TRACE_NAME", "log", "read_events",
           "validate_runlog", "validate_trace", "start_jax_profiler",
           "stop_jax_profiler"]
