"""On-device metrics bus: a pytree carried through the jitted runners.

The metrics pytree rides the scan/host/shard runner carry exactly like
PR 6's ``comm`` state — accumulated inside jit with zero host syncs and
flushed only at segment (eval/chunk) boundaries. Layout (fixed across
phases so the scan carry structure never changes):

  ``steps``        ()   int32 — steps accumulated since the last flush
  ``loss_sum``     (n,) f32   — per-node train-loss sum (mean at flush)
  ``grad_sq_sum``  (n,) f32   — per-node squared grad-norm sum
  ``consensus_sq`` (n,) f32   — ‖x_i − x̄‖² after the latest update
  ``ef_sq``        (n,) f32   — ‖x_i − x̂_i‖² CHOCO error-feedback
                                residual (zeros when no compression state)

``consensus_sq``/``ef_sq`` are latest-step snapshots (overwritten each
step); the sums are averaged at flush. The invariant
``sqrt(sum(consensus_sq)) == mixing.consensus_distance(params)`` ties
the in-jit accumulator to the host-side reference computation.

:func:`update` has two addressing modes: node-stacked (vmap drivers,
leading node axis) and shard (inside ``shard_map``, per-node quantities
psum'd over the node axis; on 2-D federation meshes the per-leaf
contributions of model-sharded leaves are additionally psum'd over the
model axis — the same reduction split as the driver's
``reduce_tree_sum`` hook).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

METRIC_FIELDS = ("loss_sum", "grad_sq_sum", "consensus_sq", "ef_sq")


def init_node_metrics(n: int):
    """Zeroed metrics pytree for ``n`` nodes (node-stacked layout)."""
    z = jnp.zeros((n,), jnp.float32)
    return {"steps": jnp.zeros((), jnp.int32),
            "loss_sum": z, "grad_sq_sum": z, "consensus_sq": z, "ef_sq": z}


def _rows_sq(x) -> jax.Array:
    """(rows, ...) -> (rows,) sum of squares per leading row, f32."""
    xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
    return jnp.sum(xf * xf, axis=1)


def update(metrics, losses, grads, params, *, ef_ref=None,
           axis_name: Optional[str] = None, num_nodes: int = 0,
           model_dims=None, model_axis: str = "model"):
    """One metrics-bus step; pure, jit-safe, no host syncs.

    Node-stacked mode (``axis_name=None``): every leaf has a leading
    node axis of size n; ``losses`` is (n,).

    Shard mode (``axis_name`` = the node mesh axis): leaves hold the
    local block of L = n // mesh rows, ``num_nodes`` is the global n and
    the node mean is psum'd. ``model_dims`` (per-leaf sharded-dim list,
    None entries = model-replicated) enables the 2-D mesh reduction:
    sharded leaves contribute partial sums psum'd over ``model_axis``.

    ``ef_ref`` is a pytree congruent with ``params`` rows (each leaf
    reshapable to (rows, -1)) holding the mixer's shared estimate x̂.
    """
    p_leaves = jax.tree.leaves(params)
    g_leaves = jax.tree.leaves(grads)
    dims = (list(model_dims) if model_dims is not None
            else [None] * len(p_leaves))

    def combine(vals):
        """Sum per-leaf (rows,) contributions, psum-ing sharded leaves
        over the model axis so every model peer holds the full value."""
        sharded = [v for v, d in zip(vals, dims) if d is not None]
        replicated = [v for v, d in zip(vals, dims) if d is None]
        total = jnp.zeros_like(vals[0])
        if sharded:
            total = total + jax.lax.psum(sum(sharded), model_axis)
        if replicated:
            total = total + sum(replicated)
        return total

    grad_sq = combine([_rows_sq(g) for g in g_leaves])

    cons = []
    for x in p_leaves:
        xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
        if axis_name is None:
            mean = jnp.mean(xf, axis=0, keepdims=True)
        else:
            mean = (jax.lax.psum(jnp.sum(xf, axis=0, keepdims=True),
                                 axis_name) / num_nodes)
        delta = xf - mean
        cons.append(jnp.sum(delta * delta, axis=1))
    consensus_sq = combine(cons)

    if ef_ref is not None:
        efs = []
        for x, h in zip(p_leaves, jax.tree.leaves(ef_ref)):
            xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
            hf = h.astype(jnp.float32).reshape(h.shape[0], -1)
            d = xf - hf
            efs.append(jnp.sum(d * d, axis=1))
        ef_sq = combine(efs)
    else:
        ef_sq = jnp.zeros_like(metrics["ef_sq"])

    return {"steps": metrics["steps"] + 1,
            "loss_sum": metrics["loss_sum"] + losses.astype(jnp.float32),
            "grad_sq_sum": metrics["grad_sq_sum"] + grad_sq,
            "consensus_sq": consensus_sq,
            "ef_sq": ef_sq}


def reset(metrics):
    """Zero the accumulators (same structure/placement — carry-safe)."""
    return jax.tree.map(jnp.zeros_like, metrics)


def summarize(metrics) -> dict:
    """Host-side flush: device_get once, derive per-node scalars.

    Returns per-node lists (``loss``, ``grad_norm``, ``consensus``,
    ``ef_residual``) plus ``consensus_total`` = ‖X − 1x̄ᵀ‖_F, which
    matches :func:`repro.core.mixing.consensus_distance`.
    """
    m = jax.device_get(metrics)
    steps = max(int(m["steps"]), 1)
    loss = np.asarray(m["loss_sum"], np.float64) / steps
    grad = np.sqrt(np.asarray(m["grad_sq_sum"], np.float64) / steps)
    cons_sq = np.maximum(np.asarray(m["consensus_sq"], np.float64), 0.0)
    ef_sq = np.maximum(np.asarray(m["ef_sq"], np.float64), 0.0)
    return {
        "accum_steps": int(m["steps"]),
        "loss": [float(v) for v in loss],
        "grad_norm": [float(v) for v in grad],
        "consensus": [float(v) for v in np.sqrt(cons_sq)],
        "consensus_total": float(np.sqrt(cons_sq.sum())),
        "ef_residual": [float(v) for v in np.sqrt(ef_sq)],
    }
