"""Knowledge-distillation primitives (Hinton et al. 2015) for IDKD.

* temperature-scaled soft labels,
* soft-label cross-entropy (the fine-tuning loss on D_ID),
* per-sample label averaging across neighbours (Algorithm 1, line 14),
* top-k sparse soft-label codec — beyond-paper adaptation that keeps label
  exchange ~2% of the weight-exchange bytes at LLM vocab sizes (DESIGN.md §3).

**Temperature convention (the one convention, both drivers):**
:func:`kd_loss` and :func:`sparse_kd_loss` return the **T²-scaled**
soft cross-entropy — Hinton et al.'s factor that keeps KD gradient
magnitudes comparable to hard-CE gradients when the two are mixed
(∂/∂z softCE(z/T) carries a 1/T² factor that the scaling cancels).
Consumers must NOT rescale: the seed's LM KD step divided the T² back
out, making the two drivers disagree by T² (= 100 at the paper's
T = 10). Pinned by tests/test_driver.py::test_kd_temperature_convention.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


def soft_labels(logits, temperature: float) -> jax.Array:
    """Teacher soft labels s_p = softmax(z / T). (paper line 5)."""
    return jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)


def kd_loss(student_logits, teacher_probs, temperature: float) -> jax.Array:
    """T²-scaled soft cross-entropy (Hinton et al. 2015)."""
    logp = jax.nn.log_softmax(
        student_logits.astype(jnp.float32) / temperature, axis=-1)
    ce = -jnp.sum(teacher_probs * logp, axis=-1)
    return (temperature ** 2) * ce


def average_labels(label_stack, mask) -> Tuple[jax.Array, jax.Array]:
    """LabelAverage (Algorithm 1, line 14).

    label_stack: (n_nodes, P, C) soft labels per node for the public set;
    mask:        (n_nodes, P) — node i included sample p in its D_ID.
    Returns (avg_labels (P, C), any_mask (P,)): per-sample average over the
    nodes that actually labelled it; samples labelled by no node get mask 0.
    """
    m = mask.astype(jnp.float32)
    num = jnp.einsum("np,npc->pc", m, label_stack.astype(jnp.float32))
    cnt = jnp.sum(m, axis=0)
    avg = num / jnp.maximum(cnt, 1.0)[:, None]
    return avg, cnt > 0


class SparseLabels(NamedTuple):
    """Top-k sparse soft labels (values + vocab indices)."""
    values: jax.Array   # (..., k) f32, renormalized
    indices: jax.Array  # (..., k) int32


def sparsify_labels(probs, k: int) -> SparseLabels:
    v, idx = jax.lax.top_k(probs, k)
    v = v / jnp.maximum(jnp.sum(v, -1, keepdims=True), 1e-9)
    return SparseLabels(v.astype(jnp.float32), idx.astype(jnp.int32))


def densify_labels(sparse: SparseLabels, vocab: int) -> jax.Array:
    zeros = jnp.zeros(sparse.values.shape[:-1] + (vocab,), jnp.float32)
    return _scatter_last(zeros, sparse.indices, sparse.values)


def _scatter_last(zeros, idx, vals):
    """Scatter vals into zeros along the last axis at idx."""
    flat_zeros = zeros.reshape(-1, zeros.shape[-1])
    flat_idx = idx.reshape(-1, idx.shape[-1])
    flat_vals = vals.reshape(-1, vals.shape[-1])
    rows = jnp.arange(flat_zeros.shape[0])[:, None]
    out = flat_zeros.at[rows, flat_idx].add(flat_vals)
    return out.reshape(zeros.shape)


def sparse_kd_loss(student_logits, sparse: SparseLabels,
                   temperature: float) -> jax.Array:
    """KD loss against top-k sparse teacher labels without densifying:
    CE = -Σ_k v_k · log_softmax(z/T)[idx_k]."""
    logp = jax.nn.log_softmax(
        student_logits.astype(jnp.float32) / temperature, axis=-1)
    gathered = jnp.take_along_axis(logp, sparse.indices, axis=-1)
    ce = -jnp.sum(sparse.values * gathered, axis=-1)
    return (temperature ** 2) * ce


def label_bytes(num_samples: int, num_classes: int, topk: int = 0) -> int:
    """Communication cost of one node's label payload (Table 6 analysis)."""
    if topk:
        return num_samples * topk * (4 + 4)   # f32 value + i32 index
    return num_samples * num_classes * 4
