# The paper's primary contribution: decentralized gossip training (topology,
# mixing, algorithms) + IDKD homogenization (ood, distill, idkd).
from repro.core.topology import Topology  # noqa: F401
from repro.core.mixing import (consensus_distance, make_dense_mixer,  # noqa: F401
                               make_ppermute_mixer)
from repro.core.algorithms import make_algorithm  # noqa: F401
