"""Graph topologies + gossip mixing matrices (paper §4.1).

The paper evaluates ring (n = 8/16/32/64), chain (Relay-SGD), and the
Florentine-families social network (Breiger & Pattison 1986, n = 15).
Mixing weights are Metropolis–Hastings, giving a symmetric doubly
stochastic W whose spectral gap 1 − λ₂(W) controls gossip convergence.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

# Florentine families marriage network (Breiger & Pattison 1986) — the
# same 15-node social graph networkx ships (Pucci is isolated and dropped).
FLORENTINE_FAMILIES = [
    "Acciaiuoli", "Albizzi", "Barbadori", "Bischeri", "Castellani",
    "Ginori", "Guadagni", "Lamberteschi", "Medici", "Pazzi", "Peruzzi",
    "Ridolfi", "Salviati", "Strozzi", "Tornabuoni",
]
_FLORENTINE_EDGES = [
    ("Acciaiuoli", "Medici"), ("Albizzi", "Ginori"), ("Albizzi", "Guadagni"),
    ("Albizzi", "Medici"), ("Barbadori", "Castellani"), ("Barbadori", "Medici"),
    ("Bischeri", "Guadagni"), ("Bischeri", "Peruzzi"), ("Bischeri", "Strozzi"),
    ("Castellani", "Peruzzi"), ("Castellani", "Strozzi"),
    ("Guadagni", "Lamberteschi"), ("Guadagni", "Tornabuoni"),
    ("Medici", "Ridolfi"), ("Medici", "Salviati"), ("Medici", "Tornabuoni"),
    ("Pazzi", "Salviati"), ("Peruzzi", "Strozzi"), ("Ridolfi", "Strozzi"),
    ("Ridolfi", "Tornabuoni"),
]


def ring_edges(n: int) -> List[Tuple[int, int]]:
    return [(i, (i + 1) % n) for i in range(n)]


def chain_edges(n: int) -> List[Tuple[int, int]]:
    return [(i, i + 1) for i in range(n - 1)]


def torus_edges(rows: int, cols: int) -> List[Tuple[int, int]]:
    e = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            e.append((i, r * cols + (c + 1) % cols))
            e.append((i, ((r + 1) % rows) * cols + c))
    return [(a, b) for a, b in e if a != b]


def full_edges(n: int) -> List[Tuple[int, int]]:
    return [(i, j) for i in range(n) for j in range(i + 1, n)]


def social_edges() -> List[Tuple[int, int]]:
    idx = {f: i for i, f in enumerate(FLORENTINE_FAMILIES)}
    return [(idx[a], idx[b]) for a, b in _FLORENTINE_EDGES]


def exponential_edges(n: int) -> List[Tuple[int, int]]:
    """Static exponential graph: i ~ i ± 2^k — O(log n) degree with a much
    larger spectral gap than the ring (Assran et al. 2019 family)."""
    e = set()
    k = 1
    while k < n:
        for i in range(n):
            e.add(tuple(sorted((i, (i + k) % n))))
        k *= 2
    return [t for t in e if t[0] != t[1]]


def hierarchical_ring_edges(num_pods: int, nodes_per_pod: int
                            ) -> List[Tuple[int, int]]:
    """Ring-of-rings: intra-pod ring + one inter-pod link per pod pair
    (node 0 of each pod joins an outer ring) — the multi-pod topology."""
    e = []
    for p in range(num_pods):
        base = p * nodes_per_pod
        e += [(base + i, base + (i + 1) % nodes_per_pod)
              for i in range(nodes_per_pod)]
    for p in range(num_pods):
        e.append((p * nodes_per_pod, ((p + 1) % num_pods) * nodes_per_pod))
    return [(a, b) for a, b in set(tuple(sorted(t)) for t in e) if a != b]


class Topology:
    """Undirected gossip graph with Metropolis–Hastings mixing weights."""

    def __init__(self, n: int, edges: List[Tuple[int, int]], name: str = ""):
        self.n = n
        self.name = name
        self._cache: Dict = {}
        self.adj: Dict[int, List[int]] = {i: [] for i in range(n)}
        for a, b in edges:
            if b not in self.adj[a]:
                self.adj[a].append(b)
            if a not in self.adj[b]:
                self.adj[b].append(a)
        for v in self.adj.values():
            v.sort()

    # -- constructors --------------------------------------------------------
    @staticmethod
    def make(kind: str, n: int) -> "Topology":
        topo = Topology._make(kind, n)
        import logging

        from repro.obs import log
        if log.get_logger().isEnabledFor(logging.DEBUG):
            # spectral_gap is an eigendecomposition — only pay for it
            # when the debug line will actually be shown
            log.debug("topology.make", kind=kind, n=n, name=topo.name,
                      edges=sum(len(v) for v in topo.adj.values()) // 2,
                      spectral_gap=round(topo.spectral_gap(), 4))
        return topo

    @staticmethod
    def _make(kind: str, n: int) -> "Topology":
        if kind == "ring":
            return Topology(n, ring_edges(n), f"ring{n}")
        if kind == "chain":
            return Topology(n, chain_edges(n), f"chain{n}")
        if kind == "full":
            return Topology(n, full_edges(n), f"full{n}")
        if kind == "social":
            if n != 15:
                raise ValueError("social (Florentine) topology has n=15")
            return Topology(15, social_edges(), "florentine15")
        if kind == "torus":
            r = int(np.sqrt(n))
            if r * r != n:
                raise ValueError("torus needs square n")
            return Topology(n, torus_edges(r, r), f"torus{n}")
        if kind == "exponential":
            return Topology(n, exponential_edges(n), f"exp{n}")
        raise ValueError(f"unknown topology {kind!r}")

    def neighbors(self, i: int) -> List[int]:
        return self.adj[i]

    def edge_key(self) -> Tuple:
        """Canonical hashable identity: (n, sorted undirected edge set).
        Two Topology objects with the same wiring share one key — the
        scheduler's compiled-object caches key on this, so re-resolved
        rewire events and same-wiring graphs hit warm caches."""
        cached = self._cache.get("edge_key")
        if cached is None:
            cached = (self.n, tuple(sorted(
                (i, j) for i, nbrs in self.adj.items()
                for j in nbrs if i < j)))
            self._cache["edge_key"] = cached
        return cached

    def degree(self, i: int) -> int:
        return len(self.adj[i])

    def max_degree(self) -> int:
        return max(self.degree(i) for i in range(self.n))

    def neighbor_arrays(self, include_self: bool = True
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded neighbour index lists for gather-based label exchange.

        Returns ``(nbr (n, D) int32, valid (n, D) float32)`` with
        D = max_degree (+1 when ``include_self``); slot d of row i is the
        d-th contributor to node i (self first). Padding slots point at
        node 0 with valid = 0 so gathers stay in bounds. Replaces dense
        (n, n) membership matrices: exchanges built on these are
        O(Σ deg) in the graph instead of O(n²).
        """
        key = ("nbr_arrays", include_self)
        cached = self._cache.get(key)
        if cached is not None:
            return cached
        D = self.max_degree() + (1 if include_self else 0)
        nbr = np.zeros((self.n, max(D, 1)), np.int32)
        valid = np.zeros((self.n, max(D, 1)), np.float32)
        for i in range(self.n):
            row = ([i] if include_self else []) + self.adj[i]
            nbr[i, :len(row)] = row
            valid[i, :len(row)] = 1.0
        self._cache[key] = (nbr, valid)
        return nbr, valid

    # -- mixing matrix ---------------------------------------------------------
    def mixing_matrix(self, active=None) -> np.ndarray:
        """Metropolis–Hastings: W_ij = 1/(1+max(d_i,d_j)) for edges; rows sum 1.

        ``active`` (optional (n,) bool mask) restricts the exchange to the
        induced subgraph of available nodes — churn support: degrees are
        recomputed on the subgraph so the active block stays symmetric
        doubly stochastic, and each down node gets an identity row
        (W_ii = 1, it neither sends nor receives).
        """
        n = self.n
        if active is None:
            act = np.ones(n, bool)
        else:
            act = np.asarray(active, bool)
            if act.shape != (n,):
                raise ValueError(f"active mask shape {act.shape} != ({n},)")
        deg = np.array([sum(act[j] for j in self.adj[i]) if act[i] else 0
                        for i in range(n)])
        W = np.zeros((n, n))
        for i in range(n):
            if not act[i]:
                continue
            for j in self.adj[i]:
                if act[j]:
                    W[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        for i in range(n):
            W[i, i] = 1.0 - W[i].sum()
        return W

    def spectral_gap(self) -> float:
        ev = np.linalg.eigvalsh(self.mixing_matrix())
        return float(1.0 - np.abs(ev)[::-1][1])

    def is_connected(self) -> bool:
        seen = {0}
        stack = [0]
        while stack:
            for j in self.adj[stack.pop()]:
                if j not in seen:
                    seen.add(j)
                    stack.append(j)
        return len(seen) == self.n

    # -- RelaySum support ------------------------------------------------------
    def is_tree(self) -> bool:
        m = sum(len(v) for v in self.adj.values()) // 2
        return self.is_connected() and m == self.n - 1


class TimeVaryingTopology:
    """One-peer time-varying exponential scheme (paper §4.1 mentions
    time-varying graphs): at step t every node talks to its ±2^(t mod log n)
    neighbour only — constant degree per round, log-n rounds to mix.
    ``mixing_matrix(t)`` returns the round-t doubly stochastic W."""

    def __init__(self, n: int):
        self.n = n
        self.num_rounds = max(1, int(np.ceil(np.log2(n))))

    def round_topology(self, t: int) -> "Topology":
        k = 2 ** (t % self.num_rounds)
        edges = [(i, (i + k) % self.n) for i in range(self.n)]
        edges = [e for e in set(tuple(sorted(p)) for p in edges)
                 if e[0] != e[1]]
        return Topology(self.n, edges, f"onepeer{self.n}@{t}")

    def mixing_matrix(self, t: int) -> np.ndarray:
        return self.round_topology(t).mixing_matrix()
