"""In-process decentralized training simulator (CPU accuracy experiments).

All N nodes live in one process: parameters are node-stacked pytrees
(leading axis = node), per-node gradients come from ``vmap``, gossip is the
dense mixing matrix — mathematically identical to the paper's MPI cluster
under synchronous rounds, which is what the paper runs.

The step loop is the unified driver (``core.driver``): loss adapters +
``make_step`` build the jitted steps, per-node sampling runs on device,
and the inner loop executes as ``lax.scan`` chunks between eval
boundaries (``driver_mode="auto"`` keeps lax-conv models on the
per-step host runner on CPU — DESIGN.md §5 CPU caveats;
``ModelConfig.conv_backend="im2col"`` lifts that). ``driver_mode=
"shard"`` places the node axis on a device mesh instead: the step runs
under ``shard_map`` with ppermute/psum gossip and the homogenization
round exchanges only top-k payloads across the node axis (DESIGN.md
§7) — trajectory-equivalent to the node-stacked runners on supported
(ring/complete) graphs, with churn rejected up front.

The *outer* loop is the federation scheduler (``repro.sched``, DESIGN.md
§6): ``run()`` compiles a :class:`~repro.sched.Schedule` (or accepts a
custom one) and replays it through ``sched.run_schedule`` — periodic
re-homogenization rounds every ``IDKDConfig.every_k_steps``, churn
(nodes dropping out and rejoining with masked Metropolis mixing), graph
rewires, mid-run checkpoint capture/resume, and a unified per-round
communication ledger all ride on that one loop. A 1-round schedule is
byte-identical to the pre-scheduler behaviour (degenerate-schedule
equivalence).

Supports the full method grid of Tables 2–7:
  * algorithms: dsgd / dsgdm / qg-dsgdm-n / d2 / relaysgd / centralized
  * ``kd_mode``: None (no distillation), "vanilla" (no OoD filter — the
    QG-DSGDm-N + KD baseline), "idkd" (MSP-filtered — the paper's method)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sched
from repro.configs.base import IDKDConfig, ModelConfig, TrainConfig
from repro.core import distill, driver, idkd, labeling
from repro.core.algorithms import make_algorithm
from repro.core.mixing import (consensus_distance, make_mixer,
                               normalize_compression, payload_elem_count)
from repro.core.topology import Topology
from repro.data.dirichlet import dirichlet_partition, partition_stats
from repro.data.synthetic import ClassificationData
from repro.models import build_model
from repro.optim.schedules import step_decay


@dataclass
class SimResult:
    final_acc: float
    acc_history: List[float] = field(default_factory=list)
    loss_history: List[float] = field(default_factory=list)
    consensus_history: List[float] = field(default_factory=list)
    pre_hist: Optional[np.ndarray] = None    # (n, C) class hists pre-IDKD
    post_hist: Optional[np.ndarray] = None   # (n, C) class hists post-IDKD
    thresholds: Optional[np.ndarray] = None
    id_fraction: float = 0.0                 # fraction of D_P kept as ID
    comm_bytes_per_iter: float = 0.0
    label_bytes_total: float = 0.0
    wall_seconds: float = 0.0
    rounds: List[Dict] = field(default_factory=list)  # per-round diagnostics
    ledger: Optional[Dict] = None            # sched.CommLedger.as_dict()
    captured: Optional[Dict] = None          # run(capture_at=...) snapshot


class _SimFederation(sched.CompiledFederationHooks):
    """Scheduler hooks binding the simulator's samplers, steps, and
    mixers to the federation loop (cache machinery lives on
    :class:`sched.CompiledFederationHooks`); the prebuilt default-mixer
    steps from ``sim._build_jits`` are reused for the all-up mask on the
    run's own gossip graph."""

    def __init__(self, sim: "DecentralizedSimulator", result: SimResult,
                 idkd_cfg: IDKDConfig):
        super().__init__()
        self.sim = sim
        self.model = sim.model
        self.algo = sim.algo
        self.lr_fn = sim.lr_fn
        self.driver_mode = sim.driver_mode
        self.result = result
        self.idkd_cfg = idkd_cfg
        self.sparse_round = False
        self.compression = sim.compression
        self.gossip = sim.gossip            # re-set per run by init_comm
        self._node_mesh = sim.node_mesh     # shard mode: one shared mesh
        self.model_parallel = sim.model_parallel
        self.priv_parts = driver.pad_partitions(sim.parts)
        self.plain_sampler = driver.make_classification_sampler(
            self.priv_parts, sim.data.train_x, sim.data.train_y,
            sim.mcfg.num_classes, sim.tcfg.batch_size)
        self.kd_sampler = None

    def reset(self, result: SimResult) -> None:
        """Rebind for a fresh run, keeping the compiled mixer/step/runner
        caches (repeated ``sim.run()`` calls — the bench warm-up path and
        checkpoint-resume runs — pay zero recompiles)."""
        self.result = result
        self.phase = "plain"
        self.ctx = None
        self.sparse_round = False
        # drop any previous run's (likely closed) telemetry sink; each
        # run() passes its own through run_schedule — same for the
        # resilience config and any leftover injected-fault state
        self.telemetry = None
        self.resil = None
        self.wire_fault = None

    # ----------------------------------------------------- cache plumbing
    def _make_mixer(self, topo: Topology, active, stale=None):
        sim = self.sim
        # the prebuilt mixer knows nothing of injected wire faults —
        # fault segments rebuild through make_mixer's validated wrap
        if (active is None and stale is None
                and self._fault_key() is None
                and topo.edge_key() == sim.gossip_topo.edge_key()
                and self._force_state == sim._prebuilt_stateful):
            return sim.mixer
        return make_mixer(topo, "dense", wire_dtype=sim.wire_dtype,
                          active=active, stale=stale, **self._mixer_opts())

    def _adapter(self):
        return {
            "plain": driver.classification_adapter,
            "kd_dense": driver.dense_kd_adapter(
                self.idkd_cfg.temperature, self.idkd_cfg.kd_weight),
            "kd_sparse": driver.sparse_kd_adapter(
                self.idkd_cfg.temperature, self.idkd_cfg.kd_weight),
        }[self.phase]

    def _sampler(self):
        return (self.plain_sampler if self.phase == "plain"
                else self.kd_sampler)

    def _base_step(self, topo: Topology, active: np.ndarray,
                   stale: np.ndarray):
        sim = self.sim
        # the prebuilt steps from sim._build_jits were compiled without
        # the metrics/guard carries and fault-free — telemetry, guarded,
        # and fault segments rebuild through the cache
        if (active.all() and not stale.any() and not self._metrics_on()
                and self._fault_key() is None
                and self._guard_spec() is None
                and topo.edge_key() == sim.gossip_topo.edge_key()
                and self._force_state == sim._prebuilt_stateful):
            return {"plain": sim._plain_step, "kd_dense": sim._kd_step,
                    "kd_sparse": sim._sparse_kd_step}[self.phase]
        return super()._base_step(topo, active, stale)

    # -------------------------------------------------------------- hooks
    def restore_ctx(self, ctx: Dict, phase: str) -> None:
        """Mid-phase resume from a durable snapshot: rebuild the KD
        sampler state straight from the snapshot's flat ctx payload
        (exactly what :meth:`on_round` would have produced) instead of
        re-running the label round."""
        sim = self.sim
        ctx = {k: jnp.asarray(v) for k, v in ctx.items()}
        self.sparse_round = "values" in ctx
        payload = ((ctx["values"], ctx["indices"]) if self.sparse_round
                   else ctx["labels"])
        self.ctx = ctx
        if self.kd_sampler is None:
            self.kd_sampler = driver.make_homogenized_sampler(
                self.priv_parts,
                driver.PaddedParts(ctx["pub_idx"], ctx["pub_size"]),
                sim.data.train_x, sim.data.train_y, sim.public_x,
                ctx["weights"], payload, sim.mcfg.num_classes,
                sim.tcfg.batch_size)
        self.phase = phase

    def on_round(self, params, round_index: int, step: int, topo: Topology,
                 active: np.ndarray) -> np.ndarray:
        sim = self.sim
        cfg = self.idkd_cfg
        hom = sim._homogenize(params, cfg, topo,
                              None if active.all() else active,
                              wire_fault=self._fault_key())
        self.sparse_round = isinstance(hom, labeling.SparseHomogenizedSet)
        payload = ((hom.labels.values, hom.labels.indices)
                   if self.sparse_round else np.asarray(hom.labels))
        weights = np.asarray(hom.weights)
        self.ctx = driver.homogenized_ctx(weights, payload,
                                          len(sim.public_x))
        if self.kd_sampler is None:
            self.kd_sampler = driver.make_homogenized_sampler(
                self.priv_parts,
                driver.PaddedParts(self.ctx["pub_idx"],
                                   self.ctx["pub_size"]),
                sim.data.train_x, sim.data.train_y, sim.public_x,
                weights, payload, sim.mcfg.num_classes,
                sim.tcfg.batch_size)
        self.phase = "kd_sparse" if self.sparse_round else "kd_dense"

        # diagnostics: last round wins the summary fields, every round is
        # appended to result.rounds
        res = self.result
        res.thresholds = np.asarray(hom.thresholds)
        res.id_fraction = float(np.mean(np.asarray(hom.id_masks)))
        res.post_hist = sim._post_histograms(hom)
        # wire cost: sparse backends ship each node's own top-k payload;
        # the dense backend always ships full (P, C) rows
        k_wire = (min(cfg.label_topk or labeling.DEFAULT_TOPK,
                      sim.mcfg.num_classes) if self.sparse_round else 0)
        id_counts = np.asarray(hom.id_masks).sum(axis=1)
        per_node = np.array([distill.label_bytes(int(c),
                                                 sim.mcfg.num_classes,
                                                 k_wire)
                             for c in id_counts], np.float64)
        res.rounds.append({"step": step, "round": round_index,
                           "id_fraction": res.id_fraction,
                           "label_bytes": float(per_node.sum())})
        # telemetry: run_schedule reads this right after on_round and
        # forwards it to hooks.on_labels + the "labels" run-log event
        stats = {"thresholds": np.asarray(hom.thresholds),
                 "selected": id_counts, "id_fraction": res.id_fraction,
                 "detector": cfg.detector}
        if self.sparse_round:
            mean_ov, per_edge = labeling.neighbor_topk_overlap(
                np.asarray(hom.labels.indices), topo)
            stats["topk_overlap"] = mean_ov
            stats["topk_overlap_per_edge"] = per_edge
        self.last_round_stats = stats
        return per_node

    def on_eval(self, params, step: int, losses) -> None:
        acc, nll = self.sim._eval(params)
        self.result.acc_history.append(acc)
        self.result.loss_history.append(nll)
        cons = float(consensus_distance(params))
        self.result.consensus_history.append(cons)
        tel = self.telemetry
        if tel is not None:
            tel.event("accuracy", step=step, acc=acc, nll=nll,
                      consensus=cons)
        if not (np.isfinite(nll) and np.isfinite(acc)):
            if tel is not None:
                tel.event("health", step=step, kind="eval_nonfinite",
                          acc=acc, nll=nll)


class DecentralizedSimulator:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 data: ClassificationData, public_x: Optional[np.ndarray] = None,
                 kd_mode: Optional[str] = None, eval_every: int = 50,
                 eval_batches: int = 4, driver_mode: str = "auto",
                 wire_dtype: str = "float32", model_parallel: int = 1):
        self.mcfg = model_cfg
        self.tcfg = train_cfg
        self.data = data
        self.public_x = public_x
        self.kd_mode = kd_mode
        self.eval_every = eval_every
        self.eval_batches = eval_batches
        self.driver_mode = driver.resolve_runner_mode(
            driver_mode, model_cfg.arch_type, model_cfg.conv_backend)
        # shard mode only: width of the federation mesh's "model" axis
        # (1 = the 1-D node mesh; DESIGN.md §10)
        self.model_parallel = model_parallel
        if model_parallel > 1 and self.driver_mode != "shard":
            raise ValueError(
                "model_parallel > 1 shards each replica over the 2-D "
                "federation mesh and needs driver_mode='shard'")
        # paper-faithful full-precision mixing is the simulator default;
        # the configured value reaches the mixer, the ledger, and the
        # result metadata alike (no more pinned "float32" anywhere)
        self.wire_dtype = wire_dtype

        n = train_cfg.num_nodes
        self.topology = Topology.make(train_cfg.topology, n)
        if train_cfg.algorithm == "centralized":
            # exact averaging reference: the complete graph's Metropolis
            # matrix is exactly uniform 1/n mixing — and its masked path
            # averages over the surviving nodes under churn
            self.gossip_topo = Topology.make("full", n)
        else:
            self.gossip_topo = self.topology
        # the prebuilt mixer/steps bake in the config's compression +
        # gossip mode; a schedule that needs a different statefulness
        # (e.g. stale churn on an uncompressed config) rebuilds through
        # the scheduler's cache instead of reusing these
        self.compression = normalize_compression(train_cfg.compression_spec)
        self.gossip = train_cfg.gossip
        self._prebuilt_stateful = bool(self.compression is not None
                                       or self.gossip == "delayed")
        self.mixer = make_mixer(self.gossip_topo, "dense",
                                wire_dtype=self.wire_dtype,
                                compression=self.compression,
                                gossip=self.gossip)
        self.algo = make_algorithm(train_cfg.algorithm,
                                   topology=self.topology,
                                   momentum=train_cfg.momentum,
                                   weight_decay=train_cfg.weight_decay)
        self.model = build_model(model_cfg)

        self.node_mesh = None
        if self.driver_mode == "shard":
            # every shard-mode limitation fails here, at construction —
            # not mid-schedule when a step/round first compiles
            from repro.core.mixing import shard_supported_topology
            if not shard_supported_topology(self.gossip_topo):
                raise ValueError(
                    f"driver_mode='shard' gossips on ring/complete graphs "
                    f"only; topology {self.gossip_topo.name!r} needs the "
                    "node-stacked runners (driver_mode='scan' or 'host')")
            if kd_mode is not None and \
                    not shard_supported_topology(self.topology):
                # centralized runs gossip on the complete graph but
                # label-exchange on the run topology — validate both
                raise ValueError(
                    f"driver_mode='shard' exchanges labels on "
                    f"ring/complete graphs only; topology "
                    f"{self.topology.name!r} needs the node-stacked "
                    "runners (driver_mode='scan' or 'host')")
            icfg = train_cfg.idkd or IDKDConfig()
            if kd_mode is not None and icfg.label_backend == "dense":
                raise ValueError(
                    "driver_mode='shard' moves only top-k label payloads "
                    "across the node axis; set IDKDConfig.label_backend="
                    "'sparse' (or 'fused'), or use driver_mode='scan'/"
                    "'host' for the dense oracle")
            from repro.launch.mesh import make_federation_mesh
            self.node_mesh = make_federation_mesh(n, self.model_parallel)

        rng = np.random.default_rng(train_cfg.seed)
        if train_cfg.algorithm == "centralized":
            # paper: centralized reference uses a random IID distribution
            idx = rng.permutation(len(data.train_y))
            self.parts = [np.asarray(p) for p in np.array_split(idx, n)]
        else:
            self.parts = dirichlet_partition(
                data.train_y, n, alpha=getattr(train_cfg, "alpha", 0.1),
                rng=rng)
        self.lr_fn = step_decay(train_cfg.lr, train_cfg.steps,
                                train_cfg.lr_decay_milestones,
                                train_cfg.lr_decay_factor)
        self._fed: Optional[_SimFederation] = None
        self._build_jits()

    # ------------------------------------------------------------------ setup
    def _build_jits(self):
        """Steps come from the unified driver (core.driver.make_step, or
        make_shard_step under driver_mode="shard"); only the diagnostics
        (forward/eval) are built here."""
        model, mixer, algo = self.model, self.mixer, self.algo
        icfg = self.tcfg.idkd or IDKDConfig()

        if self.driver_mode == "shard":
            self._plain_step = driver.make_shard_step(
                model, algo, driver.classification_adapter,
                mesh=self.node_mesh, topology=self.gossip_topo,
                compression=self.compression, gossip=self.gossip)
            self._sparse_kd_step = driver.make_shard_step(
                model, algo,
                driver.sparse_kd_adapter(icfg.temperature, icfg.kd_weight),
                mesh=self.node_mesh, topology=self.gossip_topo,
                compression=self.compression, gossip=self.gossip)
            # dense label payloads never exist in shard mode (top-k wire)
            self._kd_step = None
        else:
            self._plain_step = driver.make_step(
                model, algo, mixer, driver.classification_adapter)
            self._kd_step = driver.make_step(
                model, algo, mixer,
                driver.dense_kd_adapter(icfg.temperature, icfg.kd_weight))
            self._sparse_kd_step = driver.make_step(
                model, algo, mixer,
                driver.sparse_kd_adapter(icfg.temperature, icfg.kd_weight))

        @jax.jit
        def forward_logits(params, images):
            """vmapped per-node forward: images (n, B, ...) -> (n, B, C)."""
            return jax.vmap(
                lambda p, x: model.forward(p, {"images": x})[0])(params, images)

        @jax.jit
        def consensus_eval(params, images, labels, mask):
            mean_p = jax.tree.map(lambda t: jnp.mean(
                t.astype(jnp.float32), axis=0).astype(t.dtype), params)
            logits, _ = model.forward(mean_p, {"images": images})
            hit = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
            cnt = jnp.maximum(jnp.sum(mask), 1.0)
            acc = jnp.sum(hit * mask) / cnt
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            per = -jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
            nll = jnp.sum(per * mask) / cnt
            return acc, nll

        self._forward_logits = forward_logits
        self._consensus_eval = consensus_eval

    def _stacked_init(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = self.model.init(key)   # identical init on all nodes (paper)
        n = self.tcfg.num_nodes
        return jax.tree.map(lambda t: jnp.broadcast_to(t[None],
                                                       (n,) + t.shape), params)

    # -------------------------------------------------------------- inference
    def _node_logits(self, params, x: np.ndarray, batch: int = 256):
        """All-node logits on a shared array x: returns (n, len(x), C).
        Stays on device — shard mode keeps the stack sharded over the
        node mesh axis (params carry the placement, so the vmapped
        forward partitions over nodes); host callers np.asarray it."""
        n = self.tcfg.num_nodes
        outs = []
        for i in range(0, len(x), batch):
            xb = jnp.asarray(x[i:i + batch])
            xb = jnp.broadcast_to(xb[None], (n,) + xb.shape)
            outs.append(self._forward_logits(params, xb))
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)

    def _per_node_val_inputs(self, batch: int = 256):
        """Each node's own private samples (n, m, ...) — its ID set
        (paper: D_V^i; the node's training samples)."""
        m = min(min(len(p) for p in self.parts), batch)
        idx = np.stack([p[:m] for p in self.parts])
        return jnp.asarray(self.data.train_x[idx])

    def _per_node_val_logits(self, params, batch: int = 256):
        """Each node's logits on its own private samples (ID scores)."""
        return self._forward_logits(params,
                                    self._per_node_val_inputs(batch))

    # ------------------------------------------------------------------- run
    def default_schedule(self) -> sched.Schedule:
        """The schedule this simulator's config asks for: eval boundaries
        plus the IDKD rounds (``start_step`` + ``num_rounds`` ×
        ``every_k_steps``) when KD is active."""
        idkd_cfg = self.tcfg.idkd or IDKDConfig()
        rounds = (sched.idkd_round_steps(idkd_cfg, self.tcfg.steps)
                  if self._kd_active(idkd_cfg) else ())
        return sched.compile_schedule(self.tcfg.steps, self.eval_every,
                                      round_steps=rounds,
                                      gossip=self.gossip)

    def _kd_active(self, idkd_cfg: IDKDConfig) -> bool:
        return (self.kd_mode is not None and self.public_x is not None
                and idkd_cfg.start_step < self.tcfg.steps)

    def run(self, schedule: Optional[sched.Schedule] = None,
            resume: Optional[Dict] = None,
            capture_at: Optional[int] = None,
            telemetry=None, resil=None) -> SimResult:
        """Replay the federation schedule through the scheduler: chunked
        scan/host runners between boundaries, homogenization rounds
        re-labeling and refreshing the KD sampler as they fire, churn /
        rewire events remaking the mixer, and every byte of gossip and
        label traffic logged to the communication ledger.

        ``resume`` is a ``{"params", "opt_state", "key", "step"}`` state
        (as produced by ``capture_at``) restarting mid-schedule at a legal
        boundary; ``capture_at`` snapshots the state at that boundary into
        ``result.captured``.

        ``telemetry`` (a :class:`repro.obs.Telemetry`) turns on the
        observability layers for this run — JSONL run events, the
        on-device metrics bus, and trace spans (DESIGN.md §11). The
        trajectory is bitwise identical with it on or off.

        ``resil`` (a :class:`repro.resil.Resilience`) turns on the
        resilience layer (DESIGN.md §12): the on-device health guard,
        quarantine-on-trip, durable snapshots with auto-resume, and
        rollback-on-divergence. With guards on and no fault firing the
        trajectory is bitwise identical to guards off. A ``crash``
        :class:`~repro.sched.FaultEvent` in the schedule raises
        :class:`repro.resil.SimulatedCrash` out of this method; calling
        ``run()`` again with the same ``resil.snapshot_dir`` resumes
        from the last durable snapshot.
        """
        t0 = time.time()
        tcfg = self.tcfg
        n = tcfg.num_nodes
        idkd_cfg = tcfg.idkd or IDKDConfig()
        kd_active = self._kd_active(idkd_cfg)
        if schedule is None:
            schedule = self.default_schedule()
        elif schedule.round_steps and not kd_active:
            raise ValueError(
                "schedule contains homogenization rounds but the simulator "
                "has no kd_mode/public data to run them")
        if schedule.gossip != self.gossip:
            raise ValueError(
                f"schedule compiled with gossip={schedule.gossip!r} but "
                f"this simulator's TrainConfig.gossip is {self.gossip!r}; "
                "pass gossip= to sched.compile_schedule (or use "
                "default_schedule()) so the prebuilt steps and the "
                "schedule agree")

        result = SimResult(final_acc=0.0)
        result.pre_hist = partition_stats(self.data.train_y, self.parts,
                                          self.mcfg.num_classes)
        if resume is not None:
            params, opt_state = resume["params"], resume["opt_state"]
            key, resume_step = resume["key"], int(resume["step"])
        else:
            params = self._stacked_init()
            opt_state = self.algo.init(params)
            key = jax.random.PRNGKey(tcfg.seed)
            resume_step = 0
        if self.driver_mode == "shard":
            # churn / unsupported rewires fail here, before any training
            sched.validate_shard_schedule(schedule, n, self.model_parallel)
            from repro.launch.sharding import federation_shardings
            params = jax.device_put(
                params, federation_shardings(params, self.node_mesh, n))
            opt_state = jax.device_put(
                opt_state,
                federation_shardings(opt_state, self.node_mesh, n))

        proto = self.model.init(jax.random.PRNGKey(0))
        nparams = sum(x.size for x in jax.tree.leaves(proto))
        param_dtype = str(jax.tree.leaves(proto)[0].dtype)
        elem_bytes = sched.wire_elem_bytes(self.wire_dtype, param_dtype)
        # compressed wires ship (value, int32 index) pairs of the top-k /
        # random-k per-node payload instead of the dense parameter row
        payload_elems = (payload_elem_count(proto, self.compression,
                                            node_stacked=False)
                         if self.compression is not None else None)
        index_bytes = 4 if self.compression is not None else 0
        comp_kind, comp_frac = (self.compression
                                if self.compression is not None
                                else ("none", 0.0))
        ledger = sched.CommLedger(n, meta={
            "topology": self.gossip_topo.name,
            "wire_dtype": self.wire_dtype,
            "param_count": int(nparams),
            "compression": comp_kind, "compression_frac": comp_frac,
            "gossip": schedule.gossip})
        if self._fed is None:
            self._fed = _SimFederation(self, result, idkd_cfg)
        else:
            self._fed.reset(result)
        fed = self._fed
        params, opt_state, key, captured = sched.run_schedule(
            schedule, fed, params, opt_state, key,
            topology=self.gossip_topo, ledger=ledger,
            param_count=int(nparams), elem_bytes=elem_bytes,
            payload_elems=payload_elems, index_bytes=index_bytes,
            resume_step=resume_step, capture_at=capture_at,
            telemetry=telemetry, resil=resil)

        result.final_acc = (result.acc_history[-1]
                            if result.acc_history else 0.0)
        steps_run = ledger.gossip_steps()
        result.comm_bytes_per_iter = (
            ledger.gossip_bytes / steps_run / n if steps_run else 0.0)
        result.label_bytes_total = ledger.label_bytes
        result.ledger = ledger.as_dict()
        result.captured = captured
        result.wall_seconds = time.time() - t0
        return result

    # ------------------------------------------------------------ IDKD round
    def _homogenize(self, params, idkd_cfg: IDKDConfig,
                    topology: Optional[Topology] = None,
                    active: Optional[np.ndarray] = None,
                    wire_fault=None) -> labeling.HomogenizedResult:
        # kd_mode="vanilla" is the no-OoD-filter baseline (every public
        # sample kept) — the engine's filter_ood=False branch
        filter_ood = self.kd_mode != "vanilla"
        topo = topology or self.topology
        streaming = (idkd_cfg.stream_labels
                     and idkd_cfg.label_backend != "dense")
        if wire_fault is not None and not wire_fault.is_noop():
            if self.driver_mode == "shard":
                raise ValueError(
                    "label-round fault injection is unsupported under "
                    "driver_mode='shard' — run fault schedules "
                    "node-stacked (DESIGN.md §12)")
            if streaming:
                # the streaming round never materializes the logits
                # stack to corrupt-and-validate, so both fault kinds
                # degrade to dropped payloads: merge the faulted senders
                # out of the gossip-weight averaging via the active mask
                from repro.obs import log
                n = self.tcfg.num_nodes
                lost = np.zeros(n, bool)
                lost[list(wire_fault.senders)] = True
                act = (np.ones(n, bool) if active is None
                       else np.asarray(active, bool)) & ~lost
                if not act.any():
                    raise RuntimeError("label-round fault leaves no "
                                       "valid label payloads")
                log.warning("label_payload_lost",
                            nodes=np.flatnonzero(lost).tolist())
                active = act
                wire_fault = None
        if self.driver_mode == "shard":
            if active is not None:
                raise ValueError("sharded label rounds have no churn "
                                 "path; run churn schedules node-stacked")
            if streaming:
                # scan inside the shard body: no device ever holds more
                # than its local chunk of logits (DESIGN.md §8)
                return labeling.shard_streaming_label_round(
                    self.model, params, jnp.asarray(self.public_x),
                    self._per_node_val_inputs(), topo, idkd_cfg,
                    mesh=self.node_mesh, filter_ood=filter_ood)
            # score/select shard-local, top-k-only exchange (DESIGN.md §7)
            return labeling.shard_label_round(
                self._node_logits(params, self.public_x),
                self._per_node_val_logits(params), topo, idkd_cfg,
                mesh=self.node_mesh, filter_ood=filter_ood)
        if streaming:
            # microbatched fused pass — the (n, P, C) stack never exists
            return labeling.streaming_label_round(
                self.model, params, jnp.asarray(self.public_x),
                self._per_node_val_inputs(), topo, idkd_cfg,
                filter_ood=filter_ood, active=active)
        # one-shot oracle paths (dense backend, or stream_labels=False):
        # cal_logits=None = D_C is the public set (paper's default)
        logits = self._node_logits(params, self.public_x)
        if wire_fault is not None and not wire_fault.is_noop():
            # label-round wire faults: a dropped payload is lost outright
            # and a corrupted one fails payload validation — both degrade
            # to "that node contributes no labels this round" by merging
            # it out of the gossip-weight averaging via the active mask
            from repro.obs import log
            from repro.resil.faults import (DEFAULT_MAX_ABS, corrupt_rows,
                                            payload_valid)
            n = self.tcfg.num_nodes
            lost = np.zeros(n, bool)
            lost[list(wire_fault.drop)] = True
            if wire_fault.corrupt:
                logits = corrupt_rows(logits, wire_fault.corrupt,
                                      wire_fault.mode)
                valid = np.asarray(payload_valid(
                    jnp.reshape(logits, (n, -1)), DEFAULT_MAX_ABS))
                lost |= ~valid
            act = (np.ones(n, bool) if active is None
                   else np.asarray(active, bool)) & ~lost
            if not act.any():
                raise RuntimeError(
                    "label-round fault leaves no valid label payloads")
            if lost.any():
                log.warning("label_payload_invalid",
                            nodes=np.flatnonzero(lost).tolist())
            active = act
        return labeling.label_round(
            logits,
            self._per_node_val_logits(params), None, topo, idkd_cfg,
            backend=idkd_cfg.label_backend, filter_ood=filter_ood,
            active=active)

    def _post_histograms(self, hom: labeling.HomogenizedResult) -> np.ndarray:
        C = self.mcfg.num_classes
        sparse_round = isinstance(hom, labeling.SparseHomogenizedSet)
        hists = []
        for i in range(self.tcfg.num_nodes):
            soft = (distill.SparseLabels(hom.labels.values[i],
                                         hom.labels.indices[i])
                    if sparse_round else hom.labels[i])
            h = idkd.class_histogram(
                jnp.asarray(self.data.train_y[self.parts[i]]),
                soft, hom.weights[i], C)
            hists.append(np.asarray(h))
        return np.stack(hists)

    # ------------------------------------------------------------------ eval
    def _eval(self, params, batch: int = 256):
        """Deterministic test-set sweep: contiguous batches, each sample
        counted at most once (the seed's ``(b*B) % len`` wraparound could
        short-batch and double-count, adding noise to every accuracy
        number). The last batch is zero-padded with a mask so the jitted
        eval keeps one shape; means are weighted by true sample count."""
        N = len(self.data.test_y)
        num_batches = min(self.eval_batches, -(-N // batch))
        tot_acc = tot_nll = tot_cnt = 0.0
        for b in range(num_batches):
            lo = b * batch
            hi = min(lo + batch, N)
            cnt = hi - lo
            xb = self.data.test_x[lo:hi]
            yb = self.data.test_y[lo:hi]
            if cnt < batch:
                pad = batch - cnt
                xb = np.concatenate([xb, np.zeros((pad,) + xb.shape[1:],
                                                  xb.dtype)])
                yb = np.concatenate([yb, np.zeros((pad,), yb.dtype)])
            mask = np.zeros((batch,), np.float32)
            mask[:cnt] = 1.0
            a, l = self._consensus_eval(params, jnp.asarray(xb),
                                        jnp.asarray(yb), jnp.asarray(mask))
            tot_acc += float(a) * cnt
            tot_nll += float(l) * cnt
            tot_cnt += cnt
        acc, nll = tot_acc / tot_cnt, tot_nll / tot_cnt
        if not (np.isfinite(nll) and np.isfinite(acc)):
            # a diverged / guard-worthy model state: surface it loudly
            # instead of letting NaN accuracies ride the result silently
            from repro.obs import log
            log.warning("eval_nonfinite", acc=acc, nll=nll)
        return acc, nll

