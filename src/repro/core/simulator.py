"""In-process decentralized training simulator (CPU accuracy experiments).

All N nodes live in one process: parameters are node-stacked pytrees
(leading axis = node), per-node gradients come from ``vmap``, gossip is the
dense mixing matrix — mathematically identical to the paper's MPI cluster
under synchronous rounds, which is what the paper runs.

Supports the full method grid of Tables 2–7:
  * algorithms: dsgd / dsgdm / qg-dsgdm-n / d2 / relaysgd / centralized
  * ``kd_mode``: None (no distillation), "vanilla" (no OoD filter — the
    QG-DSGDm-N + KD baseline), "idkd" (MSP-filtered — the paper's method)
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import IDKDConfig, ModelConfig, TrainConfig
from repro.core import distill, idkd, labeling
from repro.core.algorithms import make_algorithm
from repro.core.mixing import consensus_distance, make_dense_mixer
from repro.core.topology import Topology
from repro.data.dirichlet import dirichlet_partition, partition_stats
from repro.data.pipeline import HomogenizedSampler, NodeSampler
from repro.data.synthetic import ClassificationData
from repro.models import build_model
from repro.optim.schedules import step_decay


@dataclass
class SimResult:
    final_acc: float
    acc_history: List[float] = field(default_factory=list)
    loss_history: List[float] = field(default_factory=list)
    consensus_history: List[float] = field(default_factory=list)
    pre_hist: Optional[np.ndarray] = None    # (n, C) class hists pre-IDKD
    post_hist: Optional[np.ndarray] = None   # (n, C) class hists post-IDKD
    thresholds: Optional[np.ndarray] = None
    id_fraction: float = 0.0                 # fraction of D_P kept as ID
    comm_bytes_per_iter: float = 0.0
    label_bytes_total: float = 0.0
    wall_seconds: float = 0.0


class DecentralizedSimulator:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 data: ClassificationData, public_x: Optional[np.ndarray] = None,
                 kd_mode: Optional[str] = None, eval_every: int = 50,
                 eval_batches: int = 4):
        self.mcfg = model_cfg
        self.tcfg = train_cfg
        self.data = data
        self.public_x = public_x
        self.kd_mode = kd_mode
        self.eval_every = eval_every
        self.eval_batches = eval_batches

        n = train_cfg.num_nodes
        self.topology = Topology.make(train_cfg.topology, n)
        if train_cfg.algorithm == "centralized":
            # exact averaging reference: fully-connected uniform mixing
            W = np.full((n, n), 1.0 / n)
            self.mixer = make_dense_mixer(W)
        else:
            self.mixer = make_dense_mixer(self.topology.mixing_matrix())
        self.algo = make_algorithm(train_cfg.algorithm,
                                   topology=self.topology,
                                   momentum=train_cfg.momentum,
                                   weight_decay=train_cfg.weight_decay)
        self.model = build_model(model_cfg)

        rng = np.random.default_rng(train_cfg.seed)
        if train_cfg.algorithm == "centralized":
            # paper: centralized reference uses a random IID distribution
            idx = rng.permutation(len(data.train_y))
            self.parts = [np.asarray(p) for p in np.array_split(idx, n)]
        else:
            self.parts = dirichlet_partition(
                data.train_y, n, alpha=getattr(train_cfg, "alpha", 0.1),
                rng=rng)
        self.lr_fn = step_decay(train_cfg.lr, train_cfg.steps,
                                train_cfg.lr_decay_milestones,
                                train_cfg.lr_decay_factor)
        self._build_jits()

    # ------------------------------------------------------------------ setup
    def _build_jits(self):
        model, mixer, algo = self.model, self.mixer, self.algo
        C = self.mcfg.num_classes
        kd_T = (self.tcfg.idkd.temperature if self.tcfg.idkd
                else IDKDConfig().temperature)

        def node_loss(params, images, soft_labels, weights):
            logits, _ = model.forward(params, {"images": images})
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            nll = -jnp.sum(soft_labels * logp, axis=-1)
            return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)

        def kd_node_loss(params, images, soft_labels, weights, is_pub):
            """Private part: hard CE. Public part: T²-scaled KD loss
            (Hinton's T² factor keeps KD gradients comparable to the hard
            CE gradients when mixing the two)."""
            logits, _ = model.forward(params, {"images": images})
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            hard_nll = -jnp.sum(soft_labels * logp, axis=-1)
            kd = distill.kd_loss(logits, soft_labels, kd_T)
            nll = jnp.where(is_pub, kd, hard_nll)
            return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)

        def sparse_kd_node_loss(params, images, values, indices, weights,
                                is_pub):
            """kd_node_loss on top-k sparse labels, never densified: the
            private rows carry their one-hot as a k=1 sparse label, so
            hard CE is the T=1 sparse soft-CE on the same payload."""
            logits, _ = model.forward(params, {"images": images})
            sp = distill.SparseLabels(values, indices)
            hard_nll = distill.sparse_kd_loss(logits, sp, 1.0)
            kd = distill.sparse_kd_loss(logits, sp, kd_T)
            nll = jnp.where(is_pub, kd, hard_nll)
            return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)

        grad_fn = jax.vmap(jax.grad(node_loss), in_axes=(0, 0, 0, 0))
        kd_grad_fn = jax.vmap(jax.grad(kd_node_loss), in_axes=(0, 0, 0, 0, 0))
        sparse_kd_grad_fn = jax.vmap(jax.grad(sparse_kd_node_loss),
                                     in_axes=(0, 0, 0, 0, 0, 0))

        @jax.jit
        def train_step(params, opt_state, images, soft_labels, weights, lr):
            grads = grad_fn(params, images, soft_labels, weights)
            return algo.step(params, grads, opt_state, lr, mixer)

        @jax.jit
        def kd_train_step(params, opt_state, images, soft_labels, weights,
                          is_pub, lr):
            grads = kd_grad_fn(params, images, soft_labels, weights, is_pub)
            return algo.step(params, grads, opt_state, lr, mixer)

        @jax.jit
        def sparse_kd_train_step(params, opt_state, images, values, indices,
                                 weights, is_pub, lr):
            grads = sparse_kd_grad_fn(params, images, values, indices,
                                      weights, is_pub)
            return algo.step(params, grads, opt_state, lr, mixer)

        @jax.jit
        def forward_logits(params, images):
            """vmapped per-node forward: images (n, B, ...) -> (n, B, C)."""
            return jax.vmap(
                lambda p, x: model.forward(p, {"images": x})[0])(params, images)

        @jax.jit
        def consensus_eval(params, images, labels):
            mean_p = jax.tree.map(lambda t: jnp.mean(
                t.astype(jnp.float32), axis=0).astype(t.dtype), params)
            logits, _ = model.forward(mean_p, {"images": images})
            acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
            return acc, nll

        self._train_step = train_step
        self._kd_train_step = kd_train_step
        self._sparse_kd_train_step = sparse_kd_train_step
        self._forward_logits = forward_logits
        self._consensus_eval = consensus_eval

    def _stacked_init(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = self.model.init(key)   # identical init on all nodes (paper)
        n = self.tcfg.num_nodes
        return jax.tree.map(lambda t: jnp.broadcast_to(t[None],
                                                       (n,) + t.shape), params)

    # -------------------------------------------------------------- inference
    def _node_logits(self, params, x: np.ndarray, batch: int = 256):
        """All-node logits on a shared array x: returns (n, len(x), C)."""
        n = self.tcfg.num_nodes
        outs = []
        for i in range(0, len(x), batch):
            xb = jnp.asarray(x[i:i + batch])
            xb = jnp.broadcast_to(xb[None], (n,) + xb.shape)
            outs.append(np.asarray(self._forward_logits(params, xb)))
        return np.concatenate(outs, axis=1)

    def _per_node_val_logits(self, params, batch: int = 256):
        """Each node's logits on its own private samples (ID scores)."""
        # use each node's training samples as its ID set (paper: D_V^i)
        n = self.tcfg.num_nodes
        per_node = []
        m = min(min(len(p) for p in self.parts), batch)
        idx = np.stack([p[:m] for p in self.parts])
        xb = jnp.asarray(self.data.train_x[idx])      # (n, m, ...)
        return np.asarray(self._forward_logits(params, xb))

    # ------------------------------------------------------------------- run
    def run(self) -> SimResult:
        t0 = time.time()
        tcfg = self.tcfg
        n = tcfg.num_nodes
        params = self._stacked_init()
        opt_state = self.algo.init(params)
        sampler = NodeSampler(self.parts, tcfg.batch_size, tcfg.seed)
        result = SimResult(final_acc=0.0)
        result.pre_hist = partition_stats(self.data.train_y, self.parts,
                                          self.mcfg.num_classes)

        hom: Optional[labeling.HomogenizedResult] = None
        hom_sampler: Optional[HomogenizedSampler] = None
        idkd_cfg = tcfg.idkd or IDKDConfig()
        eye = np.eye(self.mcfg.num_classes, dtype=np.float32)

        for step in range(tcfg.steps):
            lr = self.lr_fn(step)
            if (self.kd_mode and self.public_x is not None
                    and step == idkd_cfg.start_step):
                hom = self._homogenize(params, idkd_cfg)
                sparse_round = isinstance(hom, labeling.SparseHomogenizedSet)
                payload = ((np.asarray(hom.labels.values),
                            np.asarray(hom.labels.indices))
                           if sparse_round else np.asarray(hom.labels))
                hom_sampler = HomogenizedSampler(
                    self.parts, np.asarray(hom.weights), tcfg.batch_size,
                    tcfg.seed, public_labels=payload)
                result.thresholds = np.asarray(hom.thresholds)
                result.id_fraction = float(np.mean(np.asarray(hom.id_masks)))
                result.post_hist = self._post_histograms(hom)
                # wire cost: sparse backends ship each node's own top-k
                # payload; the dense backend always ships full (P, C) rows
                k_wire = (min(idkd_cfg.label_topk or labeling.DEFAULT_TOPK,
                              self.mcfg.num_classes)
                          if sparse_round else 0)
                result.label_bytes_total = float(
                    n * distill.label_bytes(
                        int(np.asarray(hom.id_masks).sum() / n),
                        self.mcfg.num_classes, k_wire))

            if hom_sampler is None:
                idx = sampler.sample()                        # (n, B)
                images = jnp.asarray(self.data.train_x[idx])
                labels = jnp.asarray(eye[self.data.train_y[idx]])
                weights = jnp.ones(idx.shape, jnp.float32)
                params, opt_state = self._train_step(
                    params, opt_state, images, labels, weights, lr)
            else:
                priv, pub, is_pub = hom_sampler.sample()
                img_priv = self.data.train_x[priv]            # (n, B, ...)
                img_pub = self.public_x[pub]
                images = jnp.asarray(np.where(is_pub[..., None, None, None],
                                              img_pub, img_priv))
                w_pub = hom_sampler.gather_weights(pub)
                weights = jnp.asarray(np.where(is_pub, w_pub, 1.0)
                                      ).astype(jnp.float32)
                if hom_sampler.sparse:
                    # sparse payload end-to-end: private one-hots ride the
                    # same (values, indices) format at k=1
                    vals, cls = hom_sampler.gather_public(pub)  # (n, B, k)
                    pv = np.zeros_like(vals)
                    pv[..., 0] = 1.0
                    pi = np.zeros_like(cls)
                    pi[..., 0] = self.data.train_y[priv]
                    values = jnp.asarray(np.where(is_pub[..., None],
                                                  vals, pv))
                    indices = jnp.asarray(np.where(is_pub[..., None],
                                                   cls, pi))
                    params, opt_state = self._sparse_kd_train_step(
                        params, opt_state, images, values, indices, weights,
                        jnp.asarray(is_pub), lr)
                else:
                    lab_priv = eye[self.data.train_y[priv]]
                    lab_pub = hom_sampler.gather_public(pub)
                    labels = jnp.asarray(np.where(is_pub[..., None],
                                                  lab_pub, lab_priv))
                    params, opt_state = self._kd_train_step(
                        params, opt_state, images, labels, weights,
                        jnp.asarray(is_pub), lr)

            if step % self.eval_every == 0 or step == tcfg.steps - 1:
                acc, nll = self._eval(params)
                result.acc_history.append(acc)
                result.loss_history.append(nll)
                result.consensus_history.append(
                    float(consensus_distance(params)))

        result.final_acc = result.acc_history[-1]
        # ring: each node sends its params to deg neighbours every iteration
        deg = np.mean([self.topology.degree(i) for i in range(n)])
        nparams = sum(x.size for x in jax.tree.leaves(self.model.init(
            jax.random.PRNGKey(0))))
        result.comm_bytes_per_iter = float(deg * nparams * 4)
        result.wall_seconds = time.time() - t0
        return result

    # ------------------------------------------------------------ IDKD round
    def _homogenize(self, params, idkd_cfg: IDKDConfig
                    ) -> labeling.HomogenizedResult:
        pub_logits = jnp.asarray(self._node_logits(params, self.public_x))
        val_logits = jnp.asarray(self._per_node_val_logits(params))
        # cal_logits=None: D_C = the public set (paper's default);
        # kd_mode="vanilla" is the no-OoD-filter baseline (every public
        # sample kept) — the engine's filter_ood=False branch
        return labeling.label_round(
            pub_logits, val_logits, None, self.topology, idkd_cfg,
            backend=idkd_cfg.label_backend,
            filter_ood=self.kd_mode != "vanilla")

    def _post_histograms(self, hom: labeling.HomogenizedResult) -> np.ndarray:
        C = self.mcfg.num_classes
        sparse_round = isinstance(hom, labeling.SparseHomogenizedSet)
        hists = []
        for i in range(self.tcfg.num_nodes):
            soft = (distill.SparseLabels(hom.labels.values[i],
                                         hom.labels.indices[i])
                    if sparse_round else hom.labels[i])
            h = idkd.class_histogram(
                jnp.asarray(self.data.train_y[self.parts[i]]),
                soft, hom.weights[i], C)
            hists.append(np.asarray(h))
        return np.stack(hists)

    # ------------------------------------------------------------------ eval
    def _eval(self, params):
        accs, nlls = [], []
        B = 256
        for b in range(self.eval_batches):
            lo = (b * B) % len(self.data.test_y)
            xb = jnp.asarray(self.data.test_x[lo:lo + B])
            yb = jnp.asarray(self.data.test_y[lo:lo + B])
            a, l = self._consensus_eval(params, xb, yb)
            accs.append(float(a))
            nlls.append(float(l))
        return float(np.mean(accs)), float(np.mean(nlls))
