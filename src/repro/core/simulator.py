"""In-process decentralized training simulator (CPU accuracy experiments).

All N nodes live in one process: parameters are node-stacked pytrees
(leading axis = node), per-node gradients come from ``vmap``, gossip is the
dense mixing matrix — mathematically identical to the paper's MPI cluster
under synchronous rounds, which is what the paper runs.

The step loop is the unified driver (``core.driver``): loss adapters +
``make_step`` build the jitted steps, per-node sampling runs on device,
and the inner loop executes as ``lax.scan`` chunks between eval
boundaries (``driver_mode="auto"`` keeps conv models on the per-step
host runner on CPU — DESIGN.md §5 CPU caveats).

Supports the full method grid of Tables 2–7:
  * algorithms: dsgd / dsgdm / qg-dsgdm-n / d2 / relaysgd / centralized
  * ``kd_mode``: None (no distillation), "vanilla" (no OoD filter — the
    QG-DSGDm-N + KD baseline), "idkd" (MSP-filtered — the paper's method)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import IDKDConfig, ModelConfig, TrainConfig
from repro.core import distill, driver, idkd, labeling
from repro.core.algorithms import make_algorithm
from repro.core.mixing import consensus_distance, make_dense_mixer
from repro.core.topology import Topology
from repro.data.dirichlet import dirichlet_partition, partition_stats
from repro.data.synthetic import ClassificationData
from repro.models import build_model
from repro.optim.schedules import step_decay


@dataclass
class SimResult:
    final_acc: float
    acc_history: List[float] = field(default_factory=list)
    loss_history: List[float] = field(default_factory=list)
    consensus_history: List[float] = field(default_factory=list)
    pre_hist: Optional[np.ndarray] = None    # (n, C) class hists pre-IDKD
    post_hist: Optional[np.ndarray] = None   # (n, C) class hists post-IDKD
    thresholds: Optional[np.ndarray] = None
    id_fraction: float = 0.0                 # fraction of D_P kept as ID
    comm_bytes_per_iter: float = 0.0
    label_bytes_total: float = 0.0
    wall_seconds: float = 0.0


class DecentralizedSimulator:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 data: ClassificationData, public_x: Optional[np.ndarray] = None,
                 kd_mode: Optional[str] = None, eval_every: int = 50,
                 eval_batches: int = 4, driver_mode: str = "auto"):
        self.mcfg = model_cfg
        self.tcfg = train_cfg
        self.data = data
        self.public_x = public_x
        self.kd_mode = kd_mode
        self.eval_every = eval_every
        self.eval_batches = eval_batches
        self.driver_mode = driver.resolve_runner_mode(driver_mode,
                                                      model_cfg.arch_type)

        n = train_cfg.num_nodes
        self.topology = Topology.make(train_cfg.topology, n)
        if train_cfg.algorithm == "centralized":
            # exact averaging reference: fully-connected uniform mixing
            W = np.full((n, n), 1.0 / n)
            self.mixer = make_dense_mixer(W)
        else:
            self.mixer = make_dense_mixer(self.topology.mixing_matrix())
        self.algo = make_algorithm(train_cfg.algorithm,
                                   topology=self.topology,
                                   momentum=train_cfg.momentum,
                                   weight_decay=train_cfg.weight_decay)
        self.model = build_model(model_cfg)

        rng = np.random.default_rng(train_cfg.seed)
        if train_cfg.algorithm == "centralized":
            # paper: centralized reference uses a random IID distribution
            idx = rng.permutation(len(data.train_y))
            self.parts = [np.asarray(p) for p in np.array_split(idx, n)]
        else:
            self.parts = dirichlet_partition(
                data.train_y, n, alpha=getattr(train_cfg, "alpha", 0.1),
                rng=rng)
        self.lr_fn = step_decay(train_cfg.lr, train_cfg.steps,
                                train_cfg.lr_decay_milestones,
                                train_cfg.lr_decay_factor)
        self._build_jits()

    # ------------------------------------------------------------------ setup
    def _build_jits(self):
        """Steps come from the unified driver (core.driver.make_step);
        only the diagnostics (forward/eval) are built here."""
        model, mixer, algo = self.model, self.mixer, self.algo
        kd_T = (self.tcfg.idkd.temperature if self.tcfg.idkd
                else IDKDConfig().temperature)

        self._plain_step = driver.make_step(
            model, algo, mixer, driver.classification_adapter)
        self._kd_step = driver.make_step(
            model, algo, mixer, driver.dense_kd_adapter(kd_T))
        self._sparse_kd_step = driver.make_step(
            model, algo, mixer, driver.sparse_kd_adapter(kd_T))

        @jax.jit
        def forward_logits(params, images):
            """vmapped per-node forward: images (n, B, ...) -> (n, B, C)."""
            return jax.vmap(
                lambda p, x: model.forward(p, {"images": x})[0])(params, images)

        @jax.jit
        def consensus_eval(params, images, labels, mask):
            mean_p = jax.tree.map(lambda t: jnp.mean(
                t.astype(jnp.float32), axis=0).astype(t.dtype), params)
            logits, _ = model.forward(mean_p, {"images": images})
            hit = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
            cnt = jnp.maximum(jnp.sum(mask), 1.0)
            acc = jnp.sum(hit * mask) / cnt
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            per = -jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
            nll = jnp.sum(per * mask) / cnt
            return acc, nll

        self._forward_logits = forward_logits
        self._consensus_eval = consensus_eval

    def _stacked_init(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = self.model.init(key)   # identical init on all nodes (paper)
        n = self.tcfg.num_nodes
        return jax.tree.map(lambda t: jnp.broadcast_to(t[None],
                                                       (n,) + t.shape), params)

    # -------------------------------------------------------------- inference
    def _node_logits(self, params, x: np.ndarray, batch: int = 256):
        """All-node logits on a shared array x: returns (n, len(x), C)."""
        n = self.tcfg.num_nodes
        outs = []
        for i in range(0, len(x), batch):
            xb = jnp.asarray(x[i:i + batch])
            xb = jnp.broadcast_to(xb[None], (n,) + xb.shape)
            outs.append(np.asarray(self._forward_logits(params, xb)))
        return np.concatenate(outs, axis=1)

    def _per_node_val_logits(self, params, batch: int = 256):
        """Each node's logits on its own private samples (ID scores)."""
        # use each node's training samples as its ID set (paper: D_V^i)
        n = self.tcfg.num_nodes
        per_node = []
        m = min(min(len(p) for p in self.parts), batch)
        idx = np.stack([p[:m] for p in self.parts])
        xb = jnp.asarray(self.data.train_x[idx])      # (n, m, ...)
        return np.asarray(self._forward_logits(params, xb))

    # ------------------------------------------------------------------- run
    def run(self) -> SimResult:
        """Chunked scan driver: the inner step loop runs on device
        (``core.driver``), breaking only at eval boundaries and at the
        homogenization step (where the sampler/step pair is swapped)."""
        t0 = time.time()
        tcfg = self.tcfg
        n = tcfg.num_nodes
        C = self.mcfg.num_classes
        params = self._stacked_init()
        opt_state = self.algo.init(params)
        result = SimResult(final_acc=0.0)
        result.pre_hist = partition_stats(self.data.train_y, self.parts,
                                          self.mcfg.num_classes)

        idkd_cfg = tcfg.idkd or IDKDConfig()
        kd_active = (self.kd_mode is not None and self.public_x is not None
                     and idkd_cfg.start_step < tcfg.steps)
        priv_parts = driver.pad_partitions(self.parts)
        sampler = driver.make_classification_sampler(
            priv_parts, self.data.train_x, self.data.train_y, C,
            tcfg.batch_size)
        runner = driver.make_runner(self._plain_step, sampler, self.lr_fn,
                                    self.driver_mode)
        key = jax.random.PRNGKey(tcfg.seed)
        hom: Optional[labeling.HomogenizedResult] = None

        for a, b in driver.eval_boundaries(
                tcfg.steps, self.eval_every,
                idkd_cfg.start_step if kd_active else None):
            if kd_active and hom is None and a == idkd_cfg.start_step:
                hom = self._homogenize(params, idkd_cfg)
                sparse_round = isinstance(hom, labeling.SparseHomogenizedSet)
                payload = (hom.labels if sparse_round
                           else np.asarray(hom.labels))
                pub_parts = driver.pad_partitions(
                    [np.flatnonzero(w > 0)
                     for w in np.asarray(hom.weights)])
                sampler = driver.make_homogenized_sampler(
                    priv_parts, pub_parts, self.data.train_x,
                    self.data.train_y, self.public_x,
                    np.asarray(hom.weights), payload, C, tcfg.batch_size)
                step_fn = (self._sparse_kd_step if sparse_round
                           else self._kd_step)
                runner = driver.make_runner(step_fn, sampler, self.lr_fn,
                                            self.driver_mode)
                result.thresholds = np.asarray(hom.thresholds)
                result.id_fraction = float(np.mean(np.asarray(hom.id_masks)))
                result.post_hist = self._post_histograms(hom)
                # wire cost: sparse backends ship each node's own top-k
                # payload; the dense backend always ships full (P, C) rows
                k_wire = (min(idkd_cfg.label_topk or labeling.DEFAULT_TOPK,
                              self.mcfg.num_classes)
                          if sparse_round else 0)
                result.label_bytes_total = float(
                    n * distill.label_bytes(
                        int(np.asarray(hom.id_masks).sum() / n),
                        self.mcfg.num_classes, k_wire))

            params, opt_state, key, _ = runner(
                params, opt_state, key, jnp.asarray(a, jnp.int32), b - a)

            last = b - 1
            if last % self.eval_every == 0 or last == tcfg.steps - 1:
                acc, nll = self._eval(params)
                result.acc_history.append(acc)
                result.loss_history.append(nll)
                result.consensus_history.append(
                    float(consensus_distance(params)))

        result.final_acc = result.acc_history[-1]
        # ring: each node sends its params to deg neighbours every iteration
        deg = np.mean([self.topology.degree(i) for i in range(n)])
        nparams = sum(x.size for x in jax.tree.leaves(self.model.init(
            jax.random.PRNGKey(0))))
        result.comm_bytes_per_iter = float(deg * nparams * 4)
        result.wall_seconds = time.time() - t0
        return result

    # ------------------------------------------------------------ IDKD round
    def _homogenize(self, params, idkd_cfg: IDKDConfig
                    ) -> labeling.HomogenizedResult:
        pub_logits = jnp.asarray(self._node_logits(params, self.public_x))
        val_logits = jnp.asarray(self._per_node_val_logits(params))
        # cal_logits=None: D_C = the public set (paper's default);
        # kd_mode="vanilla" is the no-OoD-filter baseline (every public
        # sample kept) — the engine's filter_ood=False branch
        return labeling.label_round(
            pub_logits, val_logits, None, self.topology, idkd_cfg,
            backend=idkd_cfg.label_backend,
            filter_ood=self.kd_mode != "vanilla")

    def _post_histograms(self, hom: labeling.HomogenizedResult) -> np.ndarray:
        C = self.mcfg.num_classes
        sparse_round = isinstance(hom, labeling.SparseHomogenizedSet)
        hists = []
        for i in range(self.tcfg.num_nodes):
            soft = (distill.SparseLabels(hom.labels.values[i],
                                         hom.labels.indices[i])
                    if sparse_round else hom.labels[i])
            h = idkd.class_histogram(
                jnp.asarray(self.data.train_y[self.parts[i]]),
                soft, hom.weights[i], C)
            hists.append(np.asarray(h))
        return np.stack(hists)

    # ------------------------------------------------------------------ eval
    def _eval(self, params, batch: int = 256):
        """Deterministic test-set sweep: contiguous batches, each sample
        counted at most once (the seed's ``(b*B) % len`` wraparound could
        short-batch and double-count, adding noise to every accuracy
        number). The last batch is zero-padded with a mask so the jitted
        eval keeps one shape; means are weighted by true sample count."""
        N = len(self.data.test_y)
        num_batches = min(self.eval_batches, -(-N // batch))
        tot_acc = tot_nll = tot_cnt = 0.0
        for b in range(num_batches):
            lo = b * batch
            hi = min(lo + batch, N)
            cnt = hi - lo
            xb = self.data.test_x[lo:hi]
            yb = self.data.test_y[lo:hi]
            if cnt < batch:
                pad = batch - cnt
                xb = np.concatenate([xb, np.zeros((pad,) + xb.shape[1:],
                                                  xb.dtype)])
                yb = np.concatenate([yb, np.zeros((pad,), yb.dtype)])
            mask = np.zeros((batch,), np.float32)
            mask[:cnt] = 1.0
            a, l = self._consensus_eval(params, jnp.asarray(xb),
                                        jnp.asarray(yb), jnp.asarray(mask))
            tot_acc += float(a) * cnt
            tot_nll += float(l) * cnt
            tot_cnt += cnt
        return tot_acc / tot_cnt, tot_nll / tot_cnt
