"""IDKD orchestrator — the paper's Algorithm 1, per node.

Given a node's trained model, the public dataset D_P, the node's private
validation data D_V^i and a calibration set D_C, one homogenization round
(executed every k epochs after local convergence) is:

  (line 5)  soft labels  KD_P = softmax(f_i(D_P) / T)
  (line 6)  t_opt        ROC-calibrated MSP threshold (ood.calibrate_threshold)
  (line 7)  D_ID^i       {p : max s_p > t_opt}
  (l. 9-13) exchange     labels-only gossip with graph neighbours
  (line 14) average      per-sample mean of neighbour labels
  (line 15) D_Tr^i       D_T^i ∪ D_ID  (the homogenized train set)

The round itself lives in the unified labeling engine
(:mod:`repro.core.labeling`), which both the simulator and the production
launch drive; ``homogenization_round`` is the paper-named entry point for
the dense reference backend. This module keeps the paper's diagnostics
(Figure 3a histograms, the skew metric).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import IDKDConfig
from repro.core import distill, labeling
from repro.core.labeling import HomogenizedSet  # noqa: F401 (re-export)
from repro.core.topology import Topology


def homogenization_round(public_logits, val_logits, cal_logits,
                         topology: Topology, cfg: IDKDConfig
                         ) -> HomogenizedSet:
    """One IDKD round on node-stacked logits (dense reference backend).

    public_logits: (n, P, C) — each node's logits on the public set D_P
    val_logits:    (n, V, C) — each node's logits on its private D_V^i (ID)
    cal_logits:    (n, K, C) — each node's logits on D_C (OoD calibration)
    """
    from repro.obs import log
    log.debug("idkd.homogenization_round", n=public_logits.shape[0],
              public=public_logits.shape[1], topology=topology.name,
              detector=cfg.detector, temperature=cfg.temperature)
    return labeling.label_round(public_logits, val_logits, cal_logits,
                                topology, cfg, backend="dense")


def class_histogram(hard_labels, soft_labels=None, weights=None,
                    num_classes: int = 10):
    """Paper Figure 3a: normalized per-class sample counts pre/post IDKD.
    Soft labels contribute fractionally (the paper counts soft labels for
    every class with non-zero value). ``soft_labels`` may be a dense
    (P, C) array or a :class:`repro.core.distill.SparseLabels` payload —
    sparse counting is an O(P·k) scatter-add, never densified."""
    hist = jnp.bincount(hard_labels.astype(jnp.int32), length=num_classes
                        ).astype(jnp.float32)
    if soft_labels is not None:
        if isinstance(soft_labels, distill.SparseLabels):
            w = (weights if weights is not None
                 else jnp.ones(soft_labels.values.shape[0]))
            contrib = (soft_labels.values.astype(jnp.float32)
                       * w.astype(jnp.float32)[:, None])
            hist = hist + jnp.zeros(num_classes, jnp.float32).at[
                soft_labels.indices.reshape(-1)].add(contrib.reshape(-1))
        else:
            w = (weights if weights is not None
                 else jnp.ones(soft_labels.shape[0]))
            hist = hist + jnp.einsum("p,pc->c", w.astype(jnp.float32),
                                     soft_labels.astype(jnp.float32))
    return hist / jnp.maximum(jnp.sum(hist), 1.0)


def skew_metric(histograms) -> jax.Array:
    """Mean per-node TV distance from uniform (0 = perfectly IID)."""
    n, C = histograms.shape
    return jnp.mean(0.5 * jnp.sum(jnp.abs(histograms - 1.0 / C), axis=-1))
