"""IDKD orchestrator — the paper's Algorithm 1, per node.

Given a node's trained model, the public dataset D_P, the node's private
validation data D_V^i and a calibration set D_C, one homogenization round
(executed every k epochs after local convergence) is:

  (line 5)  soft labels  KD_P = softmax(f_i(D_P) / T)
  (line 6)  t_opt        ROC-calibrated MSP threshold (ood.calibrate_threshold)
  (line 7)  D_ID^i       {p : max s_p > t_opt}
  (l. 9-13) exchange     labels-only gossip with graph neighbours
  (line 14) average      per-sample mean of neighbour labels
  (line 15) D_Tr^i       D_T^i ∪ D_ID  (the homogenized train set)

``homogenization_round`` runs lines 5–14 for *all* nodes at once on
node-stacked predictions (simulation backend); the production backend does
the same per node with ppermute label exchange (repro.launch.train).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import IDKDConfig
from repro.core import distill, ood
from repro.core.topology import Topology


class HomogenizedSet(NamedTuple):
    """Per-node distilled public subset (node-stacked)."""
    labels: jax.Array        # (n, P, C) averaged soft labels
    weights: jax.Array       # (n, P) 1.0 where sample is in node's D_ID∪neigh
    id_masks: jax.Array      # (n, P) the node's own D_ID mask (diagnostics)
    thresholds: jax.Array    # (n,) calibrated t_opt per node


def _neighbor_union(topology: Topology, id_mask, labels):
    """Lines 9–14 for every node: union of own + neighbour ID sets with
    per-sample label averaging over contributing nodes."""
    n, P = id_mask.shape
    C = labels.shape[-1]
    # membership[i, j] = 1 if node j's labels reach node i (self + neighbours)
    member = np.eye(n, dtype=np.float32)
    for i in range(n):
        for j in topology.neighbors(i):
            member[i, j] = 1.0
    member = jnp.asarray(member)
    m = id_mask.astype(jnp.float32)                       # (n_src, P)
    contrib = member[:, :, None] * m[None, :, :]          # (dst, src, P)
    num = jnp.einsum("dsp,spc->dpc", contrib, labels.astype(jnp.float32))
    cnt = jnp.sum(contrib, axis=1)                        # (dst, P)
    avg = num / jnp.maximum(cnt, 1.0)[..., None]
    return avg, (cnt > 0).astype(jnp.float32)


def homogenization_round(public_logits, val_logits, cal_logits,
                         topology: Topology, cfg: IDKDConfig
                         ) -> HomogenizedSet:
    """One IDKD round on node-stacked logits.

    public_logits: (n, P, C) — each node's logits on the public set D_P
    val_logits:    (n, V, C) — each node's logits on its private D_V^i (ID)
    cal_logits:    (n, K, C) — each node's logits on D_C (OoD calibration)
    """
    # line 5: soft labels at distillation temperature
    labels = distill.soft_labels(public_logits, cfg.temperature)
    # line 6: per-node detector threshold (MSP by default; 'energy' is the
    # paper-cited alternative — IDKDConfig.detector)
    det = cfg.detector
    conf_pub = ood.confidence(public_logits, det)         # (n, P)
    conf_val = ood.confidence(val_logits, det)            # (n, V)
    conf_cal = ood.confidence(cal_logits, det)            # (n, K)
    thresholds = jax.vmap(ood.calibrate_threshold)(conf_val, conf_cal)
    # line 7: D_ID^i
    id_mask = conf_pub > thresholds[:, None]              # (n, P)
    # lines 9–14: neighbour exchange + label average
    avg_labels, weights = _neighbor_union(topology, id_mask, labels)
    return HomogenizedSet(avg_labels, weights, id_mask, thresholds)


def class_histogram(hard_labels, soft_labels=None, weights=None,
                    num_classes: int = 10):
    """Paper Figure 3a: normalized per-class sample counts pre/post IDKD.
    Soft labels contribute fractionally (the paper counts soft labels for
    every class with non-zero value)."""
    hist = jnp.bincount(hard_labels.astype(jnp.int32), length=num_classes
                        ).astype(jnp.float32)
    if soft_labels is not None:
        w = weights if weights is not None else jnp.ones(soft_labels.shape[0])
        hist = hist + jnp.einsum("p,pc->c", w.astype(jnp.float32),
                                 soft_labels.astype(jnp.float32))
    return hist / jnp.maximum(jnp.sum(hist), 1.0)


def skew_metric(histograms) -> jax.Array:
    """Mean per-node TV distance from uniform (0 = perfectly IID)."""
    n, C = histograms.shape
    return jnp.mean(0.5 * jnp.sum(jnp.abs(histograms - 1.0 / C), axis=-1))
