"""Gossip mixing backends behind one entry point.

:func:`make_mixer` builds x_i ← Σ_j W_ij x_j over a pytree of parameters
for any :class:`~repro.core.topology.Topology`, with interchangeable
backends:

* ``dense`` — simulation reference. Node-stacked pytrees (leading axis =
  node) mixed by the dense (n, n) Metropolis matrix via ``einsum``. Used
  by the CPU accuracy experiments (paper repro) where all nodes live in
  one process via ``vmap``. O(n²) work per leaf regardless of graph
  sparsity — the numerical oracle the other backends are tested against.

* ``gather`` — neighbour-gather on node-stacked arrays. Each node gathers
  its padded neighbour slots (``Topology.neighbor_arrays``) and combines
  with the gathered Metropolis weights — O(Σ deg) work, and the form that
  shards: a gather over a static index array lowers to neighbour-local
  collectives when the node axis is sharded.

* ``roll`` — ring-only fast path. ``jnp.roll`` along the node axis, which
  XLA lowers to ``collective-permute`` between neighbouring node groups
  when that axis is sharded over the mesh (the launch path's production
  gossip; no cross-node all-reduce appears in the HLO).

* ``ppermute`` — explicit production backend. Inside ``shard_map`` over
  the mesh node axes, each node `lax.ppermute`s its parameter shard to
  its graph neighbours and combines with its Metropolis row. Communication
  is therefore exactly the paper's peer-to-peer exchange (no all-reduce),
  visible in the compiled HLO as `collective-permute` ops. With
  ``local_nodes > 1`` each mesh index holds a contiguous *block* of the
  global node axis and only the boundary rows cross devices (the sharded
  driver's layout when nodes outnumber devices); a complete-graph
  topology routes to :func:`make_psum_mixer` instead (exact averaging —
  the full graph's Metropolis matrix is uniform 1/n).

All node-stacked backends take ``wire_dtype``: "native" moves parameters
between nodes in their storage dtype (bf16 params → bf16 gossip traffic,
§Perf byte-halving) and accumulates the weighted sum in f32; "float32"
upcasts before the exchange (paper-faithful full-precision mixing).

**Per-leaf mixer protocol.** Every mixer is leafwise: ``mix(tree)`` is
``jax.tree.map(mix.mix_leaf, tree)``, and the factories expose the
per-leaf function as ``mix.mix_leaf``. Optimizers use it to fuse the
gossip mix into an adjacent whole-tree pass (QG-DSGDm-N folds mix +
displacement-EMA + momentum half-step into a single traversal — one
tree walk fewer per step on every backend, bitwise-equal to
mix-then-update because the per-leaf op sequence is unchanged). The
shard_map backends additionally expose ``mix.axis_name`` (the mesh
axis/axes the node dimension lives on) so algorithms can turn their
cross-node scalar reductions into ``psum``s — QG-DSGDm-N's grad-norm
scale sums over the whole node-stacked tree, which under shard_map
means local-block sum + psum (keeps sharded trajectories equal to the
node-stacked ones).
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology

PyTree = object
Mixer = Callable[[PyTree], PyTree]


# ---------------------------------------------------------------------------
# simulation backend (node-stacked arrays)
# ---------------------------------------------------------------------------


def make_dense_mixer(W: np.ndarray, wire_dtype: str = "float32") -> Mixer:
    Wj = jnp.asarray(W, jnp.float32)

    def mix_leaf(x):
        # the einsum accumulates in f32 either way; "native" keeps the
        # operand in storage dtype (the bytes a real wire would carry)
        xf = x.astype(jnp.float32) if wire_dtype == "float32" else x
        y = jnp.einsum("ij,j...->i...", Wj, xf,
                       preferred_element_type=jnp.float32)
        return y.astype(x.dtype)

    def mix(stacked: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, stacked)

    mix.mix_leaf = mix_leaf
    return mix


def make_gather_mixer(topology: Topology, wire_dtype: str = "native",
                      active=None) -> Mixer:
    """Neighbour-gather gossip on node-stacked pytrees.

    Row i combines x[nbr[i, d]] with the gathered Metropolis weights
    W[i, nbr[i, d]]; padding slots carry weight 0. Exactly equals the
    dense-W einsum (W is supported on self ∪ neighbours) at O(Σ deg)
    work instead of O(n²). With an ``active`` mask the gathered weights
    come from the masked Metropolis matrix (down nodes keep identity
    rows, active ones renormalize over surviving neighbours) — same
    gather structure, so churn costs no recompile of the index plumbing.
    """
    nbr, valid = topology.neighbor_arrays(include_self=True)
    W = topology.mixing_matrix(active)
    w = W[np.arange(topology.n)[:, None], nbr] * valid      # (n, D)
    nbr_j = jnp.asarray(nbr)
    w_j = jnp.asarray(w, jnp.float32)

    def mix_leaf(x):
        xw = x.astype(jnp.float32) if wire_dtype == "float32" else x
        g = xw[nbr_j]                                       # (n, D, ...)
        y = jnp.einsum("nd,nd...->n...", w_j, g.astype(jnp.float32))
        return y.astype(x.dtype)

    def mix(stacked: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, stacked)

    mix.mix_leaf = mix_leaf
    return mix


def _is_ring(topology: Topology) -> bool:
    n = topology.n
    if n <= 2:
        return True
    return all(topology.neighbors(i) == sorted({(i - 1) % n, (i + 1) % n})
               for i in range(n))


def _is_full(topology: Topology) -> bool:
    n = topology.n
    return all(len(topology.neighbors(i)) == n - 1 for i in range(n))


def shard_supported_topology(topology: Topology) -> bool:
    """Graphs the shard_map gossip backends implement: rings (ppermute)
    and complete graphs (psum exact averaging). Everything else must run
    node-stacked (``gather``/``dense`` backends)."""
    return _is_ring(topology) or _is_full(topology)


def make_roll_mixer(num_nodes: int, wire_dtype: str = "native") -> Mixer:
    """Ring gossip via rolls along the node axis (→ collective-permute).

    Metropolis weights for a ring: 1/3 self + 1/3 each neighbour
    (n == 2 degenerates to 1/2, 1/2; n == 1 to identity).
    """
    if num_nodes <= 1:
        identity = lambda t: t                              # noqa: E731
        identity.mix_leaf = lambda x: x
        return identity

    def mix_leaf(x):
        xw = x.astype(jnp.float32) if wire_dtype == "float32" else x
        fwd = jnp.roll(xw, 1, axis=0).astype(jnp.float32)
        if num_nodes == 2:
            y = 0.5 * x.astype(jnp.float32) + 0.5 * fwd
        else:
            bwd = jnp.roll(xw, -1, axis=0).astype(jnp.float32)
            y = (x.astype(jnp.float32) + fwd + bwd) / 3.0
        return y.astype(x.dtype)

    def mix(tree):
        return jax.tree.map(mix_leaf, tree)

    mix.mix_leaf = mix_leaf
    return mix


def make_mixer(topology: Topology, backend: str = "auto",
               wire_dtype: str = "native", active=None,
               **ppermute_kw) -> Mixer:
    """One entry point for every gossip backend (see module docstring).

    ``backend="auto"`` picks the roll fast path on rings (lowers to
    collective-permute when the node axis is sharded) and neighbour-gather
    everywhere else. ``backend="roll"`` requires a ring topology;
    ``backend="ppermute"`` forwards ``axis_names`` / ``axis_sizes`` /
    ``self_weight`` / ``local_nodes`` to :func:`make_ppermute_mixer` (for
    use inside ``shard_map``) — that backend implements ring /
    ring-of-rings gossip over the mesh axes (a complete graph routes to
    the exact-averaging :func:`make_psum_mixer` instead), so any other
    topology is rejected *eagerly at build time*, and it always moves
    shards in their storage dtype (``wire_dtype`` other than "native" is
    rejected rather than silently dropped). Every ppermute-branch error
    names the node-stacked backend to fall back to, so shard-mode
    callers fail at construction with a fix, not mid-schedule.

    ``active`` is the churn path: an (n,) availability mask that switches
    the mixing weights to the masked Metropolis matrix
    (``Topology.mixing_matrix(active)`` — doubly stochastic on the active
    subgraph, identity on down nodes). A ring with a hole is no longer a
    ring, so ``auto`` routes masked rings to the gather backend and the
    roll/ppermute fast paths reject masks. The node-stacked backends
    (dense / gather / roll / auto) return a mixer carrying a
    ``remake(active=...)`` handle that rebuilds the same
    backend/wire-dtype mixer for a new availability mask — the scheduler
    path as nodes leave and rejoin. The ppermute backend has no masked
    path and no remake handle (shard_map gossip under churn is an open
    item).
    """
    requested = backend
    masked = active is not None and not np.all(np.asarray(active, bool))
    if not masked:
        active = None
    if backend == "auto":
        backend = "roll" if _is_ring(topology) and not masked else "gather"
    mix: Mixer
    if backend == "dense":
        mix = make_dense_mixer(topology.mixing_matrix(active), wire_dtype)
    elif backend == "gather":
        mix = make_gather_mixer(topology, wire_dtype, active)
    elif backend == "roll":
        if masked:
            raise ValueError("roll mixer cannot mask churned nodes (a ring "
                             "with a hole is not a ring); use backend="
                             "'gather' or 'auto' for time-varying masks")
        if not _is_ring(topology):
            raise ValueError(
                f"roll mixer requires a ring topology, got {topology.name!r}")
        mix = make_roll_mixer(topology.n, wire_dtype)
    elif backend == "ppermute":
        if masked:
            raise ValueError(
                "ppermute mixer has no masked path (churn under shard_map "
                "is unsupported — DESIGN.md §7); run churn schedules "
                "node-stacked with backend='gather' (or 'dense')")
        if _is_full(topology) and not _is_ring(topology):
            if wire_dtype != "native":
                raise ValueError(
                    "psum mixer moves shards in their storage dtype; "
                    f"wire_dtype={wire_dtype!r} unsupported — use "
                    "backend='gather' for an f32 wire")
            kw = dict(ppermute_kw)
            axis_names = kw.pop("axis_names")
            kw.pop("axis_sizes", None)
            kw.pop("self_weight", None)
            kw.pop("local_nodes", None)
            if kw:
                raise ValueError(f"unknown psum mixer options {sorted(kw)}")
            return make_psum_mixer(axis_names[0], topology.n)
        if not _is_ring(topology):
            raise ValueError(
                "ppermute mixer implements ring/ring-of-rings gossip over "
                f"mesh axes (plus psum on complete graphs); topology "
                f"{topology.name!r} must run node-stacked — use "
                "backend='gather' (or 'dense')")
        if wire_dtype != "native":
            raise ValueError(
                "ppermute mixer moves shards in their storage dtype; "
                f"wire_dtype={wire_dtype!r} unsupported — use "
                "backend='gather' for an f32 wire")
        return make_ppermute_mixer(**ppermute_kw)
    else:
        raise ValueError(f"unknown mixer backend {backend!r}; expected one "
                         "of ('auto', 'dense', 'gather', 'roll', 'ppermute')")
    mix.remake = lambda active=None: make_mixer(topology, requested,
                                                wire_dtype, active=active)
    return mix


# ---------------------------------------------------------------------------
# production backend (ppermute over mesh axes)
# ---------------------------------------------------------------------------


def _ring_perms(n: int) -> Tuple[list, list]:
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def block_ring_shift(x, axis_name: str, axis_size: int, shift: int):
    """Global ring roll of a block-sharded node axis (inside shard_map).

    ``x`` is one device's contiguous block (rows ``j·L .. j·L+L-1`` of the
    global node axis, ``L = x.shape[0]``, device ``j`` along
    ``axis_name``). Returns the local block of ``jnp.roll(global_x,
    shift, axis=0)`` for ``shift = ±1``: only the single boundary row
    crosses devices (``lax.ppermute``); the rest is a local shift. With
    ``axis_size == 1`` this degenerates to ``jnp.roll``.
    """
    if shift not in (1, -1):
        raise ValueError(f"block_ring_shift supports shift ±1, got {shift}")
    if axis_size == 1:
        return jnp.roll(x, shift, axis=0)
    if shift == 1:      # row i receives row i-1
        recv = jax.lax.ppermute(
            x[-1:], axis_name,
            [(j, (j + 1) % axis_size) for j in range(axis_size)])
        return jnp.concatenate([recv, x[:-1]], axis=0)
    recv = jax.lax.ppermute(
        x[:1], axis_name,
        [(j, (j - 1) % axis_size) for j in range(axis_size)])
    return jnp.concatenate([x[1:], recv], axis=0)


def make_ppermute_mixer(axis_names: Sequence[str], axis_sizes: Sequence[int],
                        self_weight: float | None = None,
                        local_nodes: int = 1) -> Mixer:
    """Ring gossip over the named mesh axes (to be called inside shard_map).

    With one axis: plain Metropolis ring over the global node axis of
    ``local_nodes · axis_size`` nodes — each mesh index holds a
    contiguous block of ``local_nodes`` rows and only the boundary rows
    cross devices (:func:`block_ring_shift`); ``local_nodes == 1`` is the
    one-node-per-device layout where the whole shard moves. Weights
    follow :func:`make_roll_mixer` exactly (1/3 each for n ≥ 3, 1/2 each
    for n == 2, identity for n == 1), so the sharded mix equals the
    node-stacked roll/dense ring mix to float tolerance.

    With two axes (pod, data): hierarchical ring-of-rings — every node
    mixes with its intra-pod ring neighbours, and nodes additionally mix
    with the same-index node of the neighbouring pod (a torus-like wrap
    over the pod axis), keeping W doubly stochastic. ``self_weight`` and
    ``local_nodes > 1`` apply to the single-axis form only.
    """
    names = list(axis_names)
    if local_nodes < 1:
        raise ValueError(f"local_nodes must be >= 1, got {local_nodes}")
    if len(names) == 1:
        ax, size = names[0], int(axis_sizes[0])
        n = local_nodes * size
        if self_weight is not None:
            raise ValueError("self_weight applies to the hierarchical "
                             "multi-axis mixer only")
        if n <= 1:
            identity = lambda t: t                          # noqa: E731
            identity.mix_leaf = lambda x: x
            identity.axis_name = ax
            return identity

        def mix_leaf(x):
            fwd = block_ring_shift(x, ax, size, 1).astype(jnp.float32)
            if n == 2:
                y = 0.5 * x.astype(jnp.float32) + 0.5 * fwd
            else:
                bwd = block_ring_shift(x, ax, size, -1).astype(jnp.float32)
                y = (x.astype(jnp.float32) + fwd + bwd) / 3.0
            return y.astype(x.dtype)

        def mix(local: PyTree) -> PyTree:
            return jax.tree.map(mix_leaf, local)

        mix.mix_leaf = mix_leaf
        mix.axis_name = ax
        return mix

    if local_nodes != 1:
        raise ValueError("local_nodes > 1 is single-axis only; the "
                         "hierarchical mixer holds one node per mesh index")

    def mix_leaf(x):
        parts = [x]
        for ax, n in zip(names, axis_sizes):
            if n < 2:
                continue
            fwd, bwd = _ring_perms(n)
            parts.append(jax.lax.ppermute(x, ax, fwd))
            if n > 2:
                # at n == 2 fwd and bwd are the same permutation — one
                # part, not a double-weighted duplicate of the neighbour
                parts.append(jax.lax.ppermute(x, ax, bwd))
        if len(parts) == 1:
            return x
        neigh_w = 1.0 / len(parts)
        w_self = self_weight if self_weight is not None else neigh_w
        acc = parts[0].astype(jnp.float32) * w_self
        for p in parts[1:]:
            acc = acc + p.astype(jnp.float32) * neigh_w
        # keep row-sum 1 when self_weight overrides
        total = w_self + neigh_w * (len(parts) - 1)
        return (acc / total).astype(x.dtype)

    def mix(local: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, local)

    mix.mix_leaf = mix_leaf
    mix.axis_name = tuple(names)
    return mix


def make_psum_mixer(axis_name: str, num_nodes: int) -> Mixer:
    """Exact-averaging gossip for the complete graph (inside shard_map).

    The complete graph's Metropolis matrix is uniform 1/n, so the mix is
    one ``psum`` over the node axis — the centralized reference's exact
    averaging, expressed as a collective instead of an n×n einsum.
    Blocks of any ``local_nodes`` work: the local rows are summed before
    the cross-device reduction.
    """
    def mix_leaf(x):
        xf = x.astype(jnp.float32)
        total = jax.lax.psum(jnp.sum(xf, axis=0, keepdims=True), axis_name)
        return jnp.broadcast_to(total / num_nodes, xf.shape).astype(x.dtype)

    def mix(local: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, local)

    mix.mix_leaf = mix_leaf
    mix.axis_name = axis_name
    return mix


def consensus_distance(stacked: PyTree) -> jax.Array:
    """Mean L2 distance of node params from the node-average (diagnostic)."""
    def per_leaf(x):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=0, keepdims=True)
        return jnp.sum((xf - mean) ** 2)
    total = sum(jax.tree.leaves(jax.tree.map(per_leaf, stacked)))
    return jnp.sqrt(total)
