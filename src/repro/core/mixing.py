"""Gossip mixing backends.

Two implementations of x_i ← Σ_j W_ij x_j over a pytree of parameters:

* :func:`make_dense_mixer` — simulation backend. Node-stacked pytrees
  (leading axis = node) mixed by a dense (n, n) matrix ``einsum``. Used by
  the CPU accuracy experiments (paper repro) where all nodes live in one
  process via ``vmap``.

* :func:`make_ppermute_mixer` — production backend. Inside ``shard_map``
  over the mesh node axes, each node `lax.ppermute`s its parameter shard to
  its graph neighbours and combines with its Metropolis row. Communication
  is therefore exactly the paper's peer-to-peer exchange (no all-reduce),
  visible in the compiled HLO as `collective-permute` ops.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology

PyTree = object
Mixer = Callable[[PyTree], PyTree]


# ---------------------------------------------------------------------------
# simulation backend (node-stacked arrays)
# ---------------------------------------------------------------------------


def make_dense_mixer(W: np.ndarray) -> Mixer:
    Wj = jnp.asarray(W, jnp.float32)

    def mix(stacked: PyTree) -> PyTree:
        def mix_leaf(x):
            xf = x.astype(jnp.float32)
            y = jnp.einsum("ij,j...->i...", Wj, xf)
            return y.astype(x.dtype)
        return jax.tree.map(mix_leaf, stacked)

    return mix


# ---------------------------------------------------------------------------
# production backend (ppermute over mesh axes)
# ---------------------------------------------------------------------------


def _ring_perms(n: int) -> Tuple[list, list]:
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def make_ppermute_mixer(axis_names: Sequence[str], axis_sizes: Sequence[int],
                        self_weight: float | None = None) -> Mixer:
    """Ring gossip over the named mesh axes (to be called inside shard_map).

    With one axis: plain ring over that axis. With two axes (pod, data):
    hierarchical ring-of-rings — every node mixes with its intra-pod ring
    neighbours, and nodes additionally mix with the same-index node of the
    neighbouring pod (a torus-like wrap over the pod axis), keeping W
    doubly stochastic.

    Metropolis weights for a degree-2 ring are 1/3 each; hierarchical
    adds the pod links with their own 1/3·(pods>1) share.
    """
    names = list(axis_names)

    def mix(local: PyTree) -> PyTree:
        parts = [local]
        weights = []
        for ax, n in zip(names, axis_sizes):
            if n < 2:
                continue
            fwd, bwd = _ring_perms(n)
            parts.append(jax.tree.map(
                lambda x: jax.lax.ppermute(x, ax, fwd), local))
            parts.append(jax.tree.map(
                lambda x: jax.lax.ppermute(x, ax, bwd), local))
            weights += [1.0, 1.0]
        if len(parts) == 1:
            return local
        neigh_w = 1.0 / (len(weights) + 1.0)
        w_self = self_weight if self_weight is not None else neigh_w

        def combine(*xs):
            acc = xs[0].astype(jnp.float32) * w_self
            for x in xs[1:]:
                acc = acc + x.astype(jnp.float32) * neigh_w
            # keep row-sum 1 when self_weight overrides
            total = w_self + neigh_w * (len(xs) - 1)
            return (acc / total).astype(xs[0].dtype)

        return jax.tree.map(combine, *parts)

    return mix


def consensus_distance(stacked: PyTree) -> jax.Array:
    """Mean L2 distance of node params from the node-average (diagnostic)."""
    def per_leaf(x):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=0, keepdims=True)
        return jnp.sum((xf - mean) ** 2)
    total = sum(jax.tree.leaves(jax.tree.map(per_leaf, stacked)))
    return jnp.sqrt(total)
