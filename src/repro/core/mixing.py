"""Gossip mixing backends behind one entry point.

:func:`make_mixer` builds x_i ← Σ_j W_ij x_j over a pytree of parameters
for any :class:`~repro.core.topology.Topology`, with interchangeable
backends:

* ``dense`` — simulation reference. Node-stacked pytrees (leading axis =
  node) mixed by the dense (n, n) Metropolis matrix via ``einsum``. Used
  by the CPU accuracy experiments (paper repro) where all nodes live in
  one process via ``vmap``. O(n²) work per leaf regardless of graph
  sparsity — the numerical oracle the other backends are tested against.

* ``gather`` — neighbour-gather on node-stacked arrays. Each node gathers
  its padded neighbour slots (``Topology.neighbor_arrays``) and combines
  with the gathered Metropolis weights — O(Σ deg) work, and the form that
  shards: a gather over a static index array lowers to neighbour-local
  collectives when the node axis is sharded.

* ``roll`` — ring-only fast path. ``jnp.roll`` along the node axis, which
  XLA lowers to ``collective-permute`` between neighbouring node groups
  when that axis is sharded over the mesh (the launch path's production
  gossip; no cross-node all-reduce appears in the HLO).

* ``ppermute`` — explicit production backend. Inside ``shard_map`` over
  the mesh node axes, each node `lax.ppermute`s its parameter shard to
  its graph neighbours and combines with its Metropolis row. Communication
  is therefore exactly the paper's peer-to-peer exchange (no all-reduce),
  visible in the compiled HLO as `collective-permute` ops. With
  ``local_nodes > 1`` each mesh index holds a contiguous *block* of the
  global node axis and only the boundary rows cross devices (the sharded
  driver's layout when nodes outnumber devices); a complete-graph
  topology routes to :func:`make_psum_mixer` instead (exact averaging —
  the full graph's Metropolis matrix is uniform 1/n).

All node-stacked backends take ``wire_dtype``: "native" moves parameters
between nodes in their storage dtype (bf16 params → bf16 gossip traffic,
§Perf byte-halving) and accumulates the weighted sum in f32; "float32"
upcasts before the exchange (paper-faithful full-precision mixing).
**Every backend defaults to "native"** — the wire carries what the nodes
store unless a caller explicitly asks for the full-precision wire. (The
dense backend historically defaulted to "float32" while gather/roll
defaulted to "native"; the defaults are unified, and callers that want
paper-faithful f32 mixing — e.g. the CPU simulator — pass
``wire_dtype="float32"`` explicitly.)

**Compressed / stateful gossip** (DESIGN.md §9). ``make_mixer`` also
takes ``compression`` (top-k / random-k sparsified wires with per-node
error-feedback residuals), ``gossip`` ("sync" | "delayed" — the mixer
consumes the *previous* step's payload so the exchange overlaps the next
step's compute), and ``stale`` (an (n,) straggler mask: stale nodes keep
training and receiving but their *outgoing* payload is frozen at the
last one they produced). Any of these makes the mixer *stateful*: it
carries a comm pytree (error residuals + last payload) across steps.
Stateful mixers are not called directly — ``mix.init_state(params)``
builds the comm pytree and ``mix.bind(comm)`` returns a single-use bound
mixer with the ordinary ``mix(tree)`` / ``mix.mix_leaf`` protocol whose
``finalize()`` yields the updated comm state (``core.driver.make_step``
threads it through the scan carry like the sampler ctx).

**Per-leaf mixer protocol.** Every mixer is leafwise: ``mix(tree)`` is
``jax.tree.map(mix.mix_leaf, tree)``, and the factories expose the
per-leaf function as ``mix.mix_leaf``. Optimizers use it to fuse the
gossip mix into an adjacent whole-tree pass (QG-DSGDm-N folds mix +
displacement-EMA + momentum half-step into a single traversal — one
tree walk fewer per step on every backend, bitwise-equal to
mix-then-update because the per-leaf op sequence is unchanged). The
shard_map backends additionally expose ``mix.axis_name`` (the mesh
axis/axes the node dimension lives on) so algorithms can turn their
cross-node scalar reductions into ``psum``s — QG-DSGDm-N's grad-norm
scale sums over the whole node-stacked tree, which under shard_map
means local-block sum + psum (keeps sharded trajectories equal to the
node-stacked ones).
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology

PyTree = object
Mixer = Callable[[PyTree], PyTree]


# ---------------------------------------------------------------------------
# simulation backend (node-stacked arrays)
# ---------------------------------------------------------------------------


def make_dense_mixer(W: np.ndarray, wire_dtype: str = "native") -> Mixer:
    Wj = jnp.asarray(W, jnp.float32)

    def mix_leaf(x):
        # the einsum accumulates in f32 either way; "native" keeps the
        # operand in storage dtype (the bytes a real wire would carry)
        xf = x.astype(jnp.float32) if wire_dtype == "float32" else x
        y = jnp.einsum("ij,j...->i...", Wj, xf,
                       preferred_element_type=jnp.float32)
        return y.astype(x.dtype)

    def mix(stacked: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, stacked)

    mix.mix_leaf = mix_leaf
    return mix


def make_gather_mixer(topology: Topology, wire_dtype: str = "native",
                      active=None) -> Mixer:
    """Neighbour-gather gossip on node-stacked pytrees.

    Row i combines x[nbr[i, d]] with the gathered Metropolis weights
    W[i, nbr[i, d]]; padding slots carry weight 0. Exactly equals the
    dense-W einsum (W is supported on self ∪ neighbours) at O(Σ deg)
    work instead of O(n²). With an ``active`` mask the gathered weights
    come from the masked Metropolis matrix (down nodes keep identity
    rows, active ones renormalize over surviving neighbours) — same
    gather structure, so churn costs no recompile of the index plumbing.
    """
    nbr, valid = topology.neighbor_arrays(include_self=True)
    W = topology.mixing_matrix(active)
    w = W[np.arange(topology.n)[:, None], nbr] * valid      # (n, D)
    nbr_j = jnp.asarray(nbr)
    w_j = jnp.asarray(w, jnp.float32)

    def mix_leaf(x):
        xw = x.astype(jnp.float32) if wire_dtype == "float32" else x
        g = xw[nbr_j]                                       # (n, D, ...)
        y = jnp.einsum("nd,nd...->n...", w_j, g.astype(jnp.float32))
        return y.astype(x.dtype)

    def mix(stacked: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, stacked)

    mix.mix_leaf = mix_leaf
    return mix


def _is_ring(topology: Topology) -> bool:
    n = topology.n
    if n <= 2:
        return True
    return all(topology.neighbors(i) == sorted({(i - 1) % n, (i + 1) % n})
               for i in range(n))


def _is_full(topology: Topology) -> bool:
    n = topology.n
    return all(len(topology.neighbors(i)) == n - 1 for i in range(n))


def shard_supported_topology(topology: Topology) -> bool:
    """Graphs the shard_map gossip backends implement: rings (ppermute)
    and complete graphs (psum exact averaging). Everything else must run
    node-stacked (``gather``/``dense`` backends)."""
    return _is_ring(topology) or _is_full(topology)


def make_roll_mixer(num_nodes: int, wire_dtype: str = "native") -> Mixer:
    """Ring gossip via rolls along the node axis (→ collective-permute).

    Metropolis weights for a ring: 1/3 self + 1/3 each neighbour
    (n == 2 degenerates to 1/2, 1/2; n == 1 to identity).
    """
    if num_nodes <= 1:
        identity = lambda t: t                              # noqa: E731
        identity.mix_leaf = lambda x: x
        return identity

    def mix_leaf(x):
        xw = x.astype(jnp.float32) if wire_dtype == "float32" else x
        fwd = jnp.roll(xw, 1, axis=0).astype(jnp.float32)
        if num_nodes == 2:
            y = 0.5 * x.astype(jnp.float32) + 0.5 * fwd
        else:
            bwd = jnp.roll(xw, -1, axis=0).astype(jnp.float32)
            y = (x.astype(jnp.float32) + fwd + bwd) / 3.0
        return y.astype(x.dtype)

    def mix(tree):
        return jax.tree.map(mix_leaf, tree)

    mix.mix_leaf = mix_leaf
    return mix


def make_mixer(topology: Topology, backend: str = "auto",
               wire_dtype: str = "native", active=None,
               compression=None, gossip: str = "sync", stale=None,
               stateful: bool = None, consensus_lr: float = 1.0,
               wire_fault=None, wire_guard=None,
               **ppermute_kw) -> Mixer:
    """One entry point for every gossip backend (see module docstring).

    ``backend="auto"`` picks the roll fast path on rings (lowers to
    collective-permute when the node axis is sharded) and neighbour-gather
    everywhere else. ``backend="roll"`` requires a ring topology;
    ``backend="ppermute"`` forwards ``axis_names`` / ``axis_sizes`` /
    ``self_weight`` / ``local_nodes`` to :func:`make_ppermute_mixer` (for
    use inside ``shard_map``) — that backend implements ring /
    ring-of-rings gossip over the mesh axes (a complete graph routes to
    the exact-averaging :func:`make_psum_mixer` instead), so any other
    topology is rejected *eagerly at build time*, and it always moves
    shards in their storage dtype (``wire_dtype`` other than "native" is
    rejected rather than silently dropped). Every ppermute-branch error
    names the node-stacked backend to fall back to, so shard-mode
    callers fail at construction with a fix, not mid-schedule.

    ``active`` is the churn path: an (n,) availability mask that switches
    the mixing weights to the masked Metropolis matrix
    (``Topology.mixing_matrix(active)`` — doubly stochastic on the active
    subgraph, identity on down nodes). A ring with a hole is no longer a
    ring, so ``auto`` routes masked rings to the gather backend and the
    roll/ppermute fast paths reject masks. The node-stacked backends
    (dense / gather / roll / auto) return a mixer carrying a
    ``remake(active=..., stale=...)`` handle that rebuilds the same
    backend/wire-dtype/compression mixer for a new availability /
    straggler mask — the scheduler path as nodes leave and rejoin. The
    ppermute backend has no masked path and no remake handle (shard_map
    gossip under churn is an open item).

    ``compression`` / ``gossip`` / ``stale`` select the stateful
    compressed-wire path (module docstring, DESIGN.md §9); any non-default
    value returns a stateful mixer (``mix.stateful``, ``mix.init_state``,
    ``mix.bind``) instead of a directly callable one. ``stateful=True``
    forces the stateful protocol even for plain sync uncompressed gossip —
    the scheduler uses it so the comm pytree's structure stays constant
    across a schedule whose *later* segments mark nodes stale.

    ``wire_fault`` (a :class:`repro.resil.WireFault`) injects the
    scheduler's per-segment drop/corrupt faults and receive-side payload
    validation into the wire (DESIGN.md §12): stateless mixers are
    wrapped by :func:`repro.resil.make_validated_mixer`, the compressed
    stateful path masks invalid delta payloads out of its ``fresh``
    update. ``wire_guard`` (a ``resil.GuardSpec``) supplies the
    validation bound and the ``validate_wire`` switch. Without a fault
    the mixers are returned untouched — fault-free wires pay nothing.
    """
    requested = backend
    fault_on = wire_fault is not None and not wire_fault.is_noop()
    if fault_on and backend == "ppermute":
        raise ValueError(
            "wire fault injection has no shard_map path (drop/corrupt "
            "faults are rejected by validate_shard_schedule); run fault "
            "schedules node-stacked with backend='gather' (or 'dense')")
    if gossip not in GOSSIP_MODES:
        raise ValueError(f"unknown gossip mode {gossip!r}; expected one "
                         f"of {GOSSIP_MODES}")
    comp = normalize_compression(compression)
    stale_any = stale is not None and bool(np.any(np.asarray(stale, bool)))
    want_state = (stateful if stateful is not None
                  else (comp is not None or gossip == "delayed"
                        or stale_any))
    masked = active is not None and not np.all(np.asarray(active, bool))
    if not masked:
        active = None
    if want_state:
        if backend == "ppermute":
            if masked:
                raise ValueError(
                    "ppermute mixer has no masked path (churn under "
                    "shard_map is unsupported — DESIGN.md §7); run churn "
                    "schedules node-stacked with backend='gather' (or "
                    "'dense')")
            if stale_any:
                raise ValueError(
                    "straggler (stale) masks are unsupported under "
                    "shard_map — run straggler schedules node-stacked "
                    "with backend='gather' (or 'dense')")
            if wire_dtype != "native":
                raise ValueError(
                    "ppermute mixer moves shards in their storage dtype; "
                    f"wire_dtype={wire_dtype!r} unsupported — use "
                    "backend='gather' for an f32 wire")
            full = _is_full(topology) and not _is_ring(topology)
            if not full and not _is_ring(topology):
                raise ValueError(
                    "compressed/delayed ppermute gossip runs on ring or "
                    f"complete graphs only; topology {topology.name!r} "
                    "must run node-stacked — use backend='gather' (or "
                    "'dense')")
            kw = dict(ppermute_kw)
            axis_names = kw.pop("axis_names")
            axis_sizes = kw.pop("axis_sizes")
            local_nodes = kw.pop("local_nodes", 1)
            if kw.pop("self_weight", None) is not None:
                raise ValueError("self_weight applies to the hierarchical "
                                 "multi-axis mixer only")
            if kw:
                raise ValueError(f"unknown ppermute mixer options "
                                 f"{sorted(kw)}")
            return make_compressed_ppermute_mixer(
                axis_names, axis_sizes, local_nodes=local_nodes,
                num_nodes=topology.n, full_graph=full,
                compression=comp, gossip=gossip,
                consensus_lr=consensus_lr)
        mix = make_compressed_mixer(
            topology, backend, wire_dtype, active=active,
            stale=(stale if stale_any else None),
            compression=comp, gossip=gossip, consensus_lr=consensus_lr,
            wire_fault=(wire_fault if fault_on else None),
            wire_guard=wire_guard)
        mix.remake = lambda active=None, stale=None: make_mixer(
            topology, requested, wire_dtype, active=active,
            compression=comp, gossip=gossip, stale=stale, stateful=True,
            consensus_lr=consensus_lr, wire_fault=wire_fault,
            wire_guard=wire_guard)
        return mix
    if backend == "auto":
        backend = "roll" if _is_ring(topology) and not masked else "gather"
    mix: Mixer
    if backend == "dense":
        mix = make_dense_mixer(topology.mixing_matrix(active), wire_dtype)
    elif backend == "gather":
        mix = make_gather_mixer(topology, wire_dtype, active)
    elif backend == "roll":
        if masked:
            raise ValueError("roll mixer cannot mask churned nodes (a ring "
                             "with a hole is not a ring); use backend="
                             "'gather' or 'auto' for time-varying masks")
        if not _is_ring(topology):
            raise ValueError(
                f"roll mixer requires a ring topology, got {topology.name!r}")
        mix = make_roll_mixer(topology.n, wire_dtype)
    elif backend == "ppermute":
        if masked:
            raise ValueError(
                "ppermute mixer has no masked path (churn under shard_map "
                "is unsupported — DESIGN.md §7); run churn schedules "
                "node-stacked with backend='gather' (or 'dense')")
        if _is_full(topology) and not _is_ring(topology):
            if wire_dtype != "native":
                raise ValueError(
                    "psum mixer moves shards in their storage dtype; "
                    f"wire_dtype={wire_dtype!r} unsupported — use "
                    "backend='gather' for an f32 wire")
            kw = dict(ppermute_kw)
            axis_names = kw.pop("axis_names")
            kw.pop("axis_sizes", None)
            kw.pop("self_weight", None)
            kw.pop("local_nodes", None)
            if kw:
                raise ValueError(f"unknown psum mixer options {sorted(kw)}")
            return make_psum_mixer(axis_names[0], topology.n)
        if not _is_ring(topology):
            raise ValueError(
                "ppermute mixer implements ring/ring-of-rings gossip over "
                f"mesh axes (plus psum on complete graphs); topology "
                f"{topology.name!r} must run node-stacked — use "
                "backend='gather' (or 'dense')")
        if wire_dtype != "native":
            raise ValueError(
                "ppermute mixer moves shards in their storage dtype; "
                f"wire_dtype={wire_dtype!r} unsupported — use "
                "backend='gather' for an f32 wire")
        return make_ppermute_mixer(**ppermute_kw)
    else:
        raise ValueError(f"unknown mixer backend {backend!r}; expected one "
                         "of ('auto', 'dense', 'gather', 'roll', 'ppermute')")
    if fault_on:
        from repro.resil.faults import (DEFAULT_MAX_ABS,
                                        make_validated_mixer)
        mix = make_validated_mixer(
            mix, topology.mixing_matrix(active), wire_fault,
            max_abs=(wire_guard.max_abs if wire_guard is not None
                     else DEFAULT_MAX_ABS),
            validate=(wire_guard.validate_wire
                      if wire_guard is not None else True))
    mix.remake = lambda active=None, stale=None: make_mixer(
        topology, requested, wire_dtype, active=active, stale=stale,
        wire_fault=wire_fault, wire_guard=wire_guard)
    return mix


# ---------------------------------------------------------------------------
# production backend (ppermute over mesh axes)
# ---------------------------------------------------------------------------


def _ring_perms(n: int) -> Tuple[list, list]:
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def block_ring_shift(x, axis_name: str, axis_size: int, shift: int):
    """Global ring roll of a block-sharded node axis (inside shard_map).

    ``x`` is one device's contiguous block (rows ``j·L .. j·L+L-1`` of the
    global node axis, ``L = x.shape[0]``, device ``j`` along
    ``axis_name``). Returns the local block of ``jnp.roll(global_x,
    shift, axis=0)`` for ``shift = ±1``: only the single boundary row
    crosses devices (``lax.ppermute``); the rest is a local shift. With
    ``axis_size == 1`` this degenerates to ``jnp.roll``.
    """
    if shift not in (1, -1):
        raise ValueError(f"block_ring_shift supports shift ±1, got {shift}")
    if axis_size == 1:
        return jnp.roll(x, shift, axis=0)
    if shift == 1:      # row i receives row i-1
        recv = jax.lax.ppermute(
            x[-1:], axis_name,
            [(j, (j + 1) % axis_size) for j in range(axis_size)])
        return jnp.concatenate([recv, x[:-1]], axis=0)
    recv = jax.lax.ppermute(
        x[:1], axis_name,
        [(j, (j - 1) % axis_size) for j in range(axis_size)])
    return jnp.concatenate([x[1:], recv], axis=0)


def make_ppermute_mixer(axis_names: Sequence[str], axis_sizes: Sequence[int],
                        self_weight: float | None = None,
                        local_nodes: int = 1) -> Mixer:
    """Ring gossip over the named mesh axes (to be called inside shard_map).

    With one axis: plain Metropolis ring over the global node axis of
    ``local_nodes · axis_size`` nodes — each mesh index holds a
    contiguous block of ``local_nodes`` rows and only the boundary rows
    cross devices (:func:`block_ring_shift`); ``local_nodes == 1`` is the
    one-node-per-device layout where the whole shard moves. Weights
    follow :func:`make_roll_mixer` exactly (1/3 each for n ≥ 3, 1/2 each
    for n == 2, identity for n == 1), so the sharded mix equals the
    node-stacked roll/dense ring mix to float tolerance.

    With two axes (pod, data): hierarchical ring-of-rings — every node
    mixes with its intra-pod ring neighbours, and nodes additionally mix
    with the same-index node of the neighbouring pod (a torus-like wrap
    over the pod axis), keeping W doubly stochastic. ``self_weight`` and
    ``local_nodes > 1`` apply to the single-axis form only.
    """
    names = list(axis_names)
    if local_nodes < 1:
        raise ValueError(f"local_nodes must be >= 1, got {local_nodes}")
    if len(names) == 1:
        ax, size = names[0], int(axis_sizes[0])
        n = local_nodes * size
        if self_weight is not None:
            raise ValueError("self_weight applies to the hierarchical "
                             "multi-axis mixer only")
        if n <= 1:
            identity = lambda t: t                          # noqa: E731
            identity.mix_leaf = lambda x: x
            identity.axis_name = ax
            return identity

        def mix_leaf(x):
            fwd = block_ring_shift(x, ax, size, 1).astype(jnp.float32)
            if n == 2:
                y = 0.5 * x.astype(jnp.float32) + 0.5 * fwd
            else:
                bwd = block_ring_shift(x, ax, size, -1).astype(jnp.float32)
                y = (x.astype(jnp.float32) + fwd + bwd) / 3.0
            return y.astype(x.dtype)

        def mix(local: PyTree) -> PyTree:
            return jax.tree.map(mix_leaf, local)

        mix.mix_leaf = mix_leaf
        mix.axis_name = ax
        return mix

    if local_nodes != 1:
        raise ValueError("local_nodes > 1 is single-axis only; the "
                         "hierarchical mixer holds one node per mesh index")

    def mix_leaf(x):
        parts = [x]
        for ax, n in zip(names, axis_sizes):
            if n < 2:
                continue
            fwd, bwd = _ring_perms(n)
            parts.append(jax.lax.ppermute(x, ax, fwd))
            if n > 2:
                # at n == 2 fwd and bwd are the same permutation — one
                # part, not a double-weighted duplicate of the neighbour
                parts.append(jax.lax.ppermute(x, ax, bwd))
        if len(parts) == 1:
            return x
        neigh_w = 1.0 / len(parts)
        w_self = self_weight if self_weight is not None else neigh_w
        acc = parts[0].astype(jnp.float32) * w_self
        for p in parts[1:]:
            acc = acc + p.astype(jnp.float32) * neigh_w
        # keep row-sum 1 when self_weight overrides
        total = w_self + neigh_w * (len(parts) - 1)
        return (acc / total).astype(x.dtype)

    def mix(local: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, local)

    mix.mix_leaf = mix_leaf
    mix.axis_name = tuple(names)
    return mix


def make_psum_mixer(axis_name: str, num_nodes: int) -> Mixer:
    """Exact-averaging gossip for the complete graph (inside shard_map).

    The complete graph's Metropolis matrix is uniform 1/n, so the mix is
    one ``psum`` over the node axis — the centralized reference's exact
    averaging, expressed as a collective instead of an n×n einsum.
    Blocks of any ``local_nodes`` work: the local rows are summed before
    the cross-device reduction.
    """
    def mix_leaf(x):
        xf = x.astype(jnp.float32)
        total = jax.lax.psum(jnp.sum(xf, axis=0, keepdims=True), axis_name)
        return jnp.broadcast_to(total / num_nodes, xf.shape).astype(x.dtype)

    def mix(local: PyTree) -> PyTree:
        return jax.tree.map(mix_leaf, local)

    mix.mix_leaf = mix_leaf
    mix.axis_name = axis_name
    return mix


# ---------------------------------------------------------------------------
# compressed / stateful gossip (error feedback + delayed mixing, DESIGN.md §9)
# ---------------------------------------------------------------------------

COMPRESSION_KINDS = ("none", "topk", "randk")
GOSSIP_MODES = ("sync", "delayed")


def normalize_compression(spec):
    """Canonicalize a compression spec to ``None`` or ``(kind, frac)``.

    Accepts ``None`` / ``"none"``, a ``"topk:0.01"`` / ``"randk:0.1"``
    string (bare ``"topk"`` means 1%), or a ``(kind, frac)`` pair.
    ``frac`` is the kept fraction of each leaf's per-node elements,
    validated to (0, 1]."""
    if spec is None or spec == "none" or spec == ("none",):
        return None
    if isinstance(spec, str):
        kind, _, frac_s = spec.partition(":")
        frac = float(frac_s) if frac_s else 0.01
    else:
        kind, frac = spec
        if kind == "none":
            return None
        frac = float(frac)
    if kind not in ("topk", "randk"):
        raise ValueError(f"unknown compression kind {kind!r}; expected one "
                         f"of {COMPRESSION_KINDS}")
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"compression fraction must be in (0, 1], "
                         f"got {frac}")
    return (kind, frac)


def payload_k(size: int, frac: float) -> int:
    """Elements a (topk|randk, frac) payload keeps per node for one leaf
    of ``size`` per-node elements (at least 1, at most all)."""
    return max(1, min(int(size), int(round(frac * int(size)))))


def payload_elem_count(tree, compression, node_stacked: bool = True) -> int:
    """Per-node element count one gossip send carries under
    ``compression`` — the ledger's replacement for the raw param count.
    ``node_stacked`` leaves have a leading node axis (counted per node).
    ``None`` compression returns the full per-node parameter count."""
    comp = normalize_compression(compression)
    leaves = jax.tree.leaves(tree)

    def per_node(x):
        return int(np.prod(x.shape[1:])) if node_stacked else int(x.size)

    if comp is None:
        return sum(per_node(x) for x in leaves)
    _, frac = comp
    return sum(payload_k(per_node(x), frac) for x in leaves)


def _select_payload(uf, kind: str, k: int, keys=None):
    """(vals, idx) payload of a (rows, flat) matrix: per-row top-k by
    magnitude, or a random k-subset (top-k of per-row uniforms — unique
    indices; error feedback absorbs the selection bias). ``keys`` is a
    (rows, 2) uint32 key array, randk only."""
    if kind == "topk":
        _, idx = jax.lax.top_k(jnp.abs(uf), k)
    else:
        r = jax.vmap(lambda kk: jax.random.uniform(kk, uf.shape[1:]))(keys)
        _, idx = jax.lax.top_k(r, k)
    return jnp.take_along_axis(uf, idx, axis=1), idx


def _scatter_payload(vals, idx, flat: int):
    """Dense (rows, flat) f32 reconstruction of a (vals, idx) payload
    (row-wise inverse of :func:`_select_payload`'s gather)."""
    rows = jnp.arange(vals.shape[0])[:, None]
    return jnp.zeros((vals.shape[0], flat), jnp.float32
                     ).at[rows, idx].set(vals.astype(jnp.float32))


class _BoundStatefulMixer:
    """One-trace recorder a stateful mixer returns from ``bind(comm)``.

    Implements the ordinary mixer protocol (``mix(tree)`` /
    ``mix.mix_leaf``) while consuming the comm pytree's leaves by
    position: the algorithm's single whole-tree mix visits params leaves
    in ``jax.tree.leaves`` order (``jax.tree.map`` visitation), so leaf
    ``i`` of the params tree pairs with leaf ``i`` of each comm subtree.
    ``finalize()`` rebuilds the updated comm pytree — and raises if the
    algorithm mixed more or fewer leaves than the params tree has
    (gradient tracking mixes twice, RelaySGD never mixes; both are
    incompatible with per-leaf wire state and rejected loudly)."""

    def __init__(self, leaf_fn, comm, state_names, extra, keys=None,
                 axis_name=None):
        self._leaf_fn = leaf_fn
        self._names = state_names
        self._treedef = jax.tree.structure(comm[state_names[0]])
        self._leaves = {nm: jax.tree.leaves(comm[nm]) for nm in state_names}
        self._num = len(self._leaves[state_names[0]])
        self._new = {nm: [None] * self._num for nm in state_names}
        self._extra = extra            # passthrough comm keys (e.g. "key")
        self._keys = keys              # per-node base keys for randk
        self._i = 0
        if axis_name is not None:
            self.axis_name = axis_name

    def mix_leaf(self, x):
        i = self._i
        if i >= self._num:
            raise ValueError(
                "stateful gossip mixer mixed more leaves than the parameter "
                "tree has — the algorithm mixes more than once per step "
                "(e.g. gradient tracking); compressed/delayed gossip "
                "supports single-mix algorithms only")
        self._i += 1
        state = {nm: self._leaves[nm][i] for nm in self._names}
        y, new_state = self._leaf_fn(x, state, i, self._keys)
        for nm in self._names:
            self._new[nm][i] = new_state[nm]
        return y

    def __call__(self, tree: PyTree) -> PyTree:
        return jax.tree.map(self.mix_leaf, tree)

    def finalize(self):
        if self._i != self._num:
            raise ValueError(
                f"stateful gossip mixer finalized after {self._i} of "
                f"{self._num} leaf mixes — the algorithm never mixed the "
                "full parameter tree (e.g. RelaySGD routes params per-edge "
                "and ignores the gossip mixer); compressed/delayed gossip "
                "requires a single whole-tree mix per step")
        out = {nm: jax.tree.unflatten(self._treedef, self._new[nm])
               for nm in self._names}
        out.update(self._extra)
        return out


def _split_node_keys(keys):
    """Advance (n, 2) per-node PRNG keys one step: returns
    (carry, use) — both (n, 2). Per-leaf keys fold the leaf index into
    ``use`` so every leaf draws independent random-k masks."""
    pair = jax.vmap(lambda kk: jax.random.split(kk))(keys)
    return pair[:, 0], pair[:, 1]


def _fold_leaf(keys, i: int):
    return jax.vmap(jax.random.fold_in, in_axes=(0, None))(
        keys, jnp.uint32(i))


def make_compressed_mixer(topology: Topology, backend: str = "auto",
                          wire_dtype: str = "native", active=None,
                          stale=None, compression=None,
                          gossip: str = "sync", seed: int = 0,
                          consensus_lr: float = 1.0,
                          wire_fault=None, wire_guard=None) -> Mixer:
    """Stateful node-stacked gossip: delta-sparsified wires with error
    feedback, optional one-step-stale (delayed) mixing, and optional
    per-node straggler masks — on top of any node-stacked backend.

    Every node carries a *shared estimate* ``x̂`` of each node's params —
    the accumulation of every payload that node ever shipped, so sender
    and receivers hold identical copies. The wire moves compressed
    parameter **deltas** (the sparsification the paper's wire budget
    asks for), with ``C`` the top-k/random-k selection, mixed CHOCO-SGD
    style::

        p  = C(x - x̂)                   # (vals, idx) delta payload
        x̂' = x̂ + scatter(p)             # both ends apply the same delta
        y  = x + γ · (M(x̂*) - x̂*)       # x̂*: estimates actually mixed

    where ``M`` is the *plain* backend mixer (one Metropolis row-sum
    ``Σ_j W_ij x̂*_j``) and ``γ = consensus_lr`` — algebraically
    ``y_i = x_i + γ·Σ_j W_ij (x̂*_j - x̂*_i)``: the consensus correction
    is a difference of *public estimates*, so it vanishes when estimates
    agree (local training proceeds unimpeded however aggressive the
    compression) and never drags ``x`` toward stale snapshots. Error
    feedback is implicit: whatever a payload cut stays in the gap
    ``x - x̂'`` and rides the next delta (the gap is the EF residual;
    ``frac=1, γ=1`` makes ``x̂' = x`` up to f32 rounding and ``y = Wx``,
    recovering the dense mix). ``x̂*`` is this step's estimate (sync),
    the previous step's (delayed), or — for stale stragglers — frozen at
    the last payload the node produced. With ``compression=None`` the
    wire is the raw params (state is just the previous snapshot,
    classic one-step-stale gossip ``y_i = W_ii·x_i + Σ_{j≠i} W_ij·
    x_j^{t-1}``) and the sync all-fresh path reduces to the plain
    backend mix exactly.

    Down nodes (``active`` mask) keep identity rows in the masked
    Metropolis matrix, so ``y_i = x_i`` for them regardless of payloads.
    Stale nodes stay *active* — they train and receive (weights are NOT
    renormalized away from them); only their outgoing payload freezes.

    ``wire_fault`` (DESIGN.md §12) injects drop/corrupt faults into the
    delta payloads: dropped senders' payloads never land, corrupted ones
    are validated (finite, ``|v| <= max_abs``) and invalid payloads are
    masked out of the ``fresh`` update at *both* ends — sender and
    receiver estimates stay in lockstep, and neighbours keep mixing the
    sender's last good x̂ (stale-like degradation rather than identity
    fallback). Masking with an all-valid vector is bitwise neutral, so
    detected-corrupt ≡ drop holds here too. Unvalidated corruption
    propagation (``GuardSpec.validate_wire=False``) is unsupported on
    compressed wires, as are faults on the uncompressed stateful
    (delayed/stale ``prev``-snapshot) path.
    """
    comp = normalize_compression(compression)
    kind, frac = comp if comp is not None else ("none", 1.0)
    fault_on = wire_fault is not None and not wire_fault.is_noop()
    if fault_on and kind == "none":
        raise ValueError(
            "wire fault injection on the uncompressed stateful gossip "
            "path (delayed/stale 'prev' snapshots) is unsupported — "
            "inject faults on sync stateless gossip or compressed "
            "(topk/randk) wires")
    if fault_on and wire_guard is not None and not wire_guard.validate_wire:
        raise ValueError(
            "GuardSpec.validate_wire=False (propagating unvalidated "
            "corruption) is unsupported on compressed wires; compressed "
            "payloads are always validated and degrade to the sender's "
            "last good estimate")
    if gossip not in GOSSIP_MODES:
        raise ValueError(f"unknown gossip mode {gossip!r}; expected one "
                         f"of {GOSSIP_MODES}")
    n = topology.n
    masked = active is not None and not np.all(np.asarray(active, bool))
    act = (np.asarray(active, bool) if masked else np.ones(n, bool))
    stale_arr = (np.asarray(stale, bool)
                 if stale is not None and np.any(stale) else None)
    if stale_arr is not None and stale_arr.shape != (n,):
        raise ValueError(f"stale mask shape {stale_arr.shape} != ({n},)")
    base = make_mixer(topology, backend, wire_dtype,
                      active=(act if masked else None))
    W = topology.mixing_matrix(act if masked else None)
    d_self = jnp.asarray(np.diag(W), jnp.float32)
    gamma = float(consensus_lr)
    if not 0.0 < gamma <= 1.0:
        raise ValueError(f"consensus_lr must be in (0, 1], got {gamma}")
    fresh_np = act & (~stale_arr if stale_arr is not None else True)
    if fault_on:
        from repro.resil.faults import (DEFAULT_MAX_ABS, corrupt_values,
                                        payload_valid)
        drop_np = np.zeros(n, bool)
        drop_np[list(wire_fault.drop)] = True
        corrupt_np = np.zeros(n, bool)
        corrupt_np[list(wire_fault.corrupt)] = True
        fresh_np = fresh_np & ~drop_np
        fault_max_abs = (wire_guard.max_abs if wire_guard is not None
                         else DEFAULT_MAX_ABS)
        has_corrupt = bool(corrupt_np.any())
        corrupt_col = jnp.asarray(corrupt_np)[:, None]
    fresh = jnp.asarray(fresh_np)
    stale_j = jnp.asarray(stale_arr) if stale_arr is not None else None

    def _col(v, ndim):
        return v.reshape((n,) + (1,) * (ndim - 1))

    def leaf_fn(x, state, i, keys):
        xf = x.astype(jnp.float32)
        if kind == "none":
            prev = state["prev"]
            if gossip == "delayed":
                p_hat = prev
            elif stale_j is not None:
                p_hat = jnp.where(_col(stale_j, x.ndim), prev, x)
            else:
                p_hat = x
            phf = p_hat.astype(jnp.float32)
            y = base.mix_leaf(p_hat).astype(jnp.float32) \
                + _col(d_self, x.ndim) * (xf - phf)
            new_prev = jnp.where(_col(fresh, x.ndim), x, prev)
            return y.astype(x.dtype), {"prev": new_prev}
        hat = state["hat"]                      # (n, flat) shared estimates
        flat = int(np.prod(x.shape[1:]))
        xr = xf.reshape(n, -1)
        k = payload_k(flat, frac)
        lk = _fold_leaf(keys, i) if kind == "randk" else None
        vals, idx = _select_payload(xr - hat, kind, k, lk)
        if wire_dtype != "float32":
            # native wire: payload values round-trip the storage dtype;
            # the quantization error stays in the x - x̂ gap (implicit EF)
            vals = vals.astype(x.dtype).astype(jnp.float32)
        fcol = fresh[:, None]
        if fault_on:
            if has_corrupt:
                vals = jnp.where(corrupt_col,
                                 corrupt_values(vals, wire_fault.mode),
                                 vals)
            # invalid payloads are discarded by both ends: every node's
            # replica of the sender's x̂ stays at the last good value
            fcol = fcol & payload_valid(vals, fault_max_abs)[:, None]
        new_hat = jnp.where(fcol, hat + _scatter_payload(vals, idx, flat),
                            hat)
        use = hat if gossip == "delayed" else new_hat
        p_hat = use.reshape(x.shape)
        y = xf + gamma * (base.mix_leaf(p_hat).astype(jnp.float32)
                          .reshape(n, -1) - use).reshape(x.shape)
        return y.astype(x.dtype), {"hat": new_hat}

    state_names = ("prev",) if kind == "none" else ("hat",)

    def init_state(stacked: PyTree):
        """The comm pytree for step 0: the shared estimates start at the
        exact initial params (every node begins from the same broadcast
        init, so ``x̂₀ = x₀`` needs no wire traffic) — delayed/stale
        consumers at step 0 mix a real snapshot, and the first delta
        payload carries only the first local step's drift."""
        if kind == "none":
            return {"prev": jax.tree.map(jnp.asarray, stacked)}
        comm = {"hat": jax.tree.map(
            lambda x: jnp.asarray(x).astype(jnp.float32).reshape(
                x.shape[0], -1), stacked)}
        if kind == "randk":
            comm["key"] = jax.random.split(jax.random.PRNGKey(seed), n)
        return comm

    def bind(comm):
        keys = None
        extra = {}
        if kind == "randk":
            carry, keys = _split_node_keys(comm["key"])
            extra = {"key": carry}
        return _BoundStatefulMixer(leaf_fn, comm, state_names, extra, keys)

    def mix(tree: PyTree) -> PyTree:
        raise TypeError(
            "stateful gossip mixer must be bound to its comm state: "
            "mix.bind(comm)(tree) — core.driver.make_step does this when "
            "step.comm is set; mix.init_state(params) builds the initial "
            "comm pytree")

    mix.stateful = True
    mix.init_state = init_state
    mix.bind = bind
    mix.compression = comp
    mix.gossip = gossip
    # telemetry hook: the shared-estimate tree x̂ inside a comm pytree —
    # each leaf row-congruent with params, so the metrics bus can form
    # the CHOCO EF residual ‖x − x̂‖ (for kind "none" the 'prev' snapshot
    # plays x̂ and the residual measures the delayed/stale gossip gap).
    mix.ef_ref = lambda comm: comm["prev" if kind == "none" else "hat"]
    return mix


def make_compressed_ppermute_mixer(axis_names: Sequence[str],
                                   axis_sizes: Sequence[int],
                                   local_nodes: int = 1, *,
                                   num_nodes: int, full_graph: bool = False,
                                   compression=None, gossip: str = "sync",
                                   seed: int = 0,
                                   consensus_lr: float = 1.0) -> Mixer:
    """The shard_map twin of :func:`make_compressed_mixer` — compressed /
    delayed gossip inside ``shard_map`` over one mesh node axis.

    Compressed delta payloads ride the same value+index wire format the
    streaming label rounds use (``labeling.shard_label_round``): per
    leaf, each node's (k,) values and (k,) int32 indices of
    ``C(x - x̂)``. Each device carries its own nodes' shared estimates
    ``x̂`` *plus replicas of its ring neighbours' estimates* (``hfwd`` /
    ``hbwd``), kept in lockstep by applying the very payloads that cross
    the wire: the (vals, idx) arrays take the boundary-row
    :func:`block_ring_shift` (2·k·(4+4) bytes cross each device edge
    instead of the full row) and are scattered into the replicas at the
    receiver. The mix combines roll-mixer Metropolis weights over the
    full-rank replicas, CHOCO-SGD style: ``y_i = x_i + γ·((x̂_i + x̂_fwd
    + x̂_bwd)/3 - x̂_i)`` — identical math to the node-stacked
    ``y_i = x_i + γ·Σ_j W_ij (x̂_j - x̂_i)`` form, so shard and stacked
    trajectories agree to float tolerance. A complete graph keeps a
    replicated running sum ``S = Σ_j x̂_j`` updated from an
    ``all_gather`` of the (k,)-payloads (still a compressed wire):
    ``y_i = x_i + γ·(S/n - x̂_i)``. Delayed gossip mixes the pre-update
    replicas (the previous step's estimates). ``init_state`` is
    collective-free (plain node-stacked math on the global arrays), so
    the initial comm pytree is built outside shard_map and device_put
    like params. Stragglers (``stale``) and churn masks are unsupported
    under shard_map, as for the plain ppermute backend."""
    comp = normalize_compression(compression)
    kind, frac = comp if comp is not None else ("none", 1.0)
    if gossip not in GOSSIP_MODES:
        raise ValueError(f"unknown gossip mode {gossip!r}; expected one "
                         f"of {GOSSIP_MODES}")
    names = list(axis_names)
    if len(names) != 1:
        raise ValueError("compressed/delayed gossip supports the "
                         "single-axis ppermute mixer only (no hierarchical "
                         "ring-of-rings) — use the node-stacked backends")
    ax, size = names[0], int(axis_sizes[0])
    if local_nodes < 1:
        raise ValueError(f"local_nodes must be >= 1, got {local_nodes}")
    n = num_nodes
    if n != local_nodes * size:
        raise ValueError(f"num_nodes ({n}) != local_nodes ({local_nodes}) "
                         f"· axis size ({size})")
    if n <= 1:
        raise ValueError("compressed/delayed gossip needs n >= 2 nodes "
                         "(a single node has no wire to compress)")
    gamma = float(consensus_lr)
    if not 0.0 < gamma <= 1.0:
        raise ValueError(f"consensus_lr must be in (0, 1], got {gamma}")

    def leaf_fn(x, state, i, keys):
        # x: this device's (L, ...) block of the global node axis
        L = x.shape[0]
        xf = x.astype(jnp.float32)
        if kind == "none":
            prev = state["prev"]
            p_hat = prev if gossip == "delayed" else x
            phf = p_hat.astype(jnp.float32)
            if full_graph:
                tot = jax.lax.psum(jnp.sum(phf, axis=0, keepdims=True), ax)
                y = (xf + tot - phf) / n
            else:
                fwd = block_ring_shift(phf, ax, size, 1)
                if n == 2:
                    y = 0.5 * xf + 0.5 * fwd
                else:
                    bwd = block_ring_shift(phf, ax, size, -1)
                    y = (xf + fwd + bwd) / 3.0
            return y.astype(x.dtype), {"prev": x}
        hat = state["hat"]             # (L, flat) own shared estimates
        flat = int(np.prod(x.shape[1:]))
        xr = xf.reshape(L, -1)
        k = payload_k(flat, frac)
        lk = _fold_leaf(keys, i) if kind == "randk" else None
        vals, idx = _select_payload(xr - hat, kind, k, lk)
        vals = vals.astype(x.dtype).astype(jnp.float32)  # native wire
        new_hat = hat + _scatter_payload(vals, idx, flat)
        if full_graph:
            s = state["hsum"]          # (1, flat) replicated Σ_j x̂_j
            gv = jax.lax.all_gather(vals, ax)            # (size, L, k)
            gi = jax.lax.all_gather(idx, ax)
            new_s = s + jnp.sum(_scatter_payload(
                gv.reshape(-1, k), gi.reshape(-1, k), flat),
                axis=0, keepdims=True)
            uh, us = (hat, s) if gossip == "delayed" else (new_hat, new_s)
            y = xr + gamma * (us / n - uh)
            new_state = {"hat": new_hat, "hsum": new_s}
        else:
            hf = state["hfwd"]         # row i replicates x̂_{i-1}
            new_hf = hf + _scatter_payload(
                block_ring_shift(vals, ax, size, 1),
                block_ring_shift(idx, ax, size, 1), flat)
            if n == 2:
                uh, unb = ((hat, hf) if gossip == "delayed"
                           else (new_hat, new_hf))
                y = xr + gamma * (0.5 * (uh + unb) - uh)
                new_state = {"hat": new_hat, "hfwd": new_hf}
            else:
                hb = state["hbwd"]     # row i replicates x̂_{i+1}
                new_hb = hb + _scatter_payload(
                    block_ring_shift(vals, ax, size, -1),
                    block_ring_shift(idx, ax, size, -1), flat)
                uh, uf_, ub_ = ((hat, hf, hb) if gossip == "delayed"
                                else (new_hat, new_hf, new_hb))
                y = xr + gamma * ((uh + uf_ + ub_) / 3.0 - uh)
                new_state = {"hat": new_hat, "hfwd": new_hf,
                             "hbwd": new_hb}
        return y.reshape(x.shape).astype(x.dtype), new_state

    if kind == "none":
        state_names = ("prev",)
    elif full_graph:
        state_names = ("hat", "hsum")
    elif n == 2:
        state_names = ("hat", "hfwd")
    else:
        state_names = ("hat", "hfwd", "hbwd")

    def init_state(stacked: PyTree):
        """Built on the *global* node-stacked arrays (no collectives) —
        run it outside shard_map and device_put the result with
        ``node_stacked_shardings`` like the params (the (1, flat)
        ``hsum`` leaves land replicated)."""
        if kind == "none":
            return {"prev": jax.tree.map(jnp.asarray, stacked)}
        hat = jax.tree.map(
            lambda x: jnp.asarray(x).astype(jnp.float32).reshape(
                x.shape[0], -1), stacked)
        comm = {"hat": hat}
        if full_graph:
            comm["hsum"] = jax.tree.map(
                lambda h: jnp.sum(h, axis=0, keepdims=True), hat)
        else:
            comm["hfwd"] = jax.tree.map(
                lambda h: jnp.roll(h, 1, axis=0), hat)
            if n > 2:
                comm["hbwd"] = jax.tree.map(
                    lambda h: jnp.roll(h, -1, axis=0), hat)
        if kind == "randk":
            comm["key"] = jax.random.split(jax.random.PRNGKey(seed), n)
        return comm

    def bind(comm):
        keys = None
        extra = {}
        if kind == "randk":
            carry, keys = _split_node_keys(comm["key"])
            extra = {"key": carry}
        return _BoundStatefulMixer(leaf_fn, comm, state_names, extra, keys,
                                   axis_name=ax)

    def mix(tree: PyTree) -> PyTree:
        raise TypeError(
            "stateful gossip mixer must be bound to its comm state: "
            "mix.bind(comm)(tree) — core.driver.make_shard_step does this "
            "inside its shard_map body")

    mix.stateful = True
    mix.init_state = init_state
    mix.bind = bind
    mix.compression = comp
    mix.gossip = gossip
    mix.axis_name = ax
    # telemetry hook (see make_compressed_mixer): inside shard_map the
    # comm leaves are this device's local (L, flat) rows, matching the
    # local param rows, so the EF residual shards for free.
    mix.ef_ref = lambda comm: comm["prev" if kind == "none" else "hat"]
    return mix


def make_model_sharded_mixer(inner, model_dims, model_size: int,
                             model_axis: str = "model") -> Mixer:
    """2-D federation-mesh adapter for the stateful *compressed* ppermute
    mixer (DESIGN.md §10).

    On the ``("node", "model")`` mesh each device holds only a model-axis
    slice of every sharded param leaf, but the CHOCO payload selection
    (``_select_payload`` top-k / random-k of ``x − x̂``) must see the
    **full** delta row to pick the same coordinates as the 1-D run — a
    per-shard top-k is a different compressor and breaks the trajectory
    oracle. So per leaf: all-gather ``x`` over the model axis on its
    sharded dim, run the unchanged 1-D ``leaf_fn`` on full rows (the comm
    state ``x̂``/``hfwd``/``hbwd``/``hsum`` stays full-width, replicated
    over the model axis — a deliberate memory trade, noted in §10), then
    slice the mixed row back to this shard. Every model peer computes
    identical payloads and estimate updates from identical inputs, so
    the comm state is genuinely replicated and the wire bytes per *node*
    are unchanged by model parallelism.

    ``model_dims``: per-leaf (params ``jax.tree.leaves`` order) index of
    the model-sharded dim, or None for model-replicated leaves — from
    ``launch.sharding.spec_model_dim`` over the federation spec tree.
    The uncompressed delayed mixer (``kind == "none"``) needs no adapter:
    its ``prev`` state is params-shaped and its mix is linear per
    coordinate, so it runs shard-natively on the sliced leaves.
    """
    dims = list(model_dims)

    def _wrap(fn):
        def wrapped(x, state, i, keys):
            d = dims[i]
            if d is None:
                return fn(x, state, i, keys)
            xg = jax.lax.all_gather(x, model_axis, axis=d, tiled=True)
            y, new_state = fn(xg, state, i, keys)
            j = jax.lax.axis_index(model_axis)
            width = y.shape[d] // model_size
            return (jax.lax.dynamic_slice_in_dim(y, j * width, width,
                                                 axis=d), new_state)
        return wrapped

    def bind(comm):
        bound = inner.bind(comm)
        bound._leaf_fn = _wrap(bound._leaf_fn)
        return bound

    def mix(tree: PyTree) -> PyTree:
        raise TypeError(
            "stateful gossip mixer must be bound to its comm state: "
            "mix.bind(comm)(tree) — core.driver.make_shard_step does this "
            "inside its shard_map body")

    mix.stateful = True
    mix.init_state = inner.init_state
    mix.bind = bind
    mix.compression = getattr(inner, "compression", None)
    mix.gossip = getattr(inner, "gossip", "sync")
    mix.axis_name = inner.axis_name
    # no ef_ref: the comm estimates are full-width (model-replicated)
    # while params are model-sharded, so forming ‖x − x̂‖ would need an
    # extra per-step all-gather; the metrics bus reports ef=0 here.
    return mix


def consensus_distance(stacked: PyTree) -> jax.Array:
    """Mean L2 distance of node params from the node-average (diagnostic)."""
    def per_leaf(x):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=0, keepdims=True)
        return jnp.sum((xf - mean) ** 2)
    total = sum(jax.tree.leaves(jax.tree.map(per_leaf, stacked)))
    return jnp.sqrt(total)
