"""Gossip mixing backends behind one entry point.

:func:`make_mixer` builds x_i ← Σ_j W_ij x_j over a pytree of parameters
for any :class:`~repro.core.topology.Topology`, with interchangeable
backends:

* ``dense`` — simulation reference. Node-stacked pytrees (leading axis =
  node) mixed by the dense (n, n) Metropolis matrix via ``einsum``. Used
  by the CPU accuracy experiments (paper repro) where all nodes live in
  one process via ``vmap``. O(n²) work per leaf regardless of graph
  sparsity — the numerical oracle the other backends are tested against.

* ``gather`` — neighbour-gather on node-stacked arrays. Each node gathers
  its padded neighbour slots (``Topology.neighbor_arrays``) and combines
  with the gathered Metropolis weights — O(Σ deg) work, and the form that
  shards: a gather over a static index array lowers to neighbour-local
  collectives when the node axis is sharded.

* ``roll`` — ring-only fast path. ``jnp.roll`` along the node axis, which
  XLA lowers to ``collective-permute`` between neighbouring node groups
  when that axis is sharded over the mesh (the launch path's production
  gossip; no cross-node all-reduce appears in the HLO).

* ``ppermute`` — explicit production backend. Inside ``shard_map`` over
  the mesh node axes, each node `lax.ppermute`s its parameter shard to
  its graph neighbours and combines with its Metropolis row. Communication
  is therefore exactly the paper's peer-to-peer exchange (no all-reduce),
  visible in the compiled HLO as `collective-permute` ops.

All node-stacked backends take ``wire_dtype``: "native" moves parameters
between nodes in their storage dtype (bf16 params → bf16 gossip traffic,
§Perf byte-halving) and accumulates the weighted sum in f32; "float32"
upcasts before the exchange (paper-faithful full-precision mixing).
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology

PyTree = object
Mixer = Callable[[PyTree], PyTree]


# ---------------------------------------------------------------------------
# simulation backend (node-stacked arrays)
# ---------------------------------------------------------------------------


def make_dense_mixer(W: np.ndarray, wire_dtype: str = "float32") -> Mixer:
    Wj = jnp.asarray(W, jnp.float32)

    def mix(stacked: PyTree) -> PyTree:
        def mix_leaf(x):
            # the einsum accumulates in f32 either way; "native" keeps the
            # operand in storage dtype (the bytes a real wire would carry)
            xf = x.astype(jnp.float32) if wire_dtype == "float32" else x
            y = jnp.einsum("ij,j...->i...", Wj, xf,
                           preferred_element_type=jnp.float32)
            return y.astype(x.dtype)
        return jax.tree.map(mix_leaf, stacked)

    return mix


def make_gather_mixer(topology: Topology, wire_dtype: str = "native",
                      active=None) -> Mixer:
    """Neighbour-gather gossip on node-stacked pytrees.

    Row i combines x[nbr[i, d]] with the gathered Metropolis weights
    W[i, nbr[i, d]]; padding slots carry weight 0. Exactly equals the
    dense-W einsum (W is supported on self ∪ neighbours) at O(Σ deg)
    work instead of O(n²). With an ``active`` mask the gathered weights
    come from the masked Metropolis matrix (down nodes keep identity
    rows, active ones renormalize over surviving neighbours) — same
    gather structure, so churn costs no recompile of the index plumbing.
    """
    nbr, valid = topology.neighbor_arrays(include_self=True)
    W = topology.mixing_matrix(active)
    w = W[np.arange(topology.n)[:, None], nbr] * valid      # (n, D)
    nbr_j = jnp.asarray(nbr)
    w_j = jnp.asarray(w, jnp.float32)

    def mix(stacked: PyTree) -> PyTree:
        def mix_leaf(x):
            xw = x.astype(jnp.float32) if wire_dtype == "float32" else x
            g = xw[nbr_j]                                   # (n, D, ...)
            y = jnp.einsum("nd,nd...->n...", w_j, g.astype(jnp.float32))
            return y.astype(x.dtype)
        return jax.tree.map(mix_leaf, stacked)

    return mix


def _is_ring(topology: Topology) -> bool:
    n = topology.n
    if n <= 2:
        return True
    return all(topology.neighbors(i) == sorted({(i - 1) % n, (i + 1) % n})
               for i in range(n))


def make_roll_mixer(num_nodes: int, wire_dtype: str = "native") -> Mixer:
    """Ring gossip via rolls along the node axis (→ collective-permute).

    Metropolis weights for a ring: 1/3 self + 1/3 each neighbour
    (n == 2 degenerates to 1/2, 1/2; n == 1 to identity).
    """
    if num_nodes <= 1:
        return lambda t: t

    def mix(tree):
        def leaf(x):
            xw = x.astype(jnp.float32) if wire_dtype == "float32" else x
            fwd = jnp.roll(xw, 1, axis=0).astype(jnp.float32)
            if num_nodes == 2:
                y = 0.5 * x.astype(jnp.float32) + 0.5 * fwd
            else:
                bwd = jnp.roll(xw, -1, axis=0).astype(jnp.float32)
                y = (x.astype(jnp.float32) + fwd + bwd) / 3.0
            return y.astype(x.dtype)
        return jax.tree.map(leaf, tree)

    return mix


def make_mixer(topology: Topology, backend: str = "auto",
               wire_dtype: str = "native", active=None,
               **ppermute_kw) -> Mixer:
    """One entry point for every gossip backend (see module docstring).

    ``backend="auto"`` picks the roll fast path on rings (lowers to
    collective-permute when the node axis is sharded) and neighbour-gather
    everywhere else. ``backend="roll"`` requires a ring topology;
    ``backend="ppermute"`` forwards ``axis_names`` / ``axis_sizes`` /
    ``self_weight`` to :func:`make_ppermute_mixer` (for use inside
    ``shard_map``) — that backend implements ring / ring-of-rings gossip
    over the mesh axes only, so it too rejects non-ring topologies, and
    it always moves shards in their storage dtype (``wire_dtype`` other
    than "native" is rejected rather than silently dropped).

    ``active`` is the churn path: an (n,) availability mask that switches
    the mixing weights to the masked Metropolis matrix
    (``Topology.mixing_matrix(active)`` — doubly stochastic on the active
    subgraph, identity on down nodes). A ring with a hole is no longer a
    ring, so ``auto`` routes masked rings to the gather backend and the
    roll/ppermute fast paths reject masks. The node-stacked backends
    (dense / gather / roll / auto) return a mixer carrying a
    ``remake(active=...)`` handle that rebuilds the same
    backend/wire-dtype mixer for a new availability mask — the scheduler
    path as nodes leave and rejoin. The ppermute backend has no masked
    path and no remake handle (shard_map gossip under churn is an open
    item).
    """
    requested = backend
    masked = active is not None and not np.all(np.asarray(active, bool))
    if not masked:
        active = None
    if backend == "auto":
        backend = "roll" if _is_ring(topology) and not masked else "gather"
    mix: Mixer
    if backend == "dense":
        mix = make_dense_mixer(topology.mixing_matrix(active), wire_dtype)
    elif backend == "gather":
        mix = make_gather_mixer(topology, wire_dtype, active)
    elif backend == "roll":
        if masked:
            raise ValueError("roll mixer cannot mask churned nodes (a ring "
                             "with a hole is not a ring); use backend="
                             "'gather' or 'auto' for time-varying masks")
        if not _is_ring(topology):
            raise ValueError(
                f"roll mixer requires a ring topology, got {topology.name!r}")
        mix = make_roll_mixer(topology.n, wire_dtype)
    elif backend == "ppermute":
        if masked:
            raise ValueError("ppermute mixer has no masked path; churn "
                             "runs use the gather/dense backends")
        if not _is_ring(topology):
            raise ValueError("ppermute mixer implements ring/ring-of-rings "
                             f"gossip over mesh axes; got {topology.name!r}")
        if wire_dtype != "native":
            raise ValueError("ppermute mixer moves shards in their storage "
                             f"dtype; wire_dtype={wire_dtype!r} unsupported")
        return make_ppermute_mixer(**ppermute_kw)
    else:
        raise ValueError(f"unknown mixer backend {backend!r}; expected one "
                         "of ('auto', 'dense', 'gather', 'roll', 'ppermute')")
    mix.remake = lambda active=None: make_mixer(topology, requested,
                                                wire_dtype, active=active)
    return mix


# ---------------------------------------------------------------------------
# production backend (ppermute over mesh axes)
# ---------------------------------------------------------------------------


def _ring_perms(n: int) -> Tuple[list, list]:
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def make_ppermute_mixer(axis_names: Sequence[str], axis_sizes: Sequence[int],
                        self_weight: float | None = None) -> Mixer:
    """Ring gossip over the named mesh axes (to be called inside shard_map).

    With one axis: plain ring over that axis. With two axes (pod, data):
    hierarchical ring-of-rings — every node mixes with its intra-pod ring
    neighbours, and nodes additionally mix with the same-index node of the
    neighbouring pod (a torus-like wrap over the pod axis), keeping W
    doubly stochastic.

    Metropolis weights for a degree-2 ring are 1/3 each; hierarchical
    adds the pod links with their own 1/3·(pods>1) share.
    """
    names = list(axis_names)

    def mix(local: PyTree) -> PyTree:
        parts = [local]
        weights = []
        for ax, n in zip(names, axis_sizes):
            if n < 2:
                continue
            fwd, bwd = _ring_perms(n)
            parts.append(jax.tree.map(
                lambda x: jax.lax.ppermute(x, ax, fwd), local))
            parts.append(jax.tree.map(
                lambda x: jax.lax.ppermute(x, ax, bwd), local))
            weights += [1.0, 1.0]
        if len(parts) == 1:
            return local
        neigh_w = 1.0 / (len(weights) + 1.0)
        w_self = self_weight if self_weight is not None else neigh_w

        def combine(*xs):
            acc = xs[0].astype(jnp.float32) * w_self
            for x in xs[1:]:
                acc = acc + x.astype(jnp.float32) * neigh_w
            # keep row-sum 1 when self_weight overrides
            total = w_self + neigh_w * (len(xs) - 1)
            return (acc / total).astype(xs[0].dtype)

        return jax.tree.map(combine, *parts)

    return mix


def consensus_distance(stacked: PyTree) -> jax.Array:
    """Mean L2 distance of node params from the node-average (diagnostic)."""
    def per_leaf(x):
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=0, keepdims=True)
        return jnp.sum((xf - mean) ** 2)
    total = sum(jax.tree.leaves(jax.tree.map(per_leaf, stacked)))
    return jnp.sqrt(total)
