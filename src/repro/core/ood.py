"""Out-of-Distribution detection for IDKD (paper §3, Figure 2c).

The paper uses the maximum-softmax-probability (MSP) detector
(Hendrycks & Gimpel 2017): a sample is In-Distribution iff
max softmax prob > t. The threshold t_opt is calibrated on a ROC sweep —
private (validation) data as the positive/ID class, a calibration set
(the public dataset) as the negative/OoD class — picking the point that
"maximizes TPR while minimizing FPR", i.e. Youden's J = TPR − FPR
(Fawcett 2006).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def msp_confidence(logits, temperature: float = 1.0) -> jax.Array:
    """Max softmax probability. logits: (..., C) -> (...)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
    return jnp.max(probs, axis=-1)


def energy_score(logits, temperature: float = 1.0) -> jax.Array:
    """Energy-based OoD score (Liu et al. 2020b), the paper's cited
    alternative to MSP: −E(x) = T·logsumexp(z/T). Higher ⇒ more ID.
    Plugs into the same ROC calibration as MSP."""
    lf = logits.astype(jnp.float32)
    return temperature * jax.nn.logsumexp(lf / temperature, axis=-1)


def confidence(logits, detector: str = "msp", temperature: float = 1.0
               ) -> jax.Array:
    """Dispatch on IDKDConfig.detector: 'msp' (paper's default) | 'energy'."""
    if detector == "energy":
        return energy_score(logits, temperature)
    if detector == "msp":
        return msp_confidence(logits, temperature)
    raise ValueError(f"unknown OoD detector {detector!r}")


def sequence_confidence(logits, temperature: float = 1.0) -> jax.Array:
    """LLM adaptation: per-sequence MSP = mean over positions of the
    per-token max softmax probability. logits: (B, S, V) -> (B,)."""
    return jnp.mean(msp_confidence(logits, temperature), axis=-1)


def roc_curve(id_scores, ood_scores, num_thresholds: int = 256
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Threshold sweep. Returns (thresholds, TPR, FPR); score>t ⇒ ID."""
    lo = jnp.minimum(jnp.min(id_scores), jnp.min(ood_scores))
    hi = jnp.maximum(jnp.max(id_scores), jnp.max(ood_scores))
    ts = jnp.linspace(lo - 1e-6, hi + 1e-6, num_thresholds)
    tpr = jnp.mean(id_scores[None, :] > ts[:, None], axis=1)
    fpr = jnp.mean(ood_scores[None, :] > ts[:, None], axis=1)
    return ts, tpr, fpr


def calibrate_threshold(id_scores, ood_scores,
                        num_thresholds: int = 256) -> jax.Array:
    """t_opt = argmax_t TPR(t) − FPR(t) (Youden's J) — paper's Optimal()."""
    ts, tpr, fpr = roc_curve(id_scores, ood_scores, num_thresholds)
    return ts[jnp.argmax(tpr - fpr)]


def auroc(id_scores, ood_scores, num_thresholds: int = 512) -> jax.Array:
    """Area under the ROC (diagnostic for detector quality)."""
    _, tpr, fpr = roc_curve(id_scores, ood_scores, num_thresholds)
    order = jnp.argsort(fpr)
    return jnp.trapezoid(tpr[order], fpr[order])


def select_id_subset(confidences, threshold) -> jax.Array:
    """Boolean ID mask over the public set: conf > t_opt (Algorithm 1 l.7)."""
    return confidences > threshold
