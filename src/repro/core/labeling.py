"""Unified IDKD labeling engine — the paper's homogenization round
(Algorithm 1, lines 5–14) as one backend-agnostic path.

One call, :func:`label_round`, owns the whole round for every consumer:

  (line 5)  soft labels     softmax(f_i(D_P) / T)
  (line 6)  t_opt           ROC-calibrated detector threshold per node
  (line 7)  D_ID^i          {p : conf_p > t_opt}
  (l. 9-13) exchange        labels-only gossip with graph neighbours
  (line 14) average         per-sample mean over contributing nodes

Three interchangeable backends (``IDKDConfig.label_backend``):

``dense``
    The jnp reference and numerical oracle. Labels are full ``(n, P, C)``
    probability tensors; the exchange is a scan over padded neighbour
    slots (``Topology.neighbor_arrays``) — O(Σ deg · P · C) work and
    O(n · P · C) memory. (The seed's ``(n, n, P)`` membership einsum was
    O(n² · P · C); it is gone.)

``fused``
    Public-set logits are read once: detector confidence *and* the top-k
    sparse soft-label payload come out of a single fused pass — the
    ``msp_select`` Pallas kernel on TPU, its jnp oracle (which XLA fuses
    the same way) elsewhere. Output is sparse, exchanged sparsely.

``sparse``
    Like ``fused`` but scored/sparsified with plain jnp ops. Labels cross
    the "wire" as :class:`repro.core.distill.SparseLabels` (top-k values +
    class indices) and are *never* densified to ``(n, P, C)``: neighbour
    averaging concatenates the contributors' payloads along the k axis
    with 1/cnt weights (exact — see DESIGN.md §2), and training consumes
    them through ``distill.sparse_kd_loss``. Exchange cost is
    O(Σ deg · P · k) instead of O(Σ deg · P · C).

Simulation (``core.simulator``) and production launch (``launch.train``)
both call this engine; classifier ``(n, P, C)`` and LM ``(n, P, S, V)``
logit stacks are handled uniformly (sequence confidence = mean over S of
the per-token detector score).

**Streaming rounds** (DESIGN.md §8). :func:`label_round` takes
pre-materialized logit stacks — O(n · P · C) HBM for the round's input
alone, the dominant cost at LLM vocab. :func:`streaming_label_round`
is the production form of the fused/sparse backends: it takes the
*models* (via their ``forward_features`` / ``head_params`` hooks) and
``lax.scan``s the public set through them in microbatches, running the
fused head-select pass (``kernels/head_select`` on TPU, its jnp oracle
elsewhere) per chunk and accumulating only ``(conf, top-k values,
top-k indices)`` — peak memory O(microbatch · C) + O(n · P · k); the
full logit stack never exists. :func:`shard_streaming_label_round` is
its ``shard_map`` twin: the scan lives inside the shard body, so
score/calibrate/select stay shard-local and only top-k payloads cross
the node axis.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import IDKDConfig
from repro.core import distill, ood
from repro.core.topology import Topology
from repro.kernels.head_select import (NEG_INF, head_select, head_select_ref,
                                       head_select_stats_ref,
                                       merge_head_stats)
from repro.kernels.msp_select import msp_select, msp_select_ref

BACKENDS = ("dense", "fused", "sparse")
DEFAULT_TOPK = 8


class HomogenizedSet(NamedTuple):
    """Per-node distilled public subset, dense labels (node-stacked)."""
    labels: jax.Array        # (n, P[, S], C) averaged soft labels
    weights: jax.Array       # (n, P) 1.0 where sample is in node's D_ID∪neigh
    id_masks: jax.Array      # (n, P) the node's own D_ID mask (diagnostics)
    thresholds: jax.Array    # (n,) calibrated t_opt per node


class SparseHomogenizedSet(NamedTuple):
    """Per-node distilled public subset with top-k sparse labels.

    ``labels.values/indices`` have shape (n, P[, S], k_out) where
    k_out = (max_degree + 1) · k; duplicate indices are legal (every
    consumer — ``sparse_kd_loss``, ``densify_labels``, the histogram
    diagnostics — accumulates them).
    """
    labels: distill.SparseLabels
    weights: jax.Array       # (n, P)
    id_masks: jax.Array      # (n, P)
    thresholds: jax.Array    # (n,)

    def densify(self, num_classes: int) -> jax.Array:
        """Materialize (n, P[, S], C) labels — diagnostics/tests ONLY;
        production paths keep the payload sparse end to end."""
        return distill.densify_labels(self.labels, num_classes)


HomogenizedResult = Union[HomogenizedSet, SparseHomogenizedSet]


def detector_scores(logits, detector: str) -> jax.Array:
    """Per-sample detector confidence. (n, P, C) -> (n, P); LM logit
    stacks (n, P, S, V) reduce to sequence scores by the mean over S of
    the per-token score (matches ``ood.sequence_confidence`` for MSP)."""
    conf = ood.confidence(logits, detector)
    if conf.ndim == 3:
        conf = conf.mean(-1)
    return conf


def calibrate(conf_val, conf_cal) -> jax.Array:
    """Per-node ROC thresholds (line 6): val = ID class, cal = OoD."""
    return jax.vmap(ood.calibrate_threshold)(conf_val, conf_cal)


# --------------------------------------------------------------- exchange
def exchange_dense(topology: Topology, id_mask, labels
                   ) -> Tuple[jax.Array, jax.Array]:
    """Lines 9–14, dense labels: per-sample mean over the contributing
    nodes (self + neighbours whose D_ID contains the sample).

    Implemented as a scan over padded neighbour slots with a gathered
    running mean — O(Σ deg · P · C) work, O(n · P · C) memory.
    """
    nbr, valid = topology.neighbor_arrays()
    nbr = jnp.asarray(nbr)
    valid = jnp.asarray(valid)
    lf = labels.astype(jnp.float32)
    m = id_mask.astype(jnp.float32)                        # (n, P)
    extra = lf.ndim - m.ndim                               # trailing axes

    def body(carry, slot):
        num, cnt = carry
        j, ok = slot                                       # (n,), (n,)
        w = m[j] * ok[:, None]                             # (n, P)
        num = num + w.reshape(w.shape + (1,) * extra) * lf[j]
        cnt = cnt + w
        return (num, cnt), None

    init = (jnp.zeros_like(lf), jnp.zeros_like(m))
    (num, cnt), _ = jax.lax.scan(body, init, (nbr.T, valid.T))
    avg = num / jnp.maximum(cnt, 1.0).reshape(cnt.shape + (1,) * extra)
    return avg, (cnt > 0).astype(jnp.float32)


def exchange_sparse(topology: Topology, id_mask, sparse: distill.SparseLabels
                    ) -> Tuple[distill.SparseLabels, jax.Array]:
    """Lines 9–14 on top-k sparse payloads, without densifying.

    The mean over contributors ``Σ_j m_j · dense(s_j) / cnt`` distributes
    over the scatter, so it equals the *concatenation* of the
    contributors' (values · m_j / cnt, indices) pairs along the k axis.
    Output k_out = (max_degree + 1) · k with zero-valued padding slots;
    O(Σ deg · P · k) work and bytes.
    """
    nbr, valid = topology.neighbor_arrays()
    nbr = jnp.asarray(nbr)
    valid = jnp.asarray(valid)
    m = id_mask.astype(jnp.float32)
    w = m[nbr] * valid[:, :, None]                         # (n, D, P)
    cnt = jnp.sum(w, axis=1)                               # (n, P)
    share = w / jnp.maximum(cnt, 1.0)[:, None, :]
    vals = sparse.values[nbr]                              # (n, D, P[, S], k)
    idx = sparse.indices[nbr]
    extra = vals.ndim - share.ndim                         # e.g. the S axis
    vals = vals * share.reshape(share.shape + (1,) * extra)
    # merge the contributor axis into k: (n, P[, S], D·k)
    vals = jnp.moveaxis(vals, 1, -2)
    idx = jnp.moveaxis(idx, 1, -2)
    vals = vals.reshape(vals.shape[:-2] + (-1,))
    idx = idx.reshape(idx.shape[:-2] + (-1,))
    return (distill.SparseLabels(vals.astype(jnp.float32),
                                 idx.astype(jnp.int32)),
            (cnt > 0).astype(jnp.float32))


# ------------------------------------------------------------ fused pass
_fused_oracle = jax.jit(
    msp_select_ref, static_argnames=("temperature", "k", "detector"))
_stream_oracle = jax.jit(
    head_select_ref, static_argnames=("temperature", "k", "detector"))


def _fused_pass(logits, cfg: IDKDConfig, k: int
                ) -> Tuple[jax.Array, distill.SparseLabels]:
    """One read of the public logits: detector confidence + top-k payload.

    TPU: the ``msp_select`` Pallas kernel (single HBM pass over the
    (rows, C) logits). Elsewhere: its jnp oracle under jit — same fused
    dataflow, so CPU tests exercise identical math. The D_ID mask is not
    computed here: the threshold is calibrated from these confidences
    downstream, so membership is one caller-owned compare.
    """
    lead, C = logits.shape[:-1], logits.shape[-1]
    flat = logits.reshape(-1, C)
    if jax.default_backend() == "tpu":
        block = cfg.select_block_rows
        pad = (-flat.shape[0]) % block
        n_rows = flat.shape[0]
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0)))
        conf, vals, idx = msp_select(
            flat, temperature=cfg.temperature, k=k, block_n=block,
            detector=cfg.detector)
        conf, vals, idx = conf[:n_rows], vals[:n_rows], idx[:n_rows]
    else:
        conf, vals, idx = _fused_oracle(
            flat, temperature=cfg.temperature, k=k, detector=cfg.detector)
    conf = conf.reshape(lead)
    if conf.ndim == 3:                                     # (n, P, S) tokens
        conf = conf.mean(-1)
    sparse = distill.SparseLabels(vals.reshape(lead + (k,)),
                                  idx.reshape(lead + (k,)))
    return conf, sparse


def _head_pass(model, params_i, x, cfg: IDKDConfig, k: int):
    """One node's fused head-select pass on one input microbatch.

    ``forward_features`` yields the pre-head activations; the head
    matrix is applied *inside* the fused select — the ``head_select``
    Pallas kernel tiles the vocab axis on TPU, its jnp oracle forms only
    a microbatch-sized logit chunk elsewhere. Returns per-sample
    ``(conf, vals, idx)`` with LM token confidences already reduced to
    sequence scores (mean over S).
    """
    feats, _ = model.forward_features(params_i, {model.input_key: x})
    w, b = model.head_params(params_i)
    lead = feats.shape[:-1]                                # (mb,) or (mb, S)
    flat = feats.reshape(-1, feats.shape[-1])
    if jax.default_backend() == "tpu":
        block = cfg.select_block_rows
        pad = (-flat.shape[0]) % block
        n_rows = flat.shape[0]
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0)))
        conf, vals, idx = head_select(
            flat, w, b, temperature=cfg.temperature, k=k,
            block_rows=block, detector=cfg.detector)
        conf, vals, idx = conf[:n_rows], vals[:n_rows], idx[:n_rows]
    else:
        conf, vals, idx = _stream_oracle(
            flat, w, b, temperature=cfg.temperature, k=k,
            detector=cfg.detector)
    conf = conf.reshape(lead)
    if conf.ndim == 2:                                     # (mb, S) tokens
        conf = conf.mean(-1)
    return conf, vals.reshape(lead + (k,)), idx.reshape(lead + (k,))


def _vocab_sharded_head_pass(model, params_i, x, cfg: IDKDConfig, k: int,
                             model_axis: str, model_size: int):
    """:func:`_head_pass` on the 2-D federation mesh (DESIGN.md §10):
    each model-axis shard runs the fused select over its own vocab slice
    — ``O(mb · C / model_size)`` scores, never the full row — and the
    per-shard online-softmax stats ``(m, z)`` + top-k raw logits merge
    across the model axis with the kernel's own cross-tile streaming
    math (``merge_head_stats``). The finalizer (detector confidence,
    temperature renormalization) runs only on the merged stats, so the
    result matches the unsharded pass: indices exactly, conf/vals to
    float tolerance.

    The vocab slice is cut here (pad C to ``model_size`` equal slices;
    padded columns get a ``NEG_INF`` bias so they self-mask out of both
    ``z`` and the top-k) rather than read from the storage sharding, so
    ragged ``C % model_size != 0`` heads and replicated small heads work
    identically. Runs inside ``shard_map`` (under the node-block vmap);
    all collectives are over ``model_axis`` only.
    """
    feats, _ = model.forward_features(params_i, {model.input_key: x})
    w, b = model.head_params(params_i)
    C = w.shape[-1]
    w_sh = -(-C // model_size)
    pad_c = w_sh * model_size - C
    if b is None:
        b = jnp.zeros((C,), jnp.float32)
    if pad_c:
        w = jnp.pad(w, ((0, 0), (0, pad_c)))
        b = jnp.pad(b.astype(jnp.float32), (0, pad_c),
                    constant_values=NEG_INF)
    j = jax.lax.axis_index(model_axis)
    w_loc = jax.lax.dynamic_slice_in_dim(w, j * w_sh, w_sh, axis=1)
    b_loc = jax.lax.dynamic_slice_in_dim(b, j * w_sh, w_sh, axis=0)
    k_loc = min(k, w_sh)
    lead = feats.shape[:-1]                                # (mb,) or (mb, S)
    flat = feats.reshape(-1, feats.shape[-1])
    if jax.default_backend() == "tpu":
        block = cfg.select_block_rows
        pad = (-flat.shape[0]) % block
        n_rows = flat.shape[0]
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0)))
        ms, zs, tv, ti = head_select(
            flat, w_loc, b_loc, temperature=cfg.temperature, k=k_loc,
            block_rows=block, detector=cfg.detector, raw_stats=True)
        ms, zs = ms[:n_rows], zs[:n_rows]
        tv, ti = tv[:n_rows], ti[:n_rows]
    else:
        ms, zs, tv, ti = head_select_stats_ref(flat, w_loc, b_loc, k=k_loc)
    ti = ti + j * w_sh                                     # global vocab idx
    conf, vals, idx = merge_head_stats(
        jax.lax.all_gather(ms, model_axis),
        jax.lax.all_gather(zs, model_axis),
        jax.lax.all_gather(tv, model_axis),
        jax.lax.all_gather(ti, model_axis),
        temperature=cfg.temperature, k=k, detector=cfg.detector)
    conf = conf.reshape(lead)
    if conf.ndim == 2:                                     # (mb, S) tokens
        conf = conf.mean(-1)
    return conf, vals.reshape(lead + (k,)), idx.reshape(lead + (k,))


def _head_width(model, params) -> int:
    """Class/vocab count C from the head shape (no compute — eval_shape
    on one node's param slice)."""
    one = jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t.shape[1:], t.dtype), params)
    return jax.eval_shape(lambda p: model.head_params(p)[0], one).shape[-1]


def _chunk_public(public_x, microbatch: int):
    """(P, ...) -> ((num_chunks, mb, ...), P, mb). The ragged tail is
    padded by repeating row 0 (real inputs, outputs sliced off)."""
    pub = jnp.asarray(public_x)
    P = pub.shape[0]
    mb = max(1, min(microbatch or 256, P))
    num_chunks = -(-P // mb)
    pad = num_chunks * mb - P
    if pad:
        pub = jnp.concatenate(
            [pub, jnp.broadcast_to(pub[:1], (pad,) + pub.shape[1:])])
    return pub.reshape((num_chunks, mb) + pub.shape[1:]), P, mb


def _stream_public(model, params, chunks, P: int, cfg: IDKDConfig, k: int,
                   head_pass=_head_pass):
    """Scan the chunked public set through the fused head pass for a
    (possibly local) block of nodes; accumulate only (conf, vals, idx).
    ``head_pass`` swaps in the vocab-sharded pass on the 2-D mesh.
    """
    L = jax.tree.leaves(params)[0].shape[0]

    def one_chunk(xc):                                     # (mb, ...)
        xb = jnp.broadcast_to(xc[None], (L,) + xc.shape)
        return jax.vmap(
            lambda p, x: head_pass(model, p, x, cfg, k))(params, xb)

    _, (conf, vals, idx) = jax.lax.scan(
        lambda carry, xc: (carry, one_chunk(xc)), None, chunks)
    total = conf.shape[0] * conf.shape[2]                  # chunks · mb
    conf = jnp.moveaxis(conf, 0, 1).reshape(L, total)[:, :P]
    vals = jnp.moveaxis(vals, 0, 1)
    vals = vals.reshape((L, total) + vals.shape[3:])[:, :P]
    idx = jnp.moveaxis(idx, 0, 1)
    idx = idx.reshape((L, total) + idx.shape[3:])[:, :P]
    return conf, distill.SparseLabels(vals, idx)


def _stream_val_conf(model, params, val_x, cfg: IDKDConfig,
                     head_pass=_head_pass):
    """Per-node detector confidence on each node's own (small) val set,
    through the same fused head pass (k=1: only conf is consumed)."""
    return jax.vmap(
        lambda p, x: head_pass(model, p, x, cfg, 1)[0])(
            params, jnp.asarray(val_x))


# ------------------------------------------------------------ full round
def label_round(public_logits, val_logits, cal_logits, topology: Topology,
                cfg: IDKDConfig, *, backend: str = "dense",
                filter_ood: bool = True, active=None) -> HomogenizedResult:
    """One IDKD homogenization round on node-stacked logits.

    public_logits: (n, P, C) or (n, P, S, V) — each node on the public set
    val_logits:    (n, V, C) / (n, V, S, Vv) — each node on its private ID set
    cal_logits:    (n, K, C) / ... — each node on the OoD calibration set,
                   or None for D_C = D_P (the paper's default; the public
                   scores are reused instead of re-read — pass None rather
                   than public_logits under jit, where the identity check
                   cannot see through tracers)
    filter_ood:    False = the ``kd_mode="vanilla"`` baseline (no detector:
                   every public sample is kept, thresholds are 0)
    active:        optional (n,) availability mask (scheduler churn): a
                   down node contributes no D_ID labels to the exchange
                   and receives none (its weights come back all-zero), so
                   repeated rounds under churn only ever move labels
                   between live nodes

    Returns :class:`HomogenizedSet` (dense backend) or
    :class:`SparseHomogenizedSet` (fused / sparse backends).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown labeling backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    n = public_logits.shape[0]
    k = min(cfg.label_topk or DEFAULT_TOPK, public_logits.shape[-1])

    sparse = None
    if backend == "fused":
        conf_pub, sparse = _fused_pass(public_logits, cfg, k)
    else:
        conf_pub = detector_scores(public_logits, cfg.detector)

    if filter_ood:
        # D_C = D_P (None or the same array): reuse the public scores
        # instead of re-reading the (n, P, C) logits a second time
        conf_cal = (conf_pub
                    if cal_logits is None or cal_logits is public_logits
                    else detector_scores(cal_logits, cfg.detector))
        thresholds = calibrate(detector_scores(val_logits, cfg.detector),
                               conf_cal)
        id_mask = conf_pub > thresholds[:, None]
    else:
        thresholds = jnp.zeros((n,), jnp.float32)
        id_mask = jnp.ones(conf_pub.shape, bool)
    if active is not None:
        act = jnp.asarray(active, bool)
        id_mask = id_mask & act[:, None]

    if backend == "dense":
        labels = distill.soft_labels(public_logits, cfg.temperature)
        avg, weights = exchange_dense(topology, id_mask, labels)
        if active is not None:
            weights = weights * act[:, None]
        return HomogenizedSet(avg, weights, id_mask, thresholds)

    if sparse is None:                                     # backend == sparse
        probs = distill.soft_labels(public_logits, cfg.temperature)
        sparse = distill.sparsify_labels(probs, k)
    merged, weights = exchange_sparse(topology, id_mask, sparse)
    if active is not None:
        weights = weights * act[:, None]
    return SparseHomogenizedSet(merged, weights, id_mask, thresholds)


# ---------------------------------------------------------- streaming round
def streaming_label_round(model, params, public_x, val_x,
                          topology: Topology, cfg: IDKDConfig, *,
                          filter_ood: bool = True, active=None
                          ) -> SparseHomogenizedSet:
    """One IDKD homogenization round without ever materializing the
    public logit stack (DESIGN.md §8).

    Instead of node-stacked logits this takes the *model* (via its
    ``forward_features`` / ``head_params`` hooks) and node-stacked
    ``params``, and streams the shared public set through every node in
    microbatches of ``cfg.stream_microbatch``: one ``lax.scan`` whose
    body runs the per-node forward to pre-head activations and the
    fused head-select pass (``kernels/head_select`` on TPU, its jnp
    oracle elsewhere), accumulating only ``(conf, top-k values, top-k
    indices)``. Peak memory is O(n · microbatch · C) for the in-flight
    chunk plus O(n · P · k) for the accumulated payload — the
    O(n · P · C) tensor of :func:`label_round` never exists, which is
    what lets the public corpus scale past device memory.

    ``public_x``: (P, ...) shared public inputs (images or tokens);
    ``val_x``:    (n, V, ...) each node's own private ID inputs;
    D_C = D_P (the paper's default): the public confidences double as
    the OoD calibration scores. Numerically this is the fused backend
    of :func:`label_round` to float tolerance (online-softmax detector
    stats, blockwise top-k merge), and it always produces sparse top-k
    labels — the wire format the streaming path exists to preserve.
    ``filter_ood`` / ``active`` behave exactly as in
    :func:`label_round`.
    """
    n = jax.tree.leaves(params)[0].shape[0]
    if topology.n != n:
        raise ValueError(f"param stack has {n} nodes, topology "
                         f"{topology.name!r} has {topology.n}")
    C = _head_width(model, params)
    k = min(cfg.label_topk or DEFAULT_TOPK, C)
    chunks, P, _ = _chunk_public(public_x, cfg.stream_microbatch)
    conf_pub, sparse = _stream_public(model, params, chunks, P, cfg, k)

    if filter_ood:
        conf_val = _stream_val_conf(model, params, val_x, cfg)
        thresholds = calibrate(conf_val, conf_pub)
        id_mask = conf_pub > thresholds[:, None]
    else:
        thresholds = jnp.zeros((n,), jnp.float32)
        id_mask = jnp.ones(conf_pub.shape, bool)
    if active is not None:
        act = jnp.asarray(active, bool)
        id_mask = id_mask & act[:, None]
    merged, weights = exchange_sparse(topology, id_mask, sparse)
    if active is not None:
        weights = weights * act[:, None]
    return SparseHomogenizedSet(merged, weights, id_mask, thresholds)


# ------------------------------------------------------------ sharded round
def _shard_layout(topology: Topology, n: int, mesh, axis: str):
    """Shared shard-round validation: node-count divisibility and the
    ring/complete support set. Returns (size, ring, full)."""
    from repro.core import mixing

    if topology.n != n:
        raise ValueError(f"node stack has {n} nodes, topology "
                         f"{topology.name!r} has {topology.n}")
    size = mesh.shape[axis]
    if n % size != 0:
        raise ValueError(f"node count ({n}) not divisible by the mesh "
                         f"{axis!r} axis ({size})")
    ring = mixing._is_ring(topology)
    full = mixing._is_full(topology)
    if not (ring or full):
        raise ValueError(
            f"sharded label exchange supports ring/complete graphs; "
            f"topology {topology.name!r} must use the node-stacked "
            "labeling.label_round (backend='sparse')")
    return size, ring, full


def _merge_payloads(parts_v, parts_i, parts_m):
    """Mean over contributors distributes over the scatter: concat
    contributor payloads along k with m_j/cnt weights (DESIGN.md §2)."""
    cnt = sum(parts_m)                                      # (L, P)
    share = [m / jnp.maximum(cnt, 1.0) for m in parts_m]
    extra = parts_v[0].ndim - cnt.ndim                      # e.g. the S axis
    vals = jnp.concatenate(
        [v * s.reshape(s.shape + (1,) * extra)
         for v, s in zip(parts_v, share)], axis=-1)
    idx = jnp.concatenate(parts_i, axis=-1)
    return (vals.astype(jnp.float32), idx.astype(jnp.int32),
            (cnt > 0).astype(jnp.float32))


def _shard_exchange(sp: distill.SparseLabels, m, *, axis: str, size: int,
                    n: int, ring: bool, full: bool):
    """The label exchange across the mesh node axis (inside shard_map):
    only the top-k payload (values, indices, D_ID mask) moves — ring
    neighbours swap boundary rows via ``lax.ppermute``
    (``mixing.block_ring_shift``), complete graphs ``all_gather``."""
    from repro.core import mixing

    if full and not (ring and n <= 3):
        vals_all = jax.lax.all_gather(sp.values, axis, axis=0,
                                      tiled=True)           # (n, P[, S], k)
        idx_all = jax.lax.all_gather(sp.indices, axis, axis=0, tiled=True)
        m_all = jax.lax.all_gather(m, axis, axis=0, tiled=True)
        # contributor axis consumed by _merge_payloads → (P[, S], n·k);
        # on the complete graph every node merges the same contributor
        # set, so the result broadcasts over local nodes
        vals, idx, w = _merge_payloads(list(vals_all), list(idx_all),
                                       list(m_all))
        L = m.shape[0]
        vals = jnp.broadcast_to(vals[None], (L,) + vals.shape)
        idx = jnp.broadcast_to(idx[None], (L,) + idx.shape)
        w = jnp.broadcast_to(w[None], (L,) + w.shape)
        return vals, idx, w
    if n == 1:
        return _merge_payloads([sp.values], [sp.indices], [m])

    def shifted(t, s):
        return mixing.block_ring_shift(t, axis, size, s)
    parts_v = [sp.values, shifted(sp.values, 1)]
    parts_i = [sp.indices, shifted(sp.indices, 1)]
    parts_m = [m, shifted(m, 1)]
    if n > 2:
        parts_v.append(shifted(sp.values, -1))
        parts_i.append(shifted(sp.indices, -1))
        parts_m.append(shifted(m, -1))
    return _merge_payloads(parts_v, parts_i, parts_m)


def shard_label_round(public_logits, val_logits, topology: Topology,
                      cfg: IDKDConfig, *, mesh, axis: str = "node",
                      filter_ood: bool = True) -> SparseHomogenizedSet:
    """One IDKD homogenization round under ``shard_map`` over the mesh
    node axis — the ``driver_mode="shard"`` twin of :func:`label_round`
    (DESIGN.md §7).

    Score, calibrate, and select run *shard-local*: each device computes
    detector confidences, ROC thresholds, D_ID masks, and the top-k
    sparse payload for its own block of nodes with zero communication.
    Only the label exchange crosses the node axis, and it moves nothing
    but top-k payloads: ring neighbours swap ``(values, indices, mask)``
    via boundary-row ``lax.ppermute`` (complete graphs ``all_gather``
    them), never the ``(P, C)`` dense labels. The merged payload equals
    the node-stacked sparse backend's up to a permutation along the k
    axis (contributor order is self/prev/next instead of
    self/sorted-neighbours) — every consumer accumulates duplicate
    indices, so the trained trajectories agree to float tolerance and
    the per-node payload bytes match exactly (``tests/test_shard.py``).

    Always produces sparse top-k labels (the dense backend has no
    sharded path — its wire format is the thing shard mode exists to
    avoid); churn masks are unsupported, like the rest of shard mode.
    Topologies other than rings / complete graphs raise eagerly — run
    those rounds through the node-stacked :func:`label_round`.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = public_logits.shape[0]
    size, ring, full = _shard_layout(topology, n, mesh, axis)
    k = min(cfg.label_topk or DEFAULT_TOPK, public_logits.shape[-1])
    spec = P(axis)

    def body(pub, val):
        # ---- score / calibrate / select: shard-local, zero comm
        conf_pub = detector_scores(pub, cfg.detector)
        if filter_ood:
            thresholds = calibrate(detector_scores(val, cfg.detector),
                                   conf_pub)
            id_mask = conf_pub > thresholds[:, None]
        else:
            thresholds = jnp.zeros((pub.shape[0],), jnp.float32)
            id_mask = jnp.ones(conf_pub.shape, bool)
        sp = distill.sparsify_labels(
            distill.soft_labels(pub, cfg.temperature), k)
        m = id_mask.astype(jnp.float32)
        # ---- exchange: only the top-k payload crosses the node axis
        vals, idx, w = _shard_exchange(sp, m, axis=axis, size=size, n=n,
                                       ring=ring, full=full)
        return vals, idx, w, id_mask, thresholds

    vals, idx, w, id_mask, thresholds = shard_map(
        body, mesh=mesh, in_specs=(spec, spec),
        out_specs=(spec, spec, spec, spec, spec), check_rep=False)(
            public_logits, val_logits)
    return SparseHomogenizedSet(distill.SparseLabels(vals, idx), w,
                                id_mask, thresholds)


def shard_streaming_label_round(model, params, public_x, val_x,
                                topology: Topology, cfg: IDKDConfig, *,
                                mesh, axis: str = "node",
                                filter_ood: bool = True
                                ) -> SparseHomogenizedSet:
    """:func:`streaming_label_round` under ``shard_map`` over the mesh
    node axis — the streaming twin of :func:`shard_label_round`.

    The public-set scan lives *inside* the shard_map body: each device
    streams the (replicated) public microbatches through its own block
    of nodes' models — forward to pre-head activations, fused
    head-select per chunk — and calibrates thresholds shard-local, so
    score/select cost zero communication and no device ever holds more
    than O(local_nodes · microbatch · C) of logits. Exactly as in
    :func:`shard_label_round`, only the top-k payload crosses the node
    axis (boundary-row ppermutes on rings, all_gather on complete
    graphs); churn masks remain unsupported in shard mode.

    On a 2-D ``("node", "model")`` federation mesh (``launch.mesh.
    make_federation_mesh``) the params arrive model-sharded
    (``launch.sharding.federation_specs``): the body all-gathers the
    weight leaves over the model axis for ``forward_features`` and runs
    the **vocab-sharded** head pass (:func:`_vocab_sharded_head_pass`) —
    each model shard scores only its own vocab slice and the stats merge
    across the model axis with the kernel's streaming math. The label
    exchange still moves top-k payloads over the node axis only, so
    label wire bytes are unchanged by model parallelism (DESIGN.md §10).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import federation_specs, gather_model_tree

    n = jax.tree.leaves(params)[0].shape[0]
    size, ring, full = _shard_layout(topology, n, mesh, axis)
    model_axis = "model"
    model_size = dict(mesh.shape).get(model_axis, 1)
    C = _head_width(model, params)
    k = min(cfg.label_topk or DEFAULT_TOPK, C)
    chunks, P_pub, _ = _chunk_public(public_x, cfg.stream_microbatch)
    val_x = jnp.asarray(val_x)
    spec = P(axis)
    p_specs = federation_specs(params, n, mesh, axis)
    if model_size > 1:
        def head_pass(model, p, x, cfg, k):
            return _vocab_sharded_head_pass(model, p, x, cfg, k,
                                            model_axis, model_size)
    else:
        head_pass = _head_pass

    def body(p_local, chunks_rep, val_local):
        if model_size > 1:
            p_local = gather_model_tree(p_local, p_specs, model_axis)
        # ---- stream / score / calibrate / select: shard-local
        conf_pub, sp = _stream_public(model, p_local, chunks_rep, P_pub,
                                      cfg, k, head_pass)
        if filter_ood:
            thresholds = calibrate(
                _stream_val_conf(model, p_local, val_local, cfg, head_pass),
                conf_pub)
            id_mask = conf_pub > thresholds[:, None]
        else:
            thresholds = jnp.zeros((conf_pub.shape[0],), jnp.float32)
            id_mask = jnp.ones(conf_pub.shape, bool)
        m = id_mask.astype(jnp.float32)
        # ---- exchange: only the top-k payload crosses the node axis
        vals, idx, w = _shard_exchange(sp, m, axis=axis, size=size, n=n,
                                       ring=ring, full=full)
        return vals, idx, w, id_mask, thresholds

    vals, idx, w, id_mask, thresholds = shard_map(
        body, mesh=mesh,
        in_specs=(p_specs, P(), spec),
        out_specs=(spec, spec, spec, spec, spec), check_rep=False)(
            params, chunks, val_x)
    return SparseHomogenizedSet(distill.SparseLabels(vals, idx), w,
                                id_mask, thresholds)


def neighbor_topk_overlap(indices, topology: Topology):
    """Telemetry diagnostic: how much of each node's top-k label index
    set its graph neighbours share.

    ``indices`` is the sparse payload's index tensor, shape
    (n, P[, S], k) — each node's selected class/token ids per public
    sample. For every undirected edge (i, j) the overlap is the
    fraction of node i's entries that also appear in node j's set for
    the same sample, averaged over samples (symmetric because both
    sets have the same width k). Returns ``(mean, per_edge)`` where
    ``per_edge`` maps ``"i-j"`` -> overlap fraction; mean is 0.0 on an
    edgeless graph. Host-side numpy — runs once per homogenization
    round, never inside jit.
    """
    import numpy as np

    idx = np.asarray(indices)
    n = idx.shape[0]
    flat = idx.reshape(n, -1, idx.shape[-1])            # (n, M, k)
    per_edge = {}
    for i in range(n):
        for j in topology.neighbors(i):
            if j <= i:
                continue
            a, b = flat[i], flat[j]                      # (M, k) each
            hit = (a[:, :, None] == b[:, None, :]).any(-1)
            per_edge[f"{i}-{j}"] = float(hit.mean())
    mean = float(np.mean(list(per_edge.values()))) if per_edge else 0.0
    return mean, per_edge
