"""Decentralized optimization algorithms (paper §2/§4 baselines + base).

Every algorithm is expressed against an abstract ``mix(pytree) -> pytree``
gossip operator, so the same code runs in both backends:

* simulation — node-stacked params + dense mixing matrix (CPU experiments);
* production — per-node params inside ``shard_map`` + ppermute mixing.

Implemented:
  * ``centralized``  — SGD with exact global averaging (paper's upper bound)
  * ``dsgd``         — Lian et al. 2017, x ← W x − η g
  * ``dsgdm``        — DSGD + local heavy-ball momentum
  * ``qg-dsgdm-n``   — Lin et al. 2021 quasi-global momentum w/ normalized
                       gradients (the paper's base optimizer)
  * ``d2``           — Tang et al. 2018 bias-corrected D²
  * ``relaysgd``     — Vogels et al. 2021 RelaySum/Model (sim backend only;
                       requires per-edge relay state on a tree topology)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology

PyTree = Any
Mixer = Callable[[PyTree], PyTree]


def tree_axpy(a, x, y):
    """a*x + y elementwise over pytrees (f32 accumulate, cast back)."""
    return jax.tree.map(
        lambda xi, yi: (a * xi.astype(jnp.float32)
                        + yi.astype(jnp.float32)).astype(yi.dtype), x, y)


def tree_scale(a, x):
    return jax.tree.map(lambda xi: (a * xi.astype(jnp.float32)).astype(xi.dtype), x)


def tree_sub(x, y):
    return jax.tree.map(lambda a, b: a - b, x, y)


def tree_zeros_like(x):
    return jax.tree.map(jnp.zeros_like, x)


def global_grad_norm(grads) -> jax.Array:
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)]
    return jnp.sqrt(sum(leaves))


def _apply_weight_decay(params, grads, wd: float):
    if not wd:
        return grads
    return jax.tree.map(
        lambda g, p: g + wd * p.astype(g.dtype), grads, params)


@dataclass
class Algorithm:
    """init(params) -> state; step(params, grads, state, lr, mix) -> ..."""
    name: str
    init: Callable[[PyTree], PyTree]
    step: Callable[..., Any]
    needs_topology: bool = False


# ---------------------------------------------------------------------------
# centralized SGD (upper-bound reference; exact averaging every step)
# ---------------------------------------------------------------------------


def make_centralized(momentum: float = 0.9, weight_decay: float = 0.0,
                     nesterov: bool = True) -> Algorithm:
    def init(params):
        return {"m": tree_zeros_like(params)}

    def step(params, grads, state, lr, mix: Mixer):
        grads = mix(grads)  # exact average when mix is full averaging
        grads = _apply_weight_decay(params, grads, weight_decay)
        m = tree_axpy(momentum, state["m"], grads)
        upd = tree_axpy(momentum, m, grads) if nesterov else m
        new_params = tree_axpy(-lr, upd, params)
        return new_params, {"m": m}

    return Algorithm("centralized", init, step)


# ---------------------------------------------------------------------------
# DSGD / DSGDm (Lian et al. 2017; Assran et al. 2019)
# ---------------------------------------------------------------------------


def make_dsgd(momentum: float = 0.0, weight_decay: float = 0.0) -> Algorithm:
    def init(params):
        return {"m": tree_zeros_like(params)} if momentum else {}

    def step(params, grads, state, lr, mix: Mixer):
        grads = _apply_weight_decay(params, grads, weight_decay)
        if momentum:
            m = tree_axpy(momentum, state["m"], grads)
            state = {"m": m}
            upd = m
        else:
            upd = grads
        mixed = mix(params)
        new_params = tree_axpy(-lr, upd, mixed)
        return new_params, state

    return Algorithm("dsgd" if not momentum else "dsgdm", init, step)


# ---------------------------------------------------------------------------
# QG-DSGDm-N (Lin et al. 2021) — the paper's base optimizer
# ---------------------------------------------------------------------------


def make_qg_dsgdm_n(momentum: float = 0.9, weight_decay: float = 1e-4,
                    normalize: bool = True, eps: float = 1e-8) -> Algorithm:
    """Quasi-global momentum: the momentum buffer tracks the *global*
    descent direction d_t = (x_t − x_{t+1})/η — which includes the gossip
    displacement — instead of the biased local gradient. With ``normalize``
    the local stochastic gradient is L2-normalized (the “-N” variant),
    making the local step scale-free under heterogeneous gradients.

    The step is *fused*: the grad-norm reduction (weight decay folded
    in), then — when the mixer exposes the per-leaf protocol
    (``mix.mix_leaf``, which every ``core.mixing`` backend does) — one
    single whole-tree pass computing the momentum half-step
    x − η(βm + ĝ), the gossip mix, and the displacement-EMA momentum
    update per leaf. That is two tree traversals per step, down from the
    four of the mix-as-a-separate-pass form (and ~9 in the original
    unfused sequence: wd, norm, scale, two axpys, mix, sub, scale, EMA),
    which on CPU dominated the step with hundreds of tiny thunks at
    small scale (ROADMAP thunk-floor item; measured in bench_driver).
    The per-leaf op sequence is unchanged, so the fused pass is
    bitwise-equal to mix-then-update
    (``test_qgm_leaf_fused_mix_bitwise_equals_mix_then_update``); mixers
    without ``mix_leaf`` fall back to the 4-pass form.
    """
    def init(params):
        return {"m": tree_zeros_like(params)}

    def step(params, grads, state, lr, mix: Mixer):
        wd = weight_decay
        if normalize:
            sq = jax.tree.map(
                lambda g, p: jnp.sum((g.astype(jnp.float32)
                                      + wd * p.astype(jnp.float32)) ** 2)
                if wd else jnp.sum(g.astype(jnp.float32) ** 2),
                grads, params)
            # the norm spans the whole node-stacked tree; under shard_map
            # (mix.axis_name set) the node axis is a mesh axis, so the
            # local-block sum completes across devices via psum — keeps
            # sharded trajectories equal to the node-stacked runner's.
            # On the 2-D federation mesh the reduction is leaf-dependent
            # (model-sharded leaves also reduce over "model"; replicated
            # leaves must not be double-counted), so a mixer may supply
            # the whole reduction as reduce_tree_sum.
            reduce = getattr(mix, "reduce_tree_sum", None)
            if reduce is not None:
                total = reduce(sq)
            else:
                total = sum(jax.tree.leaves(sq))
                axis = getattr(mix, "axis_name", None)
                if axis is not None:
                    total = jax.lax.psum(total, axis)
            scale = 1.0 / (jnp.sqrt(total) + eps)
        else:
            scale = 1.0

        def half_leaf(p, g, m):
            gf = g.astype(jnp.float32)
            if wd:
                gf = gf + wd * p.astype(jnp.float32)
            gf = scale * gf
            upd = momentum * m.astype(jnp.float32) + gf
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        inv_lr = 1.0 / lr

        def m_leaf(m, p, y):
            d = (p.astype(jnp.float32) - y.astype(jnp.float32)) * inv_lr
            return (momentum * m.astype(jnp.float32)
                    + (1 - momentum) * d).astype(m.dtype)

        mix_leaf = getattr(mix, "mix_leaf", None)
        if mix_leaf is None:
            # opaque mixer: half-step map, whole-tree mix, EMA map
            half = jax.tree.map(half_leaf, params, grads, state["m"])
            new_params = mix(half)
            new_m = jax.tree.map(m_leaf, state["m"], params, new_params)
            return new_params, {"m": new_m}

        # per-leaf mixer protocol: half-step + mix + displacement EMA in
        # one traversal (same per-leaf op sequence → bitwise-equal)
        def fused_leaf(p, g, m):
            y = mix_leaf(half_leaf(p, g, m))
            return y, m_leaf(m, p, y)

        pairs = jax.tree.map(fused_leaf, params, grads, state["m"])
        new_params, new_m = jax.tree.transpose(
            jax.tree.structure(params), jax.tree.structure((0, 0)), pairs)
        return new_params, {"m": new_m}

    return Algorithm("qg-dsgdm-n", init, step)


# ---------------------------------------------------------------------------
# D² (Tang et al. 2018)
# ---------------------------------------------------------------------------


def make_d2(weight_decay: float = 0.0) -> Algorithm:
    """x_{t+1} = W(2 x_t − x_{t−1} − η(g_t − g_{t−1})) — removes the
    data-heterogeneity bias term from DSGD's fixed point."""
    def init(params):
        return {"prev_x": params, "prev_g": tree_zeros_like(params),
                "t": jnp.zeros((), jnp.int32)}

    def step(params, grads, state, lr, mix: Mixer):
        grads = _apply_weight_decay(params, grads, weight_decay)
        first = (state["t"] == 0)

        def combine(x, px, g, pg):
            xf, pxf = x.astype(jnp.float32), px.astype(jnp.float32)
            gf, pgf = g.astype(jnp.float32), pg.astype(jnp.float32)
            base = jnp.where(first, xf - lr * gf,
                             2.0 * xf - pxf - lr * (gf - pgf))
            return base.astype(x.dtype)

        half = jax.tree.map(combine, params, state["prev_x"], grads,
                            state["prev_g"])
        new_params = mix(half)
        return new_params, {"prev_x": params, "prev_g": grads,
                            "t": state["t"] + 1}

    return Algorithm("d2", init, step)


# ---------------------------------------------------------------------------
# Gradient Tracking (Koloskova et al. 2021) — another non-IID baseline
# ---------------------------------------------------------------------------


def make_gradient_tracking(weight_decay: float = 0.0) -> Algorithm:
    """GT-DSGD: maintain a tracker y_i of the *global* gradient:

        x⁺ = W(x − η y)
        y⁺ = W(y) + g⁺ − g

    The tracker converges to the node-average gradient, removing DSGD's
    heterogeneity bias (the same goal as D², via consensus on gradients)."""
    def init(params):
        return {"y": tree_zeros_like(params),
                "prev_g": tree_zeros_like(params),
                "t": jnp.zeros((), jnp.int32)}

    def step(params, grads, state, lr, mix: Mixer):
        grads = _apply_weight_decay(params, grads, weight_decay)
        first = state["t"] == 0
        # y_t: on the first step the tracker is the local gradient
        y = jax.tree.map(
            lambda yi, g, pg: jnp.where(first, g,
                                        yi + g - pg), state["y"], grads,
            state["prev_g"])
        y = mix(y)
        half = tree_axpy(-lr, y, params)
        new_params = mix(half)
        return new_params, {"y": y, "prev_g": grads, "t": state["t"] + 1}

    return Algorithm("gradient-tracking", init, step)


# ---------------------------------------------------------------------------
# RelaySGD (Vogels et al. 2021) — sim backend, tree topologies
# ---------------------------------------------------------------------------


def make_relaysgd(topology: Topology, momentum: float = 0.9,
                  weight_decay: float = 5e-4) -> Algorithm:
    """RelaySum/Model: spanning-tree message relaying gives every node the
    *exact* (delayed) average of all models — no mixing-matrix variance.
    State carries per-directed-edge relay messages; requires a tree
    (the paper runs it on a chain)."""
    if not topology.is_tree():
        raise ValueError("RelaySGD requires a tree topology (e.g. chain)")
    n = topology.n
    adj = np.zeros((n, n), bool)
    for i in range(n):
        for j in topology.neighbors(i):
            adj[i, j] = True
    adj_j = jnp.asarray(adj)

    def init(params):
        # msg leaf: (n_src, n_dst, ...) — msg[i, j] = m_{i->j}, edges only
        def zeros_edge(x):
            return jnp.zeros((n,) + x.shape, jnp.float32)  # x: (n, ...)
        return {"msg": jax.tree.map(zeros_edge, params),
                "cnt": jnp.zeros((n, n), jnp.float32),
                "m": tree_zeros_like(params)}

    def _incoming(msg_leaf):
        """inc[i] = Σ_k adj[k, i] · msg[k, i]."""
        return jnp.einsum("ki...,ki->i...", msg_leaf,
                          adj_j.astype(msg_leaf.dtype))

    def step(params, grads, state, lr, mix: Mixer = None):
        grads = _apply_weight_decay(params, grads, weight_decay)
        m = tree_axpy(momentum, state["m"], grads)
        xhat = tree_axpy(-lr, m, params)            # (n, ...)

        def relay(msg_leaf, xh):
            # msg'_{i->j} = xhat_i + Σ_{k∈N(i)\{j}} msg_{k->i}
            inc = _incoming(msg_leaf)                               # (n, ...)
            msg_T = jnp.swapaxes(msg_leaf, 0, 1)                    # [i,j]=m_{j->i}
            new = (xh.astype(jnp.float32)[:, None] + inc[:, None] - msg_T)
            mask = adj_j.reshape((n, n) + (1,) * (msg_leaf.ndim - 2))
            return jnp.where(mask, new, 0.0)

        new_msg = jax.tree.map(relay, state["msg"], xhat)

        cnt = state["cnt"]
        inc_cnt = jnp.einsum("ki,ki->i", cnt, adj_j.astype(cnt.dtype))
        new_cnt = jnp.where(adj_j, 1.0 + inc_cnt[:, None] - cnt.T, 0.0)

        total_cnt = 1.0 + jnp.einsum("ki,ki->i", new_cnt,
                                     adj_j.astype(new_cnt.dtype))   # (n,)

        def combine(xh, msg_leaf):
            inc = _incoming(msg_leaf)
            shape = (n,) + (1,) * (xh.ndim - 1)
            return ((xh.astype(jnp.float32) + inc)
                    / total_cnt.reshape(shape)).astype(xh.dtype)

        new_params = jax.tree.map(combine, xhat, new_msg)
        return new_params, {"msg": new_msg, "cnt": new_cnt, "m": m}

    return Algorithm("relaysgd", init, step, needs_topology=True)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def make_algorithm(name: str, *, topology: Optional[Topology] = None,
                   momentum: float = 0.9, weight_decay: float = 1e-4
                   ) -> Algorithm:
    name = name.lower()
    if name == "centralized":
        return make_centralized(momentum, weight_decay)
    if name == "dsgd":
        return make_dsgd(0.0, weight_decay)
    if name == "dsgdm":
        return make_dsgd(momentum, weight_decay)
    if name in ("qg-dsgdm-n", "qgm"):
        return make_qg_dsgdm_n(momentum, weight_decay)
    if name == "d2":
        return make_d2(weight_decay)
    if name in ("gradient-tracking", "gt"):
        return make_gradient_tracking(weight_decay)
    if name == "relaysgd":
        if topology is None:
            raise ValueError("relaysgd needs a topology")
        return make_relaysgd(topology, momentum, weight_decay)
    raise ValueError(f"unknown algorithm {name!r}")
