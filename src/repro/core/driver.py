"""One on-device decentralized training driver.

Both consumers of the gossip step loop — the CPU accuracy simulator
(``core.simulator.DecentralizedSimulator``) and the LM launch path
(``launch.train.run_training``) — run on this engine instead of private
Python loops. Three pieces compose:

**Loss adapters + step factory.** :func:`make_step` builds the one
decentralized train step — per-node ``value_and_grad`` via ``vmap`` on
node-stacked params, then ``algo.step`` with an abstract gossip mixer —
parameterized by a *loss adapter* ``adapter(model) -> node_loss(params,
batch)``. Adapters exist for hard-CE classification, dense-KD, sparse-KD,
LM next-token, and LM next-token + sparse-KD; they are the only per-task
code. (The seed tree had five near-duplicate jitted step builders; they
are gone.)

**On-device sampling.** Per-node batch sampling runs under ``jit`` via
``jax.random`` over padded partition-index arrays (:class:`PaddedParts`,
a jit-friendly port of ``data.pipeline.NodeSampler`` /
``HomogenizedSampler``), and the private/public image-label merge that
the seed did with host-side ``np.where`` happens inside the jitted
sampler. One behavioural delta vs the host samplers: draws are always
with replacement (``jax.random.randint``), where the numpy samplers
switched to without-replacement for large partitions.

**Scan / host runners.** :func:`make_scan_runner` compiles the inner loop
as one ``lax.scan`` over a chunk of steps between eval boundaries — no
per-step Python dispatch or host↔device batch round-trips.
:func:`make_host_runner` drives the *same* jitted step + sampler from a
per-step Python loop; it exists as the dispatch-overhead baseline
(``benchmarks/bench_driver.py``) and the equivalence oracle
(``tests/test_driver.py``): both runners consume identical PRNG key
sequences, so their trajectories match to float tolerance.

**Sharded execution** (``driver_mode="shard"``, DESIGN.md §7).
:func:`make_shard_step` places the node axis on a
``jax.sharding.Mesh`` (``launch.mesh.make_node_mesh``) and runs the
per-node train step inside ``shard_map``: each device holds a
contiguous block of nodes, gossip is the ``ppermute`` mixer backend
(boundary-row collective-permutes on rings, ``psum`` exact averaging on
the complete graph), and the per-step loss is a ``psum`` mean. From the
outside the step has the node-stacked contract — same shapes, same
sampler, same PRNG sequence — so the scan runner drives it unchanged
and trajectories match the node-stacked runners to float tolerance
(``tests/test_shard.py``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import IDKDConfig
from repro.core import distill

PyTree = Any
Batch = Dict[str, jax.Array]
NodeLoss = Callable[[PyTree, Batch], jax.Array]
LossAdapter = Callable[..., NodeLoss]
SampleFn = Callable[[jax.Array, jax.Array], Batch]

RUNNER_MODES = ("scan", "host", "auto", "shard")
NODE_AXIS = "node"


def resolve_runner_mode(mode: str, arch_type: str = "",
                        conv_backend: str = "lax") -> str:
    """``auto`` → the empirically fastest runner for the backend.

    On XLA:CPU, ``lax.conv`` inside ``while`` loops falls off the
    threaded fast path (~5× slower; measured in
    ``benchmarks/bench_driver.py``), so conv models keep the per-step
    host loop there — unless the model opts into the im2col conv path
    (``ModelConfig.conv_backend="im2col"``, plain matmuls with no conv
    pathology), which makes the scan/shard runners viable on CPU.
    Everything else — and every accelerator backend — gets the scan
    driver. ``"shard"`` is never picked automatically; it is an explicit
    opt-in.
    """
    if mode != "auto":
        return mode
    if arch_type == "cnn" and conv_backend != "im2col" \
            and jax.default_backend() == "cpu":
        return "host"
    return "scan"


# --------------------------------------------------------------- adapters
def classification_adapter(model) -> NodeLoss:
    """Weighted soft-CE on (soft or one-hot) labels — the plain phase."""
    def node_loss(params, batch):
        logits, _ = model.forward(params, {"images": batch["images"]})
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.sum(batch["labels"] * logp, axis=-1)
        w = batch["weights"]
        return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    return node_loss


def dense_kd_adapter(temperature: float,
                     kd_weight: float = 1.0) -> LossAdapter:
    """Private rows: hard CE. Public rows: T²-scaled KD loss (the one
    distillation convention, ``distill.kd_loss`` — Hinton's T² factor
    keeps KD gradients comparable to the hard-CE gradients), scaled by
    ``IDKDConfig.kd_weight`` (the LM adapter always honoured it; the
    classification adapters silently dropped it)."""
    def adapter(model) -> NodeLoss:
        def node_loss(params, batch):
            logits, _ = model.forward(params, {"images": batch["images"]})
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            hard_nll = -jnp.sum(batch["labels"] * logp, axis=-1)
            kd = distill.kd_loss(logits, batch["labels"], temperature)
            nll = jnp.where(batch["is_pub"], kd_weight * kd, hard_nll)
            w = batch["weights"]
            return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
        return node_loss
    return adapter


def sparse_kd_adapter(temperature: float,
                      kd_weight: float = 1.0) -> LossAdapter:
    """dense_kd on top-k sparse labels, never densified: private rows
    carry their one-hot as a k=1 sparse label, so hard CE is the T=1
    sparse soft-CE on the same payload."""
    def adapter(model) -> NodeLoss:
        def node_loss(params, batch):
            logits, _ = model.forward(params, {"images": batch["images"]})
            sp = distill.SparseLabels(batch["values"], batch["indices"])
            hard_nll = distill.sparse_kd_loss(logits, sp, 1.0)
            kd = distill.sparse_kd_loss(logits, sp, temperature)
            nll = jnp.where(batch["is_pub"], kd_weight * kd, hard_nll)
            w = batch["weights"]
            return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
        return node_loss
    return adapter


def lm_adapter(model) -> NodeLoss:
    """Next-token LM loss. The whole batch goes to ``model.loss`` —
    frontend keys (VLM images, audio conditioning) ride along."""
    def node_loss(params, batch):
        loss, _ = model.loss(params, batch)
        return loss
    return node_loss


def lm_sparse_kd_adapter(idkd_cfg: IDKDConfig) -> LossAdapter:
    """LM next-token loss + sparse-KD on homogenized public batches.

    The KD term is ``distill.sparse_kd_loss`` — T²-scaled, the same
    convention as the classification adapters (the seed's LM step divided
    the T² back out, so the two drivers disagreed by a factor of T²).
    """
    def adapter(model) -> NodeLoss:
        def node_loss(params, batch):
            base, _ = model.loss(params, batch)
            logits, _ = model.forward(params, {"tokens": batch["pub_tokens"]})
            kd = distill.sparse_kd_loss(
                logits, distill.SparseLabels(batch["pub_vals"],
                                             batch["pub_idx"]),
                idkd_cfg.temperature)
            kd = jnp.sum(kd.mean(-1) * batch["pub_w"]) / \
                jnp.maximum(jnp.sum(batch["pub_w"]), 1.0)
            return base + idkd_cfg.kd_weight * kd
        return node_loss
    return adapter


# ----------------------------------------------------------- step factory
def make_step(model, algo, mixer, loss_adapter,
              telemetry: bool = False, guard=None) -> Callable:
    """The one decentralized train step.

    ``loss_adapter`` is either ``adapter(model) -> node_loss`` directly
    (``classification_adapter``, ``lm_adapter``) or the result of a
    parameterized factory (``dense_kd_adapter(T)`` etc.). Returns
    ``step(params, opt_state, batch, lr) -> (params, opt_state, loss)``
    on node-stacked pytrees, with ``step.init_opt = algo.init``.

    A *stateful* mixer (compressed / delayed / straggler gossip —
    ``mixing.make_mixer(..., compression=..., gossip=..., stale=...)``)
    changes the contract: the step carries the mixer's comm pytree
    (error-feedback residuals + last wire payloads) like the sampler
    ctx — ``step(params, opt_state, batch, lr, comm) -> (params,
    opt_state, loss, comm)``, flagged ``step.comm = True``, with
    ``step.init_comm = mixer.init_state`` building the initial state.

    ``telemetry=True`` adds the on-device metrics bus
    (:mod:`repro.obs.metrics`) as a trailing carry, after comm when both
    are present: ``step(..., metrics) -> (..., metrics)``, flagged
    ``step.metrics = True``. The metrics pytree accumulates per-node
    loss / grad norm / consensus distance (and, with a stateful mixer,
    the ‖x − x̂‖ EF residual via ``mixer.ef_ref``) with no host syncs.

    ``guard`` (a ``repro.resil.GuardSpec``) appends the on-device health
    guard (:mod:`repro.resil.guards`) as the last trailing carry, after
    comm and metrics: ``step(..., guard) -> (..., guard)``, flagged
    ``step.guard = True``. When the mixer carries fault injection its
    ``wire_check`` feeds per-sender wire invalidity into the guard.

    Trailing carries are always ordered (comm, metrics, guard). The
    metrics and guard updates touch nothing the training math reads, so
    telemetry-on / guard-on trajectories are bitwise-equal to the plain
    step.
    """
    node_loss = loss_adapter(model)
    grad_fn = jax.vmap(jax.value_and_grad(node_loss))
    if telemetry:
        from repro.obs import metrics as obs_metrics
    if guard is not None:
        from repro.resil import guards as resil_guards
    ef_fn = getattr(mixer, "ef_ref", None) if telemetry else None
    stateful = getattr(mixer, "stateful", False)
    wire_check = getattr(mixer, "wire_check", None)

    def step(params, opt_state, batch, lr, *rest):
        rest = list(rest)
        comm = rest.pop(0) if stateful else None
        metrics = rest.pop(0) if telemetry else None
        guard_state = rest.pop(0) if guard is not None else None
        # sender attribution must read the *pre-mix* payload: after the
        # mix, propagated corruption (validate_wire=False) has already
        # poisoned the victims' params, and checking those would flag
        # victim and offender in the same step — the strictly-later
        # invariant wire_offenders relies on only holds pre-mix
        wire_invalid = (wire_check(params)
                        if guard is not None and wire_check is not None
                        else None)
        losses, grads = grad_fn(params, batch)
        if stateful:
            bound = mixer.bind(comm)
            params, opt_state = algo.step(params, grads, opt_state, lr,
                                          bound)
            comm = bound.finalize()
        else:
            params, opt_state = algo.step(params, grads, opt_state, lr,
                                          mixer)
        out = [params, opt_state, jnp.mean(losses)]
        if stateful:
            out.append(comm)
        if telemetry:
            out.append(obs_metrics.update(
                metrics, losses, grads, params,
                ef_ref=(ef_fn(comm) if stateful and ef_fn is not None
                        else None)))
        if guard is not None:
            out.append(resil_guards.update(
                guard_state, guard, losses, grads, params,
                wire_invalid=wire_invalid))
        return tuple(out)

    step.comm = stateful
    step.metrics = telemetry
    step.guard = guard is not None
    if stateful:
        step.init_comm = mixer.init_state
    step.init_opt = algo.init
    return step


def make_shard_step(model, algo, loss_adapter, *, mesh, topology,
                    axis: str = NODE_AXIS, compression=None,
                    gossip: str = "sync", telemetry: bool = False,
                    guard=None) -> Callable:
    """The decentralized train step under ``shard_map`` over the mesh
    node axis — the ``driver_mode="shard"`` twin of :func:`make_step`.

    Node-stacked params / optimizer state / batches shard their leading
    node axis over ``mesh``'s ``axis`` (``launch.sharding.
    node_stacked_specs``); leaves without a node axis (e.g. D²'s scalar
    step counter) replicate. Inside the shard_map body each device runs
    ``vmap(value_and_grad)`` over its own block of nodes and gossips
    through the ``ppermute`` mixer backend — ring neighbours exchange
    boundary rows via ``lax.ppermute`` (complete graphs reduce via
    ``psum``), so the wire carries exactly the paper's peer-to-peer
    traffic, no all-reduce. The returned step keeps :func:`make_step`'s
    node-stacked contract (global shapes in, global shapes out, scalar
    mean loss), so the scan runner and samplers drive it unchanged and
    fixed-seed trajectories match the node-stacked runners to float
    tolerance.

    Eager validation (fail at build, not mid-schedule): the topology
    must be a ring or complete graph (others need the node-stacked
    ``gather``/``dense`` backends), the node count must be divisible by
    the mesh size, and per-edge-state algorithms (RelaySGD) are
    rejected. Churn / availability masks are unsupported under shard_map
    (DESIGN.md §7) — the scheduler raises before the run starts.

    ``compression`` / ``gossip="delayed"`` select the stateful
    compressed-wire ppermute backend (``mixing.
    make_compressed_ppermute_mixer`` — top-k payloads cross device
    boundaries as value+index pairs). The step then follows
    :func:`make_step`'s stateful contract (``step.comm``,
    ``step.init_comm``); the comm pytree shards its node axis like the
    params (``init_comm`` runs *outside* shard_map on global arrays —
    device_put its result with ``launch.sharding.federation_shardings``).

    **2-D federation mesh** (DESIGN.md §10): when ``mesh`` carries a
    non-trivial ``"model"`` axis (``launch.mesh.make_federation_mesh``),
    params / optimizer state / comm store FSDP-style model-axis shards
    (``launch.sharding.federation_specs``). The body all-gathers the
    model-sharded weight leaves back to full width for the forward /
    backward, slices the grads back to the local shard, and runs the
    algorithm update + gossip on the *sharded* trees — elementwise
    updates and the linear node-axis mix commute with the slicing, so
    the 2-D trajectory equals the 1-D shard run exactly. All gossip
    collectives stay on the node axis (model peers hold shards of the
    *same* replica); ``psum`` touches the model axis only for true
    replica-wide reductions (qg-dsgdm-n grad norms — see the mixer's
    ``reduce_tree_sum`` hook). Compressed gossip wraps the mixer in
    ``mixing.make_model_sharded_mixer`` so payload top-k still sees full
    delta rows.

    ``telemetry=True`` adds the on-device metrics-bus carry (see
    :func:`make_step`): per-node quantities are computed *inside* the
    shard_map body — the node mean for consensus is psum'd over the node
    axis, and on a 2-D mesh the per-leaf contributions of model-sharded
    leaves are additionally psum'd over the model axis (the same
    reduction split as ``reduce_tree_sum``). EF residuals are reported
    for 1-D compressed/delayed gossip and for the shard-native
    uncompressed state; the 2-D compressed mixer keeps full-width
    estimates against sharded params, so its ``ef_sq`` stays zero.

    ``guard`` (a ``repro.resil.GuardSpec``) appends the on-device health
    guard carry after metrics, sharded over the node axis like the
    metrics bus and following the same 2-D model-axis reduction split
    (wire fault injection has no shard path — ``validate_shard_schedule``
    rejects drop/corrupt faults — so ``wire_invalid`` stays zero here).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import mixing
    from repro.launch.sharding import (federation_specs, gather_model_tree,
                                       node_stacked_specs, slice_model_tree,
                                       spec_model_dim)

    n = topology.n
    size = mesh.shape[axis]
    model_axis = "model"
    model_size = dict(mesh.shape).get(model_axis, 1)
    if n % size != 0:
        raise ValueError(
            f"shard driver needs the node count ({n}) divisible by the "
            f"mesh {axis!r} axis ({size}); build the mesh with "
            "launch.mesh.make_federation_mesh")
    if getattr(algo, "needs_topology", False):
        raise ValueError(
            f"algorithm {algo.name!r} carries per-edge state and cannot "
            "run under shard_map; use the node-stacked runners "
            "(driver_mode='scan'/'host')")
    # rejects non-ring/non-full topologies eagerly, naming the fallback
    mixer = mixing.make_mixer(topology, backend="ppermute",
                              axis_names=(axis,), axis_sizes=(size,),
                              local_nodes=n // size,
                              compression=compression, gossip=gossip)

    node_loss = loss_adapter(model)
    grad_fn = jax.vmap(jax.value_and_grad(node_loss))

    def specs_of(tree):
        return federation_specs(tree, n, mesh, axis)

    def _leaf_model_dims(p_specs):
        return [spec_model_dim(s) for s in jax.tree.leaves(
            p_specs, is_leaf=lambda s: isinstance(s, P))]

    def _make_reduce(model_dims):
        # replica-wide tree-sum for qg-dsgdm-n's grad norm: model-sharded
        # leaf sums are partial (complete over "model" too); replicated
        # leaves appear on every model peer (node axis only, or they
        # would be counted model_size times)
        def reduce_tree_sum(sq):
            leaves = jax.tree.leaves(sq)
            sh = [v for v, d in zip(leaves, model_dims) if d is not None]
            rep = [v for v, d in zip(leaves, model_dims) if d is None]
            total = 0.0
            if sh:
                total = total + jax.lax.psum(sum(sh), (axis, model_axis))
            if rep:
                total = total + jax.lax.psum(sum(rep), (axis,))
            return total
        return reduce_tree_sum

    if telemetry:
        from repro.obs import metrics as obs_metrics
    if guard is not None:
        from repro.resil import guards as resil_guards

    if getattr(mixer, "stateful", False):
        def comm_step(params, opt_state, batch, lr, comm, *rest):
            rest = list(rest)
            metrics = rest.pop(0) if telemetry else None
            guard_state = rest.pop(0) if guard is not None else None
            p_specs = specs_of(params)
            model_dims = _leaf_model_dims(p_specs)
            step_mixer = mixer
            if model_size > 1 and compression is not None:
                # payload selection must see full delta rows (see
                # make_model_sharded_mixer); the uncompressed delayed
                # mixer is per-coordinate linear and runs shard-natively
                step_mixer = mixing.make_model_sharded_mixer(
                    mixer, model_dims, model_size, model_axis)
            ef_fn = (getattr(step_mixer, "ef_ref", None) if telemetry
                     else None)

            def comm_body(params, opt_state, batch, lr, comm, *m):
                full = (gather_model_tree(params, p_specs, model_axis)
                        if model_size > 1 else params)
                losses, grads = grad_fn(full, batch)
                if model_size > 1:
                    grads = slice_model_tree(grads, p_specs, model_size,
                                             model_axis)
                bound = step_mixer.bind(comm)
                if model_size > 1:
                    bound.reduce_tree_sum = _make_reduce(model_dims)
                params, opt_state = algo.step(params, grads, opt_state, lr,
                                              bound)
                comm = bound.finalize()
                loss = jax.lax.psum(jnp.sum(losses), axis) / n
                out = [params, opt_state, loss, comm]
                m = list(m)
                if metrics is not None:
                    out.append(obs_metrics.update(
                        m.pop(0), losses, grads, params,
                        ef_ref=ef_fn(comm) if ef_fn is not None else None,
                        axis_name=axis, num_nodes=n,
                        model_dims=(model_dims if model_size > 1 else None),
                        model_axis=model_axis))
                if guard_state is not None:
                    out.append(resil_guards.update(
                        m.pop(0), guard, losses, grads, params,
                        axis_name=axis, num_nodes=n,
                        model_dims=(model_dims if model_size > 1 else None),
                        model_axis=model_axis))
                return tuple(out)

            base_in = (p_specs, specs_of(opt_state),
                       node_stacked_specs(batch, n, axis), P(),
                       specs_of(comm))
            base_out = (p_specs, specs_of(opt_state), P(), specs_of(comm))
            extra_specs, extra_args = (), ()
            for carry in (metrics, guard_state):
                if carry is not None:
                    extra_specs += (node_stacked_specs(carry, n, axis),)
                    extra_args += (carry,)
            sharded = shard_map(comm_body, mesh=mesh,
                                in_specs=base_in + extra_specs,
                                out_specs=base_out + extra_specs,
                                check_rep=False)
            return sharded(params, opt_state, batch, lr, comm, *extra_args)

        comm_step.comm = True
        comm_step.metrics = telemetry
        comm_step.guard = guard is not None
        comm_step.init_comm = mixer.init_state
        comm_step.init_opt = algo.init
        return comm_step

    def step(params, opt_state, batch, lr, *rest):
        rest = list(rest)
        metrics = rest.pop(0) if telemetry else None
        guard_state = rest.pop(0) if guard is not None else None
        p_specs = specs_of(params)
        model_dims = _leaf_model_dims(p_specs)

        def body(params, opt_state, batch, lr, *m):
            full = (gather_model_tree(params, p_specs, model_axis)
                    if model_size > 1 else params)
            losses, grads = grad_fn(full, batch)
            if model_size > 1:
                grads = slice_model_tree(grads, p_specs, model_size,
                                         model_axis)
                mixer.reduce_tree_sum = _make_reduce(model_dims)
            params, opt_state = algo.step(params, grads, opt_state, lr,
                                          mixer)
            loss = jax.lax.psum(jnp.sum(losses), axis) / n
            out = [params, opt_state, loss]
            m = list(m)
            if metrics is not None:
                out.append(obs_metrics.update(
                    m.pop(0), losses, grads, params, axis_name=axis,
                    num_nodes=n,
                    model_dims=(model_dims if model_size > 1 else None),
                    model_axis=model_axis))
            if guard_state is not None:
                out.append(resil_guards.update(
                    m.pop(0), guard, losses, grads, params,
                    axis_name=axis, num_nodes=n,
                    model_dims=(model_dims if model_size > 1 else None),
                    model_axis=model_axis))
            return tuple(out)

        base_in = (p_specs, specs_of(opt_state),
                   node_stacked_specs(batch, n, axis), P())
        base_out = (p_specs, specs_of(opt_state), P())
        extra_specs, extra_args = (), ()
        for carry in (metrics, guard_state):
            if carry is not None:
                extra_specs += (node_stacked_specs(carry, n, axis),)
                extra_args += (carry,)
        sharded = shard_map(body, mesh=mesh,
                            in_specs=base_in + extra_specs,
                            out_specs=base_out + extra_specs,
                            check_rep=False)
        return sharded(params, opt_state, batch, lr, *extra_args)

    step.metrics = telemetry
    step.guard = guard is not None
    step.init_opt = algo.init
    return step


def make_frozen_step(step_fn, active) -> Callable:
    """Churn wrapper: nodes with ``active[i] == False`` hold their params
    and node-stacked optimizer state — they neither train nor gossip
    (pair with a masked mixer, ``make_mixer(..., active=...)``, so the
    surviving nodes' Metropolis weights stay doubly stochastic). Leaves
    without a leading node axis (e.g. D²'s scalar step counter) pass
    through untouched. The per-step PRNG spend is unchanged — frozen
    nodes still draw (and discard) their batches — so a node rejoining
    later leaves every other node's trajectory byte-identical.
    """
    act = jnp.asarray(np.asarray(active, bool))
    n = act.shape[0]

    def select(new, old):
        if new.ndim >= 1 and new.shape[0] == n:
            return jnp.where(act.reshape((n,) + (1,) * (new.ndim - 1)),
                             new, old)
        return new

    # trailing carries pass through untouched: the stateful mixer's own
    # freshness mask (active & ~stale) already holds down nodes' comm
    # residuals and payloads, and the metrics bus keeps accumulating the
    # inner step's pre-freeze values (a frozen node's rows describe the
    # discarded hypothetical update — telemetry, not training state)
    def step(params, opt_state, batch, lr, *rest):
        out = step_fn(params, opt_state, batch, lr, *rest)
        return (jax.tree.map(select, out[0], params),
                jax.tree.map(select, out[1], opt_state)) + tuple(out[2:])

    step.comm = getattr(step_fn, "comm", False)
    step.metrics = getattr(step_fn, "metrics", False)
    step.guard = getattr(step_fn, "guard", False)
    if hasattr(step_fn, "init_comm"):
        step.init_comm = step_fn.init_comm
    step.init_opt = step_fn.init_opt
    return step


# ------------------------------------------------------ on-device sampling
class PaddedParts(NamedTuple):
    """Padded per-node partition indices, samplable under jit."""
    idx: jax.Array    # (n, Pmax) int32 — rows padded (padding never drawn)
    size: jax.Array   # (n,) int32 — true row lengths (may be 0)


def pad_partitions(parts: List[np.ndarray]) -> PaddedParts:
    n = len(parts)
    pmax = max(max((len(p) for p in parts), default=0), 1)
    idx = np.zeros((n, pmax), np.int32)
    size = np.zeros((n,), np.int32)
    for i, p in enumerate(parts):
        p = np.asarray(p, np.int64)
        idx[i, :len(p)] = p
        size[i] = len(p)
    return PaddedParts(jnp.asarray(idx), jnp.asarray(size))


def sample_partition(parts: PaddedParts, key, batch_size: int) -> jax.Array:
    """(n, B) global indices, node i drawn uniformly from its partition.
    Empty partitions yield index 0 — mask on ``parts.size > 0``."""
    keys = jax.random.split(key, parts.idx.shape[0])

    def one(k, row, size):
        r = jax.random.randint(k, (batch_size,), 0, jnp.maximum(size, 1))
        return row[r]

    return jax.vmap(one)(keys, parts.idx, parts.size)


def _bcast(mask, ndim: int):
    """Broadcast a (n, B) mask over trailing sample axes."""
    return mask.reshape(mask.shape + (1,) * (ndim - mask.ndim))


def _require_nonempty(parts: PaddedParts, what: str) -> None:
    """Private partitions must be non-empty: sample_partition would
    silently return index 0 for an empty row (the host samplers raised
    there). Empty *public* D_ID rows stay legal — ``is_pub`` masks them."""
    sizes = np.asarray(parts.size)
    if (sizes == 0).any():
        empty = np.flatnonzero(sizes == 0).tolist()
        raise ValueError(f"empty {what} partition for node(s) {empty}; "
                         "cannot sample a training batch from them")


def make_classification_sampler(parts: PaddedParts, train_x, train_y,
                                num_classes: int,
                                batch_size: int) -> SampleFn:
    """Plain-phase batches: private images + one-hot labels."""
    _require_nonempty(parts, "private")
    train_x = jnp.asarray(train_x)
    train_y = jnp.asarray(train_y)

    def sample(key, step) -> Batch:
        idx = sample_partition(parts, key, batch_size)
        return {"images": train_x[idx],
                "labels": jax.nn.one_hot(train_y[idx], num_classes,
                                         dtype=jnp.float32),
                "weights": jnp.ones(idx.shape, jnp.float32)}

    return sample


def homogenized_ctx(hom_weights, payload, capacity: int) -> Dict:
    """Round-varying KD sampler state as one pytree.

    The scheduler refreshes the :func:`make_homogenized_sampler` between
    chunks by passing a new ctx through the runner instead of rebuilding
    (and recompiling) the sampler: padded public partitions are sized to
    the fixed ``capacity`` (the public set size) so every round shares
    one compiled executable. Keys: ``pub_idx`` (n, capacity), ``pub_size``
    (n,), ``weights`` (n, P), and ``labels`` (dense) or
    ``values``/``indices`` (sparse top-k payload).
    """
    w = np.asarray(hom_weights, np.float32)
    n = w.shape[0]
    idx = np.zeros((n, max(capacity, 1)), np.int32)
    size = np.zeros((n,), np.int32)
    for i, row in enumerate(w):
        nz = np.flatnonzero(row > 0)
        idx[i, :len(nz)] = nz
        size[i] = len(nz)
    ctx = {"pub_idx": jnp.asarray(idx), "pub_size": jnp.asarray(size),
           "weights": jnp.asarray(w)}
    if isinstance(payload, (tuple, list, distill.SparseLabels)):
        ctx["values"] = jnp.asarray(payload[0])
        ctx["indices"] = jnp.asarray(payload[1])
    else:
        ctx["labels"] = jnp.asarray(payload)
    return ctx


def make_homogenized_sampler(priv_parts: PaddedParts, pub_parts: PaddedParts,
                             train_x, train_y, public_x, hom_weights,
                             payload, num_classes: int,
                             batch_size: int) -> SampleFn:
    """KD-phase batches from D_T^i ∪ D_ID (Algorithm 1 line 15), merged
    inside jit: each slot is public with probability |D_ID| / (|D_T| +
    |D_ID|); images, labels, and weights are ``jnp.where``-selected from
    the private or public source.

    ``payload`` is the post-round label payload: a dense (n, P, C) array,
    or a ``distill.SparseLabels`` / (values, indices) pair — sparse rides
    through un-densified, with private one-hots as k=1 sparse labels.

    ``sample(key, step, ctx=None)``: with ``ctx`` (see
    :func:`homogenized_ctx`) the round-varying state — D_ID membership,
    weights, label payload — is read from the passed pytree instead of
    the factory arguments, so repeated homogenization rounds reuse one
    compiled runner. The draws are identical either way: partition
    padding width never affects which indices are sampled.
    """
    _require_nonempty(priv_parts, "private")
    train_x = jnp.asarray(train_x)
    train_y = jnp.asarray(train_y)
    public_x = jnp.asarray(public_x)
    hom_weights = jnp.asarray(hom_weights, jnp.float32)
    n = hom_weights.shape[0]
    sparse = isinstance(payload, (tuple, list, distill.SparseLabels))
    if sparse:
        default_ctx = {"pub_idx": pub_parts.idx, "pub_size": pub_parts.size,
                       "weights": hom_weights,
                       "values": jnp.asarray(payload[0]),
                       "indices": jnp.asarray(payload[1])}
    else:
        default_ctx = {"pub_idx": pub_parts.idx, "pub_size": pub_parts.size,
                       "weights": hom_weights,
                       "labels": jnp.asarray(payload)}
    nidx = jnp.arange(n)[:, None]

    def sample(key, step, ctx=None) -> Batch:
        c = default_ctx if ctx is None else ctx
        pub_c = PaddedParts(c["pub_idx"], c["pub_size"])
        p_pub = c["pub_size"] / jnp.maximum(priv_parts.size + c["pub_size"],
                                            1)
        kp, kq, ku = jax.random.split(key, 3)
        priv = sample_partition(priv_parts, kp, batch_size)    # (n, B)
        pub = sample_partition(pub_c, kq, batch_size)
        u = jax.random.uniform(ku, priv.shape)
        is_pub = (u < p_pub[:, None]) & (c["pub_size"] > 0)[:, None]
        img_priv = train_x[priv]
        images = jnp.where(_bcast(is_pub, img_priv.ndim),
                           public_x[pub], img_priv)
        weights = jnp.where(is_pub, c["weights"][nidx, pub], 1.0
                            ).astype(jnp.float32)
        batch = {"images": images, "weights": weights, "is_pub": is_pub}
        if sparse:
            vals = c["values"][nidx, pub]                      # (n, B, k)
            cls = c["indices"][nidx, pub]
            pv = jnp.zeros_like(vals).at[..., 0].set(1.0)
            pi = jnp.zeros_like(cls).at[..., 0].set(
                train_y[priv].astype(cls.dtype))
            batch["values"] = jnp.where(is_pub[..., None], vals, pv)
            batch["indices"] = jnp.where(is_pub[..., None], cls, pi)
        else:
            lab_priv = jax.nn.one_hot(train_y[priv], num_classes,
                                      dtype=jnp.float32)
            batch["labels"] = jnp.where(is_pub[..., None],
                                        c["labels"][nidx, pub], lab_priv)
        return batch

    return sample


def make_lm_sampler(parts: PaddedParts, tokens, batch_size: int) -> SampleFn:
    """LM batches: (n, B, S) token/next-token pairs from per-node shards."""
    _require_nonempty(parts, "private")
    tokens = jnp.asarray(tokens)

    def sample(key, step) -> Batch:
        idx = sample_partition(parts, key, batch_size)
        seq = tokens[idx]                                      # (n, B, S+1)
        return {"tokens": seq[..., :-1], "labels": seq[..., 1:]}

    return sample


def lm_kd_ctx(pub_vals, pub_idx, pub_w) -> Dict:
    """Round-varying LM-KD sampler state (see :func:`make_lm_kd_sampler`):
    the sparse label payload + weights refreshed by each homogenization
    round, passed through the runner so one compiled executable serves
    every round."""
    return {"pub_vals": jnp.asarray(pub_vals),
            "pub_idx": jnp.asarray(pub_idx),
            "pub_w": jnp.asarray(pub_w, jnp.float32)}


def make_lm_kd_sampler(parts: PaddedParts, tokens, batch_size: int,
                       public_tokens, pub_vals, pub_idx, pub_w,
                       pub_batch: int) -> SampleFn:
    """LM batches + a per-node public sub-batch with its sparse payload.
    ``sample(key, step, ctx=None)`` — ``ctx`` (:func:`lm_kd_ctx`)
    overrides the factory payload for post-first-round refreshes."""
    base = make_lm_sampler(parts, tokens, batch_size)
    public_tokens = jnp.asarray(public_tokens)
    default_ctx = lm_kd_ctx(pub_vals, pub_idx, pub_w)
    n = default_ctx["pub_w"].shape[0]
    nidx = jnp.arange(n)[:, None]

    def sample(key, step, ctx=None) -> Batch:
        c = default_ctx if ctx is None else ctx
        k1, k2 = jax.random.split(key)
        batch = base(k1, step)
        pb = jax.random.randint(k2, (n, pub_batch), 0, len(public_tokens))
        batch["pub_tokens"] = public_tokens[pb]
        batch["pub_vals"] = c["pub_vals"][nidx, pb]
        batch["pub_idx"] = c["pub_idx"][nidx, pb]
        batch["pub_w"] = c["pub_w"][nidx, pb]
        return batch

    return sample


# ---------------------------------------------------------------- runners
def make_scan_runner(step_fn, sample_fn: SampleFn, lr_fn) -> Callable:
    """``run(params, opt_state, key, step0, num_steps, ctx=None)`` — the
    whole chunk of steps is one ``lax.scan`` under jit (sampling
    included): zero per-step dispatch. ``step0`` is traced (chunks at
    different offsets share one executable); ``num_steps`` is static (one
    compile per distinct chunk length); ``ctx`` is the round-varying
    sampler state (traced — the scheduler swaps label payloads between
    homogenization rounds without triggering a recompile).

    A comm-carrying step (``step_fn.comm`` — stateful compressed/delayed
    gossip) extends the contract to ``run(params, opt_state, key, step0,
    num_steps, ctx=None, comm=None) -> (params, opt_state, key, losses,
    comm)``: the mixer state rides the scan carry next to params, flagged
    ``run.comm = True``. A metrics-carrying step (``step_fn.metrics`` —
    the :mod:`repro.obs` metrics bus) appends ``metrics`` the same way
    (after comm when both are present), flagged ``run.metrics = True``;
    a guard-carrying step (``step_fn.guard`` — the
    :mod:`repro.resil.guards` health guard) appends ``guard`` last,
    flagged ``run.guard = True``. All carries ride one generic scan: jax
    treats ``None`` as an empty pytree, so absent carries cost nothing
    in the compiled program.
    """
    has_comm = getattr(step_fn, "comm", False)
    has_metrics = getattr(step_fn, "metrics", False)
    has_guard = getattr(step_fn, "guard", False)

    if has_comm or has_metrics or has_guard:
        @functools.partial(jax.jit, static_argnums=(4,))
        def aug_run(params, opt_state, key, step0, num_steps, ctx=None,
                    comm=None, metrics=None, guard=None):
            def body(carry, t):
                params, opt_state, key, comm, metrics, guard = carry
                key, sub = jax.random.split(key)
                batch = (sample_fn(sub, step0 + t) if ctx is None
                         else sample_fn(sub, step0 + t, ctx))
                args = (params, opt_state, batch, lr_fn(step0 + t))
                if has_comm:
                    args += (comm,)
                if has_metrics:
                    args += (metrics,)
                if has_guard:
                    args += (guard,)
                out = step_fn(*args)
                params, opt_state, loss = out[0], out[1], out[2]
                rest = list(out[3:])
                if has_comm:
                    comm = rest.pop(0)
                if has_metrics:
                    metrics = rest.pop(0)
                if has_guard:
                    guard = rest.pop(0)
                return (params, opt_state, key, comm, metrics, guard), loss

            (params, opt_state, key, comm, metrics, guard), losses = \
                jax.lax.scan(
                    body, (params, opt_state, key, comm, metrics, guard),
                    jnp.arange(num_steps))
            out = (params, opt_state, key, losses)
            if has_comm:
                out += (comm,)
            if has_metrics:
                out += (metrics,)
            if has_guard:
                out += (guard,)
            return out

        aug_run.comm = has_comm
        aug_run.metrics = has_metrics
        aug_run.guard = has_guard
        return aug_run

    @functools.partial(jax.jit, static_argnums=(4,))
    def run(params, opt_state, key, step0, num_steps, ctx=None):
        def body(carry, t):
            params, opt_state, key = carry
            key, sub = jax.random.split(key)
            batch = (sample_fn(sub, step0 + t) if ctx is None
                     else sample_fn(sub, step0 + t, ctx))
            params, opt_state, loss = step_fn(params, opt_state, batch,
                                              lr_fn(step0 + t))
            return (params, opt_state, key), loss

        (params, opt_state, key), losses = jax.lax.scan(
            body, (params, opt_state, key), jnp.arange(num_steps))
        return params, opt_state, key, losses

    return run


def make_host_runner(step_fn, sample_fn: SampleFn, lr_fn) -> Callable:
    """Same contract as :func:`make_scan_runner`, but a per-step Python
    loop around one jitted step — the dispatch-overhead baseline. Key
    handling matches the scan body exactly, so trajectories agree."""
    has_comm = getattr(step_fn, "comm", False)
    has_metrics = getattr(step_fn, "metrics", False)
    has_guard = getattr(step_fn, "guard", False)

    if has_comm or has_metrics or has_guard:
        @jax.jit
        def aug_one(params, opt_state, key, t, ctx=None, comm=None,
                    metrics=None, guard=None):
            key, sub = jax.random.split(key)
            batch = (sample_fn(sub, t) if ctx is None
                     else sample_fn(sub, t, ctx))
            args = (params, opt_state, batch, lr_fn(t))
            if has_comm:
                args += (comm,)
            if has_metrics:
                args += (metrics,)
            if has_guard:
                args += (guard,)
            out = step_fn(*args)
            params, opt_state, loss = out[0], out[1], out[2]
            rest = list(out[3:])
            if has_comm:
                comm = rest.pop(0)
            if has_metrics:
                metrics = rest.pop(0)
            if has_guard:
                guard = rest.pop(0)
            return params, opt_state, key, loss, comm, metrics, guard

        def aug_run(params, opt_state, key, step0, num_steps, ctx=None,
                    comm=None, metrics=None, guard=None):
            losses = []
            for t in range(num_steps):
                params, opt_state, key, loss, comm, metrics, guard = \
                    aug_one(params, opt_state, key,
                            jnp.asarray(step0 + t, jnp.int32), ctx, comm,
                            metrics, guard)
                losses.append(loss)
            out = (params, opt_state, key,
                   jnp.stack(losses) if losses
                   else jnp.zeros((0,), jnp.float32))
            if has_comm:
                out += (comm,)
            if has_metrics:
                out += (metrics,)
            if has_guard:
                out += (guard,)
            return out

        aug_run.comm = has_comm
        aug_run.metrics = has_metrics
        aug_run.guard = has_guard
        return aug_run

    @jax.jit
    def one(params, opt_state, key, t, ctx=None):
        key, sub = jax.random.split(key)
        batch = sample_fn(sub, t) if ctx is None else sample_fn(sub, t, ctx)
        params, opt_state, loss = step_fn(params, opt_state, batch,
                                          lr_fn(t))
        return params, opt_state, key, loss

    def run(params, opt_state, key, step0, num_steps, ctx=None):
        losses = []
        for t in range(num_steps):
            params, opt_state, key, loss = one(
                params, opt_state, key, jnp.asarray(step0 + t, jnp.int32),
                ctx)
            losses.append(loss)
        return (params, opt_state, key,
                jnp.stack(losses) if losses else jnp.zeros((0,), jnp.float32))

    return run


def make_runner(step_fn, sample_fn: SampleFn, lr_fn,
                mode: str = "scan", arch_type: str = "",
                conv_backend: str = "lax") -> Callable:
    """``mode="shard"`` expects a :func:`make_shard_step`-built step and
    drives it with the scan runner — sampling stays outside shard_map
    (replicated, identical PRNG math), the step reshards per its specs."""
    if mode not in RUNNER_MODES:
        raise ValueError(f"unknown driver mode {mode!r}; "
                         f"expected one of {RUNNER_MODES}")
    mode = resolve_runner_mode(mode, arch_type, conv_backend)
    maker = make_host_runner if mode == "host" else make_scan_runner
    return maker(step_fn, sample_fn, lr_fn)


def eval_boundaries(steps: int, eval_every: int,
                    extra: Optional[int] = None) -> List[Tuple[int, int]]:
    """Chunk [start, stop) spans between eval/homogenization boundaries.

    Chunks end right after each eval step (``s % eval_every == 0`` or the
    last step) and break *before* ``extra`` (the homogenization step), so
    the driver can swap samplers between chunks. Chunk lengths take only
    a few distinct values → a few scan compiles per run.
    """
    cuts = {0, steps}
    cuts |= {s + 1 for s in range(steps)
             if s % eval_every == 0 or s == steps - 1}
    if extra is not None and 0 <= extra < steps:
        cuts.add(extra)
    edges = sorted(cuts)
    return [(a, b) for a, b in zip(edges[:-1], edges[1:])]
