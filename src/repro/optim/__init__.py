from repro.optim.schedules import constant, cosine, step_decay  # noqa: F401
from repro.optim.sgd import (adamw_init, adamw_update, sgd_init,  # noqa: F401
                             sgd_update)
