"""Plain (non-decentralized) optimizers — used inside a node's
model-parallel group and by the centralized baseline."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: object


def sgd_init(params, momentum: float = 0.9):
    return SGDState(jax.tree.map(jnp.zeros_like, params))


def sgd_update(params, grads, state: SGDState, lr, momentum: float = 0.9,
               weight_decay: float = 0.0, nesterov: bool = False):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype),
                             grads, params)
    m = jax.tree.map(lambda mi, g: momentum * mi + g, state.momentum, grads)
    upd = jax.tree.map(lambda mi, g: momentum * mi + g, m, grads) \
        if nesterov else m
    new = jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                     - lr * u.astype(jnp.float32)
                                     ).astype(p.dtype), params, upd)
    return new, SGDState(m)


class AdamWState(NamedTuple):
    mu: object
    nu: object
    t: jax.Array


def adamw_init(params):
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(z, jax.tree.map(jnp.zeros_like, z),
                      jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state: AdamWState, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    t = state.t + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                      * jnp.square(g.astype(jnp.float32)), state.nu, grads)
    c1 = 1 - b1 ** t.astype(jnp.float32)
    c2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    return jax.tree.map(upd, params, mu, nu), AdamWState(mu, nu, t)
