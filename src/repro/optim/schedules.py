"""Learning-rate schedules (paper: step decay ×0.1 at 60%/80% of training)."""
from __future__ import annotations

import jax.numpy as jnp


def step_decay(base_lr: float, total_steps: int, milestones=(0.6, 0.8),
               factor: float = 0.1):
    ms = jnp.asarray([m * total_steps for m in milestones])

    def lr(step):
        k = jnp.sum(step >= ms)
        return base_lr * (factor ** k)

    return lr


def cosine(base_lr: float, total_steps: int, warmup: int = 0,
           min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                     0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def constant(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)
