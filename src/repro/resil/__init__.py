"""Resilience subsystem: deterministic fault injection, on-device
health guards, quarantine, and durable checkpoint/rollback
(DESIGN.md §12)."""
from repro.resil.faults import (  # noqa: F401
    CORRUPT_MODES, DEFAULT_MAX_ABS, FAULT_KINDS, SimulatedCrash,
    WireFault, corrupt_rows, corrupt_values, make_validated_mixer,
    payload_valid)
from repro.resil.guards import (  # noqa: F401
    GUARD_COUNTERS, GuardSpec, init_node_guard, tripped_nodes,
    wire_offenders)
from repro.resil.snapshot import Resilience, SnapshotManager  # noqa: F401
