"""On-device health guards: a pytree carried through the jitted runners.

The guard rides the scan/host/shard runner carry exactly like PR 6's
comm state and PR 8's metrics bus — accumulated inside jit with zero
host syncs, flushed only at segment boundaries where the scheduler turns
counters into quarantine/rollback decisions. Layout (fixed across
phases so the scan carry structure never changes):

  ``steps``             ()   int32 — steps since the last flush
  ``loss_ema``          (n,) f32   — EMA of per-node train loss (spike ref)
  ``nonfinite_loss``    (n,) int32 — steps the node's loss was nan/inf
  ``nonfinite_grad``    (n,) int32 — steps any grad element was nan/inf
  ``nonfinite_param``   (n,) int32 — steps any param element was nan/inf
  ``loss_spike``        (n,) int32 — steps loss exceeded factor × EMA
  ``consensus_blowup``  (n,) int32 — steps ‖x_i − x̄‖ exceeded the bound
  ``wire_invalid``      (n,) int32 — steps the node's *outgoing* wire
                                     payload failed validation (sender
                                     attribution, from the validated
                                     mixer's ``wire_check``)

All checks are read-only observers of the training step — a guard-on
no-fault run computes bitwise the same params/opt/loss trajectory as a
guard-off run. :func:`update` has the same two addressing modes as
``repro.obs.metrics.update``: node-stacked (leading node axis) and shard
(inside ``shard_map``; per-leaf contributions of model-sharded leaves
psum'd over the model axis on 2-D federation meshes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.resil.faults import DEFAULT_MAX_ABS

GUARD_COUNTERS = ("nonfinite_loss", "nonfinite_grad", "nonfinite_param",
                  "loss_spike", "consensus_blowup", "wire_invalid")
# counters that indict the node's own health (wire_invalid instead
# attributes the *sender* of a bad payload — still a node index)
OWN_HEALTH_COUNTERS = GUARD_COUNTERS[:-1]
_EMA_DECAY = 0.9


@dataclass(frozen=True)
class GuardSpec:
    """Static guard thresholds (hashable — baked into the jitted step).

    ``loss_spike_factor``/``consensus_max`` of 0 disable those checks;
    non-finite detection is always on (params/grads gated by the
    ``check_*`` flags). ``max_abs`` bounds wire payload magnitudes for
    validation; ``validate_wire=False`` disables the mixer's
    receive-side degradation (injected corruption then genuinely
    propagates — the rollback path's test bed) while ``wire_check``
    sender attribution keeps running."""
    loss_spike_factor: float = 0.0
    warmup_steps: int = 5
    consensus_max: float = 0.0
    check_grads: bool = True
    check_params: bool = True
    max_abs: float = DEFAULT_MAX_ABS
    validate_wire: bool = True


def init_node_guard(n: int):
    """Zeroed guard pytree for ``n`` nodes (node-stacked layout)."""
    g = {"steps": jnp.zeros((), jnp.int32),
         "loss_ema": jnp.zeros((n,), jnp.float32)}
    for k in GUARD_COUNTERS:
        g[k] = jnp.zeros((n,), jnp.int32)
    return g


def _row_bad_counts(x):
    """(rows, ...) -> (rows,) int32 count of non-finite elements."""
    flat = x.astype(jnp.float32).reshape(x.shape[0], -1)
    return jnp.sum((~jnp.isfinite(flat)).astype(jnp.int32), axis=1)


def update(guard, spec: GuardSpec, losses, grads, params, *,
           wire_invalid=None, axis_name: Optional[str] = None,
           num_nodes: int = 0, model_dims=None, model_axis: str = "model"):
    """One guard step; pure, jit-safe, no host syncs, reads-only.

    ``wire_invalid`` is the validated mixer's per-sender ``(n,)`` bool
    (None when no fault injection is active). Shard-mode addressing
    matches ``obs.metrics.update`` — leaves hold the local node block,
    ``model_dims`` marks model-sharded leaves whose contributions are
    psum'd over ``model_axis``."""
    p_leaves = jax.tree.leaves(params)
    g_leaves = jax.tree.leaves(grads)
    dims = (list(model_dims) if model_dims is not None
            else [None] * len(p_leaves))

    def combine(vals):
        sharded = [v for v, d in zip(vals, dims) if d is not None]
        replicated = [v for v, d in zip(vals, dims) if d is None]
        total = jnp.zeros_like(vals[0])
        if sharded:
            total = total + jax.lax.psum(sum(sharded), model_axis)
        if replicated:
            total = total + sum(replicated)
        return total

    lf = losses.astype(jnp.float32)
    finite_loss = jnp.isfinite(lf)
    bad_loss = ~finite_loss

    zeros_i = jnp.zeros_like(guard["nonfinite_loss"])
    if spec.check_grads:
        bad_grad = combine([_row_bad_counts(g) for g in g_leaves]) > 0
    else:
        bad_grad = zeros_i > 0
    if spec.check_params:
        bad_param = combine([_row_bad_counts(p) for p in p_leaves]) > 0
    else:
        bad_param = zeros_i > 0

    ema = guard["loss_ema"]
    warm = guard["steps"] >= jnp.int32(spec.warmup_steps)
    if spec.loss_spike_factor > 0:
        spike = (warm & finite_loss & (ema > 0)
                 & (lf > jnp.float32(spec.loss_spike_factor) * ema))
    else:
        spike = zeros_i > 0
    safe_lf = jnp.where(finite_loss, lf, ema)
    new_ema = jnp.where(guard["steps"] == 0, safe_lf,
                        _EMA_DECAY * ema + (1.0 - _EMA_DECAY) * safe_lf)

    if spec.consensus_max > 0:
        cons = []
        for x in p_leaves:
            xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
            if axis_name is None:
                mean = jnp.mean(xf, axis=0, keepdims=True)
            else:
                mean = (jax.lax.psum(jnp.sum(xf, axis=0, keepdims=True),
                                     axis_name) / num_nodes)
            delta = xf - mean
            cons.append(jnp.sum(delta * delta, axis=1))
        blowup = (combine(cons)
                  > jnp.float32(spec.consensus_max) ** 2)
    else:
        blowup = zeros_i > 0

    out = {"steps": guard["steps"] + 1, "loss_ema": new_ema,
           "nonfinite_loss": guard["nonfinite_loss"]
           + bad_loss.astype(jnp.int32),
           "nonfinite_grad": guard["nonfinite_grad"]
           + bad_grad.astype(jnp.int32),
           "nonfinite_param": guard["nonfinite_param"]
           + bad_param.astype(jnp.int32),
           "loss_spike": guard["loss_spike"] + spike.astype(jnp.int32),
           "consensus_blowup": guard["consensus_blowup"]
           + blowup.astype(jnp.int32)}
    wire = guard["wire_invalid"]
    if wire_invalid is not None:
        wire = wire + wire_invalid.astype(jnp.int32)
    out["wire_invalid"] = wire
    return out


def reset(guard):
    """Zero the accumulators (same structure/placement — carry-safe)."""
    return jax.tree.map(jnp.zeros_like, guard)


def summarize(guard) -> dict:
    """Host-side flush: device_get once, counters as plain int lists."""
    g = jax.device_get(guard)
    out = {"accum_steps": int(g["steps"]),
           "loss_ema": [float(v) for v in np.asarray(g["loss_ema"])]}
    for k in GUARD_COUNTERS:
        out[k] = [int(v) for v in np.asarray(g[k])]
    return out


def tripped_nodes(summary: dict) -> np.ndarray:
    """(n,) bool — nodes any own-health counter flagged this flush."""
    bad = np.zeros(len(summary["nonfinite_loss"]), bool)
    for k in OWN_HEALTH_COUNTERS:
        bad |= np.asarray(summary[k], np.int64) > 0
    return bad


def wire_offenders(summary: dict) -> np.ndarray:
    """(n,) bool — senders attributed by wire validation.

    Under propagation (validation off), poisoned *victims* start failing
    wire checks too, but strictly later than the true offender — the
    offender's count is maximal, so only max-count senders are
    indicted."""
    wire = np.asarray(summary["wire_invalid"], np.int64)
    if not (wire > 0).any():
        return np.zeros(wire.shape, bool)
    return wire == wire.max()
