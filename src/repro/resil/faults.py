"""Deterministic wire-fault injection and receive-side payload validation.

Faults are *per-segment static*: the schedule compiler cuts a segment
boundary at every ``FaultEvent`` step, so within one jitted runner
invocation the fault state is a compile-time constant — injection is a
mixer wrapper, never an in-jit step dependence. A :class:`WireFault` is
the frozen, hashable description of that state (part of the scheduler's
mixer cache keys).

Three wire fault kinds ride the gossip exchange:

``drop``
    The listed senders' payloads never arrive. Receivers fall back to
    self-weight via a masked Metropolis matrix (``W_eff``) — the same
    graceful-degradation math the churn machinery uses, applied to
    messages instead of nodes.
``corrupt``
    The listed senders' payloads are corrupted in flight (``nan`` /
    ``inf`` constants, or ``bitflip`` — an exponent-bit XOR yielding
    huge finite values). With receive-side validation on (the default),
    a corrupted payload fails the finite-and-bounded check and is
    treated exactly as dropped: detected-corrupt and drop runs are
    bitwise identical. With validation off (``GuardSpec.validate_wire =
    False``) nan/inf corruption genuinely reaches receivers — the
    rollback-on-divergence path's test bed.
``crash``
    The process dies mid-run (:class:`SimulatedCrash`); recovery is the
    durable-snapshot auto-resume path, not the mixer.

Validation never runs when no fault is injected — the no-fault mixers
are returned unwrapped, so fault-free trajectories are bitwise untouched
(steady-state health protection is the node-level guard's job,
:mod:`repro.resil.guards`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FAULT_KINDS = ("drop", "corrupt", "crash", "clear")
CORRUPT_MODES = ("nan", "inf", "bitflip")
# receive-side payload magnitude bound: anything larger than this (or
# non-finite) fails validation — generous against real params/deltas,
# tripped by every corruption mode above
DEFAULT_MAX_ABS = 1e8


@dataclass(frozen=True)
class WireFault:
    """Static wire-fault state for one schedule segment.

    ``drop`` / ``corrupt`` are sender node indices; ``mode`` is the
    corruption applied to corrupt senders' payloads. Hashable — the
    scheduler folds it into mixer/step cache keys."""
    drop: Tuple[int, ...] = ()
    corrupt: Tuple[int, ...] = ()
    mode: str = "nan"

    def __post_init__(self):
        object.__setattr__(self, "drop", tuple(sorted(set(self.drop))))
        object.__setattr__(self, "corrupt",
                           tuple(sorted(set(self.corrupt))))
        if self.mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corruption mode {self.mode!r}; "
                             f"expected one of {CORRUPT_MODES}")

    @property
    def senders(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.drop) | set(self.corrupt)))

    def is_noop(self) -> bool:
        return not self.drop and not self.corrupt


class SimulatedCrash(RuntimeError):
    """A ``FaultEvent(kind="crash")`` fired: the run 'dies' here, mid
    schedule. The CLIs catch this and exit cleanly; recovery is a fresh
    invocation auto-resuming from the latest durable snapshot."""

    def __init__(self, step: int):
        super().__init__(f"simulated crash at step {step}")
        self.step = step


def _col(v, ndim: int):
    """Broadcast a per-row vector over a (rows, ...) array's trailing dims."""
    v = jnp.asarray(v)
    return v.reshape(v.shape[:1] + (1,) * (ndim - 1))


def corrupt_values(xf, mode: str):
    """A fully corrupted f32 copy of ``xf`` (callers mask rows in).

    ``bitflip`` XORs f32 exponent bit 30 — small values blow up by
    ~2^128 into huge (mostly finite) magnitudes, the realistic
    memory-fault shape the bounded-magnitude validation check exists
    for."""
    if mode == "nan":
        return jnp.full_like(xf, jnp.nan)
    if mode == "inf":
        return jnp.full_like(xf, jnp.inf)
    if mode == "bitflip":
        bits = jax.lax.bitcast_convert_type(xf, jnp.int32)
        return jax.lax.bitcast_convert_type(bits ^ jnp.int32(1 << 30),
                                            jnp.float32)
    raise ValueError(f"unknown corruption mode {mode!r}; expected one of "
                     f"{CORRUPT_MODES}")


def corrupt_rows(x, rows, mode: str):
    """Apply ``mode`` corruption to the marked leading-axis rows (f32)."""
    xf = jnp.asarray(x).astype(jnp.float32)
    return jnp.where(_col(jnp.asarray(rows, bool), xf.ndim),
                     corrupt_values(xf, mode), xf)


def payload_valid(x, max_abs: float = DEFAULT_MAX_ABS):
    """(rows,) bool — each row payload entirely finite and bounded."""
    flat = jnp.asarray(x).astype(jnp.float32).reshape(x.shape[0], -1)
    return jnp.all(jnp.isfinite(flat) & (jnp.abs(flat) <= max_abs), axis=1)


def make_validated_mixer(base, W, fault: Optional[WireFault] = None, *,
                         max_abs: float = DEFAULT_MAX_ABS,
                         validate: bool = True):
    """Wrap a stateless node-stacked mixer with fault injection and
    receive-side payload validation.

    ``W`` is the (masked) Metropolis matrix the base mixer encodes. Per
    leaf: corruption is injected into the senders' wire rows, every
    sender's wire payload is validated (finite and ``|v| <= max_abs``),
    and when any payload fails — or is dropped by fiat — the mix runs a
    degraded dense pass with ``W_eff``: invalid senders' off-diagonal
    columns zeroed and their Metropolis mass returned to each receiver's
    self-weight. The degraded einsum reads the *clean* ``x`` (invalid
    columns carry zero weight, and ``0 * nan = nan`` would otherwise
    poison the row), which is exactly why detected-corrupt ≡ drop holds
    bitwise: both reduce to the same ``W_eff`` product over the same
    clean operand. The all-valid branch calls the base mixer untouched.

    With ``validate=False``, nan/inf corruption propagates for real:
    every receiver with a corrupted in-neighbour gets a fully poisoned
    row (exact — a whole-payload nan/inf contribution saturates the
    weighted sum). ``bitflip`` without validation is rejected (its huge
    finite values cannot be propagated exactly through the masked
    einsum's zero weights).

    The wrapper exposes ``wire_check(tree) -> (n,) bool`` — per-sender
    invalidity of the actual wire values, recomputed from the same
    injection — which the on-device guard uses for sender attribution
    (``drop`` is a network fault, not sender misbehaviour, and is
    excluded)."""
    Wnp = np.asarray(W, np.float64)
    n = Wnp.shape[0]
    Wj = jnp.asarray(Wnp, jnp.float32)
    eye = jnp.eye(n, dtype=jnp.float32)
    drop_np = np.zeros(n, bool)
    corrupt_np = np.zeros(n, bool)
    mode = "nan"
    if fault is not None:
        drop_np[list(fault.drop)] = True
        corrupt_np[list(fault.corrupt)] = True
        mode = fault.mode
    drop_j = jnp.asarray(drop_np)
    has_corrupt = bool(corrupt_np.any())
    corrupt_j = jnp.asarray(corrupt_np)
    if not validate and has_corrupt and mode == "bitflip":
        raise ValueError(
            "bitflip wire corruption requires receive-side validation "
            "(GuardSpec.validate_wire=True): its finite values cannot "
            "propagate exactly through the masked mixing path")

    def wire_rows(xf):
        """The f32 values each sender actually puts on the wire."""
        if has_corrupt:
            return jnp.where(_col(corrupt_j, xf.ndim),
                             corrupt_values(xf, mode), xf)
        return xf

    def _degraded(xf, valid):
        vf = valid.astype(jnp.float32)
        mask = vf[None, :] * (1.0 - eye) + eye
        W_eff = Wj * mask
        W_eff = W_eff + jnp.diag(1.0 - W_eff.sum(axis=1))
        return jnp.einsum("ij,j...->i...", W_eff, xf,
                          preferred_element_type=jnp.float32)

    if validate:
        def mix_leaf(x):
            xf = x.astype(jnp.float32)
            valid = payload_valid(wire_rows(xf), max_abs) & ~drop_j
            return jax.lax.cond(
                jnp.all(valid),
                lambda: jnp.asarray(base.mix_leaf(x)),
                lambda: _degraded(xf, valid).astype(x.dtype))
    else:
        # corruption reaches receivers: poison every row with a corrupted
        # in-neighbour (static — W's sparsity pattern and the corrupt set
        # are both compile-time constants)
        affected_np = ((Wnp * (1.0 - np.eye(n)))
                       @ corrupt_np.astype(np.float64)) > 0
        bad = float("nan") if mode == "nan" else float("inf")

        def mix_leaf(x):
            if drop_np.any():
                y = _degraded(x.astype(jnp.float32),
                              ~drop_j).astype(x.dtype)
            else:
                y = base.mix_leaf(x)
            if has_corrupt:
                y = jnp.where(_col(jnp.asarray(affected_np), y.ndim),
                              jnp.asarray(bad, y.dtype), y)
            return y

    def wire_check(tree):
        """(n,) bool — senders whose actual wire payload fails
        validation on any leaf (corruption injected; drop excluded)."""
        flags = jnp.zeros((n,), bool)
        for x in jax.tree.leaves(tree):
            flags = flags | ~payload_valid(
                wire_rows(x.astype(jnp.float32)), max_abs)
        return flags

    def mix(tree):
        return jax.tree.map(mix_leaf, tree)

    mix.mix_leaf = mix_leaf
    mix.wire_check = wire_check
    mix.wire_fault = fault
    return mix
