"""Durable on-disk snapshots: periodic training-state checkpoints with
schema version + checksum, newest-valid auto-resume, and pruning.

A snapshot captures everything a segment boundary needs to continue the
run exactly: params, optimizer state, the PRNG key, the comm pytree
(CHOCO shared estimates / delayed-gossip state, when the run is
stateful), the homogenization context (the KD sampler's flat str→array
payload), and the phase string. State rides
:func:`repro.checkpoint.save_checkpoint` (versioned + checksummed); the
ctx — whose array shapes are round-dependent and unknowable at load
time — rides a sibling plain npz with its own checksum recorded in the
snapshot meta.

``load_latest`` scans newest→oldest and *skips* any snapshot that fails
validation (version skew, checksum mismatch, truncated write, structure
mismatch) with a logged warning — a half-written file from a crash never
blocks recovery, it just costs one snapshot interval of recompute.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint import (checkpoint_checksum, load_checkpoint,
                              save_checkpoint)
from repro.obs import log
from repro.resil.guards import GuardSpec


@dataclass(frozen=True)
class Resilience:
    """Run-level resilience configuration.

    ``guard`` enables the on-device health guard carry; ``snapshot_dir``
    enables durable snapshots every ``snapshot_every`` steps (0 = every
    segment boundary), keeping the newest ``keep``; ``rollback`` turns a
    guard trip into restore-last-good + re-run with the offender
    quarantined (at most ``max_retries`` times per segment) instead of
    quarantine-and-continue."""
    guard: Optional[GuardSpec] = None
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 0
    keep: int = 3
    rollback: bool = False
    max_retries: int = 2

    @property
    def snapshots_on(self) -> bool:
        return self.snapshot_dir is not None


class SnapshotManager:
    """Writes/prunes/loads ``snap-<step>`` durable snapshots in a dir."""

    def __init__(self, directory, every: int = 0, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.every = int(every)
        self.keep = max(int(keep), 1)
        self._last: Optional[int] = None

    def _base(self, step: int) -> str:
        return str(self.dir / f"snap-{step:08d}")

    def steps(self):
        """Snapshot steps on disk, ascending."""
        out = []
        for p in self.dir.glob("snap-*.meta.json"):
            try:
                out.append(int(p.name[len("snap-"):-len(".meta.json")]))
            except ValueError:
                continue
        return sorted(out)

    def due(self, step: int) -> bool:
        if self._last is None or self.every <= 0:
            return True
        return step - self._last >= self.every

    # ------------------------------------------------- crash tombstones
    # A simulated crash kills the process once; the resumed incarnation
    # must run *through* that step. The durable tombstone is what makes
    # "once" survive the restart (the schedule itself is static).
    def crash_seen(self, step: int) -> bool:
        return (self.dir / f"crash-{step:08d}.tomb").exists()

    def mark_crash(self, step: int) -> None:
        (self.dir / f"crash-{step:08d}.tomb").touch()

    def save(self, step: int, state, *, ctx=None, phase: str = "plain",
             fired: int = 0) -> None:
        """Persist one snapshot; ``state`` is the checkpointable pytree
        (params/opt_state/key[/comm]), ``ctx`` the flat str→array
        homogenization payload (or None before the first round)."""
        extra = {"phase": phase, "fired": int(fired), "has_ctx": False}
        if ctx is not None:
            flat = {k: np.asarray(v) for k, v in ctx.items()}
            np.savez(self._base(step) + ".ctx.npz", **flat)
            extra.update(has_ctx=True,
                         ctx_checksum=checkpoint_checksum(flat))
        save_checkpoint(self._base(step), state, step=step, extra=extra)
        self._last = step
        self._prune()

    def _prune(self) -> None:
        for step in self.steps()[:-self.keep]:
            base = self._base(step)
            for suffix in (".npz", ".meta.json", ".ctx.npz"):
                try:
                    os.unlink(base + suffix)
                except FileNotFoundError:
                    pass

    def load_latest(self, like) -> Optional[dict]:
        """Newest snapshot that validates, restored into ``like``'s
        structure — or None when no usable snapshot exists. Returns
        ``{"state", "step", "phase", "fired", "ctx"}``."""
        for step in reversed(self.steps()):
            base = self._base(step)
            try:
                state, saved_step = load_checkpoint(base, like)
                with open(base + ".meta.json") as f:
                    extra = json.load(f).get("extra", {})
                ctx = None
                if extra.get("has_ctx"):
                    npz = np.load(base + ".ctx.npz")
                    ctx = {k: npz[k] for k in npz.files}
                    crc = checkpoint_checksum(ctx)
                    if extra.get("ctx_checksum") != crc:
                        raise ValueError(
                            f"snapshot ctx checksum mismatch at step "
                            f"{step}: meta {extra.get('ctx_checksum')!r}"
                            f" != arrays {crc}")
                self._last = saved_step
                return {"state": state, "step": saved_step,
                        "phase": extra.get("phase", "plain"),
                        "fired": int(extra.get("fired", 0)), "ctx": ctx}
            except (ValueError, OSError, KeyError, json.JSONDecodeError
                    ) as e:
                log.warning("snapshot_invalid", step=step, error=str(e))
        return None
