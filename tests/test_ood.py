import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep: shim keeps collection
    from hypothesis_shim import given, settings, st


from repro.core.ood import (auroc, calibrate_threshold, msp_confidence,
                            roc_curve, select_id_subset, sequence_confidence)


def test_msp_confidence_range():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(32, 10)) * 3)
    conf = msp_confidence(logits)
    assert (np.asarray(conf) >= 1.0 / 10 - 1e-6).all()
    assert (np.asarray(conf) <= 1.0).all()


def test_confident_logits_have_high_msp():
    logits = jnp.zeros((4, 10)).at[:, 0].set(20.0)
    assert np.asarray(msp_confidence(logits)).min() > 0.99


def test_calibration_separates_gaussians():
    rng = np.random.default_rng(0)
    id_scores = jnp.asarray(rng.normal(0.8, 0.05, size=500))
    ood_scores = jnp.asarray(rng.normal(0.3, 0.05, size=500))
    t = float(calibrate_threshold(id_scores, ood_scores))
    assert 0.4 < t < 0.75
    mask = select_id_subset(id_scores, t)
    assert np.asarray(mask).mean() > 0.95
    assert np.asarray(select_id_subset(ood_scores, t)).mean() < 0.05


def test_auroc_extremes():
    rng = np.random.default_rng(1)
    sep_id = jnp.asarray(rng.normal(1.0, 0.01, 400))
    sep_ood = jnp.asarray(rng.normal(0.0, 0.01, 400))
    assert float(auroc(sep_id, sep_ood)) > 0.99
    same = jnp.asarray(rng.normal(0.5, 0.1, 400))
    assert 0.4 < float(auroc(same, same)) < 0.6


@given(mu_gap=st.floats(0.05, 1.0), sigma=st.floats(0.01, 0.3))
@settings(max_examples=15, deadline=None)
def test_youden_threshold_is_optimal(mu_gap, sigma):
    """Property: t_opt maximizes TPR−FPR over the sweep grid."""
    rng = np.random.default_rng(42)
    id_s = jnp.asarray(rng.normal(0.5 + mu_gap, sigma, 300))
    ood_s = jnp.asarray(rng.normal(0.5, sigma, 300))
    ts, tpr, fpr = roc_curve(id_s, ood_s)
    t_opt = calibrate_threshold(id_s, ood_s)
    j_opt = float(jnp.max(tpr - fpr))
    i = int(jnp.argmin(jnp.abs(ts - t_opt)))
    assert float(tpr[i] - fpr[i]) == pytest.approx(j_opt, abs=1e-6)


def test_sequence_confidence_shape():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, 32)))
    assert sequence_confidence(logits).shape == (4,)
