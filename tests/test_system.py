"""End-to-end behaviour tests for the IDKD system (CPU-reduced scale).

These are the integration tests of the paper's Algorithm 1: a real
decentralized run over the simulator with non-IID data, the IDKD round
firing mid-training, and its observable effects (ID filtering, histogram
flattening, accuracy).
"""
import numpy as np
import pytest

from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.idkd import skew_metric
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import make_classification_data, make_public_data
import jax.numpy as jnp


@pytest.fixture(scope="module")
def tiny_data():
    data = make_classification_data(image_size=8, n_train=768, n_val=128,
                                    n_test=256, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=256, kind="aligned", seed=1)
    return data, pub


def _cfg(**kw):
    base = dict(algorithm="qg-dsgdm-n", num_nodes=4, alpha=0.05, steps=30,
                batch_size=16, lr=0.3, seed=4,
                idkd=IDKDConfig(start_step=20, temperature=10.0))
    base.update(kw)
    return TrainConfig(**base)


@pytest.fixture(scope="module")
def mcfg():
    return SMALL_CONFIG.replace(image_size=8)


def test_training_reduces_loss(tiny_data, mcfg):
    data, pub = tiny_data
    sim = DecentralizedSimulator(mcfg, _cfg(steps=25), data, None,
                                 kd_mode=None, eval_every=24)
    r = sim.run()
    assert len(r.acc_history) >= 2
    assert r.acc_history[-1] > 0.15          # better than 10-class chance
    assert np.isfinite(r.loss_history).all()


def test_idkd_round_fires_and_filters(tiny_data, mcfg):
    data, pub = tiny_data
    sim = DecentralizedSimulator(mcfg, _cfg(), data, pub, kd_mode="idkd",
                                 eval_every=29)
    r = sim.run()
    assert 0.0 < r.id_fraction < 1.0, "MSP filter kept everything/nothing"
    assert r.thresholds is not None and (r.thresholds > 0).all()
    assert r.post_hist is not None


def test_idkd_homogenizes_class_distribution(tiny_data, mcfg):
    """Paper Fig. 3a: post-IDKD per-node class histograms are flatter."""
    data, pub = tiny_data
    sim = DecentralizedSimulator(mcfg, _cfg(steps=40,
                                            idkd=IDKDConfig(start_step=30)),
                                 data, pub, kd_mode="idkd", eval_every=39)
    r = sim.run()
    pre = float(skew_metric(jnp.asarray(r.pre_hist)))
    post = float(skew_metric(jnp.asarray(r.post_hist)))
    assert post < pre, f"IDKD did not reduce skew ({pre:.3f} -> {post:.3f})"


def test_vanilla_kd_keeps_whole_public_set(tiny_data, mcfg):
    data, pub = tiny_data
    sim = DecentralizedSimulator(mcfg, _cfg(), data, pub, kd_mode="vanilla",
                                 eval_every=29)
    r = sim.run()
    assert r.id_fraction == pytest.approx(1.0)


def test_centralized_reference_runs(tiny_data, mcfg):
    data, pub = tiny_data
    sim = DecentralizedSimulator(mcfg, _cfg(algorithm="centralized",
                                            steps=20, idkd=None),
                                 data, None, eval_every=19)
    r = sim.run()
    assert np.isfinite(r.acc_history).all()


def test_comm_cost_accounting(tiny_data, mcfg):
    """Label bytes must be a small fraction of cumulative gossip bytes
    (paper Table 6: ~2% overhead)."""
    data, pub = tiny_data
    tcfg = _cfg(steps=30)
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=29)
    r = sim.run()
    total_gossip = r.comm_bytes_per_iter * tcfg.steps
    assert r.label_bytes_total < 0.25 * total_gossip
