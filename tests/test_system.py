"""End-to-end behaviour tests for the IDKD system (CPU-reduced scale).

These are the integration tests of the paper's Algorithm 1: a real
decentralized run over the simulator with non-IID data, the IDKD round
firing mid-training, and its observable effects (ID filtering, histogram
flattening, accuracy).

Each scenario runs at reduced-step "fast" settings by default; the
original full-length settings are the ``full`` parametrizations, marked
``slow`` (deselected by pytest.ini's ``-m "not slow"`` default, run via
``pytest -m slow``).
"""
import numpy as np
import pytest

from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.idkd import skew_metric
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import make_classification_data, make_public_data
import jax.numpy as jnp

MODES = [pytest.param("fast", id="fast"),
         pytest.param("full", id="full", marks=pytest.mark.slow)]


@pytest.fixture(scope="module")
def tiny_data():
    data = make_classification_data(image_size=8, n_train=768, n_val=128,
                                    n_test=256, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=256, kind="aligned", seed=1)
    return data, pub


def _cfg(mode="full", **kw):
    if mode == "fast":
        base = dict(algorithm="qg-dsgdm-n", num_nodes=4, alpha=0.05,
                    steps=14, batch_size=16, lr=0.3, seed=4,
                    idkd=IDKDConfig(start_step=8, temperature=10.0))
    else:
        base = dict(algorithm="qg-dsgdm-n", num_nodes=4, alpha=0.05,
                    steps=30, batch_size=16, lr=0.3, seed=4,
                    idkd=IDKDConfig(start_step=20, temperature=10.0))
    base.update(kw)
    return TrainConfig(**base)


@pytest.fixture(scope="module")
def mcfg():
    return SMALL_CONFIG.replace(image_size=8)


@pytest.fixture(scope="module")
def idkd_fast_run(tiny_data, mcfg):
    """One shared fast IDKD run: the filtering / histogram / comm-cost
    scenarios assert different observables of the same trajectory, so the
    fast variants reuse one simulator (compile once) instead of three."""
    data, pub = tiny_data
    tcfg = _cfg("fast")
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=tcfg.steps - 1)
    return tcfg, sim.run()


@pytest.mark.parametrize("mode", MODES)
def test_training_reduces_loss(tiny_data, mcfg, mode):
    data, pub = tiny_data
    steps = 14 if mode == "fast" else 25
    sim = DecentralizedSimulator(mcfg, _cfg(mode, steps=steps), data, None,
                                 kd_mode=None, eval_every=steps - 1)
    r = sim.run()
    assert len(r.acc_history) >= 2
    assert r.acc_history[-1] > 0.15          # better than 10-class chance
    assert np.isfinite(r.loss_history).all()


@pytest.mark.parametrize("mode", MODES)
def test_idkd_round_fires_and_filters(tiny_data, mcfg, idkd_fast_run, mode):
    if mode == "fast":
        _, r = idkd_fast_run
    else:
        data, pub = tiny_data
        tcfg = _cfg(mode)
        sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                     eval_every=tcfg.steps - 1)
        r = sim.run()
    assert 0.0 < r.id_fraction < 1.0, "MSP filter kept everything/nothing"
    assert r.thresholds is not None and (r.thresholds > 0).all()
    assert r.post_hist is not None


@pytest.mark.parametrize("mode", MODES)
def test_idkd_homogenizes_class_distribution(tiny_data, mcfg, idkd_fast_run,
                                             mode):
    """Paper Fig. 3a: post-IDKD per-node class histograms are flatter."""
    if mode == "fast":
        _, r = idkd_fast_run
    else:
        data, pub = tiny_data
        tcfg = _cfg(mode, steps=40, idkd=IDKDConfig(start_step=30))
        sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                     eval_every=tcfg.steps - 1)
        r = sim.run()
    pre = float(skew_metric(jnp.asarray(r.pre_hist)))
    post = float(skew_metric(jnp.asarray(r.post_hist)))
    assert post < pre, f"IDKD did not reduce skew ({pre:.3f} -> {post:.3f})"


@pytest.mark.parametrize("mode", MODES)
def test_vanilla_kd_keeps_whole_public_set(tiny_data, mcfg, mode):
    data, pub = tiny_data
    tcfg = _cfg(mode)
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="vanilla",
                                 eval_every=tcfg.steps - 1)
    r = sim.run()
    assert r.id_fraction == pytest.approx(1.0)


@pytest.mark.parametrize("mode", MODES)
def test_centralized_reference_runs(tiny_data, mcfg, mode):
    data, pub = tiny_data
    steps = 10 if mode == "fast" else 20
    sim = DecentralizedSimulator(mcfg, _cfg(mode, algorithm="centralized",
                                            steps=steps, idkd=None),
                                 data, None, eval_every=steps - 1)
    r = sim.run()
    assert np.isfinite(r.acc_history).all()


@pytest.mark.parametrize("mode", MODES)
def test_comm_cost_accounting(tiny_data, mcfg, idkd_fast_run, mode):
    """Label bytes must be a small fraction of cumulative gossip bytes
    (paper Table 6: ~2% overhead)."""
    if mode == "fast":
        tcfg, r = idkd_fast_run
    else:
        data, pub = tiny_data
        tcfg = _cfg(mode)
        sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                     eval_every=tcfg.steps - 1)
        r = sim.run()
    total_gossip = r.comm_bytes_per_iter * tcfg.steps
    assert r.label_bytes_total < 0.25 * total_gossip
