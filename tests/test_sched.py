"""Federation scheduler tests (repro.sched + both drivers' ports onto it).

* schedule compiler: degenerate boundaries == the drivers' historical
  ``eval_boundaries``; events land on the right segments; malformed
  schedule params fail loudly;
* degenerate-schedule equivalence: the scheduler-driven simulator
  reproduces a faithful reimplementation of the pre-scheduler loop
  (same steps, samplers, keys) to float tolerance;
* churn: masked Metropolis stays doubly stochastic, frozen nodes hold
  params/opt state, end-to-end runs stay finite and ship fewer bytes;
* repeated rounds: K>1 homogenizations re-label and refresh the sampler
  payload; the ledger buckets gossip + label bytes per round;
* rewire: mid-run graph swap remakes the mixer;
* launch path: K-round churn scenario end-to-end through run_training;
* the bench regression guard's extract/compare logic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core import driver
from repro.core.simulator import DecentralizedSimulator
from repro.core.topology import Topology
from repro.data.synthetic import make_classification_data, make_public_data


@pytest.fixture(scope="module")
def tiny_data():
    data = make_classification_data(image_size=8, n_train=512, n_val=64,
                                    n_test=300, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=96, kind="aligned", seed=1)
    return data, pub


@pytest.fixture(scope="module")
def mcfg():
    return SMALL_CONFIG.replace(image_size=8)


# ------------------------------------------------------- schedule compiler
def test_degenerate_boundaries_match_eval_boundaries():
    """The compiled segment spans must be *identical* to the boundaries
    both drivers consumed before the scheduler existed (DESIGN.md §6
    degenerate-schedule equivalence, structural half)."""
    for steps, ee, start in [(8, 3, 4), (300, 50, 180), (20, 7, 0),
                             (10, 100, 5), (6, 2, 5)]:
        s = sched.compile_schedule(steps, ee, round_steps=(start,))
        assert s.boundaries() == driver.eval_boundaries(steps, ee,
                                                        extra=start)
        s0 = sched.compile_schedule(steps, ee)
        assert s0.boundaries() == driver.eval_boundaries(steps, ee)
        # eval flags reproduce the drivers' historical eval rule
        for seg in s.segments:
            last = seg.stop - 1
            assert seg.eval_after == (last % ee == 0 or last == steps - 1)


def test_events_attach_to_their_segment_in_order():
    ev = [sched.ChurnEvent(step=6, down=(1,)),
          sched.RewireEvent(step=6, topology="full")]
    s = sched.compile_schedule(12, 4, round_steps=(6,), events=ev)
    seg = next(g for g in s.segments if g.start == 6)
    # churn/rewire fire before the homogenization round at the same step
    assert isinstance(seg.events[-1], sched.HomogenizeEvent)
    assert {type(e) for e in seg.events[:-1]} == {sched.ChurnEvent,
                                                  sched.RewireEvent}
    assert s.round_steps == (6,)
    # every event step is a chunk boundary
    assert 6 in {g.start for g in s.segments}


def test_unknown_schedule_params_fail_loudly():
    with pytest.raises(TypeError, match="unknown schedule event"):
        sched.compile_schedule(10, 5, events=[object()])
    with pytest.raises(ValueError, match="churn mode"):
        sched.compile_schedule(
            10, 5, events=[sched.ChurnEvent(step=2, down=(0,),
                                            mode="pause")])
    with pytest.raises(ValueError, match="names no"):
        sched.compile_schedule(10, 5, events=[sched.ChurnEvent(step=2)])
    with pytest.raises(ValueError, match="outside"):
        sched.compile_schedule(10, 5, round_steps=(10,))
    with pytest.raises(ValueError, match="outside"):
        sched.compile_schedule(
            10, 5, events=[sched.RewireEvent(step=11)])
    with pytest.raises(ValueError, match="every_k_steps"):
        sched.idkd_round_steps(IDKDConfig(start_step=0, num_rounds=3,
                                          every_k_steps=0), 100)
    with pytest.raises(ValueError, match="malformed churn spec"):
        sched.parse_churn("3@@5", 8, 100)
    with pytest.raises(ValueError, match="churn node"):
        sched.parse_churn("9@5-7", 8, 100)


def test_idkd_round_steps_spacing_and_clipping():
    cfg = IDKDConfig(start_step=10, every_k_steps=20, num_rounds=4)
    assert sched.idkd_round_steps(cfg, 100) == (10, 30, 50, 70)
    assert sched.idkd_round_steps(cfg, 45) == (10, 30)   # clipped
    assert sched.idkd_round_steps(
        IDKDConfig(start_step=10, num_rounds=0), 100) == ()
    assert sched.idkd_round_steps(
        IDKDConfig(start_step=-1), 100) == ()
    # the paper's default: one round at start_step
    assert sched.idkd_round_steps(IDKDConfig(start_step=7), 100) == (7,)


def test_resume_validation():
    s = sched.compile_schedule(12, 4, round_steps=(4, 8))
    s.validate_resume(0)
    s.validate_resume(8)             # a round boundary — legal
    with pytest.raises(ValueError, match="not a segment boundary"):
        s.validate_resume(3)
    with pytest.raises(ValueError, match="round boundary"):
        s.validate_resume(5)         # past round 4, not itself a round


# ---------------------------------------------------------------- ledger
def test_ledger_gossip_and_label_accounting():
    topo = Topology.make("ring", 4)
    per_step = sched.gossip_bytes_per_step(topo, None, param_count=10,
                                           elem_bytes=4)
    assert per_step.tolist() == [80, 80, 80, 80]     # deg 2 · 10 · 4
    act = np.array([True, True, True, False])
    masked = sched.gossip_bytes_per_step(topo, act, 10, 4)
    # node 3 silent; its ring neighbours 0 and 2 each lose one link
    assert masked.tolist() == [40, 80, 40, 0]

    led = sched.CommLedger(4)
    led.log_gossip(0, 0, 5, per_step)
    led.log_gossip(1, 5, 8, masked)
    led.log_labels(1, 5, np.array([100.0, 0.0, 50.0, 0.0]))
    assert led.gossip_bytes == 80 * 4 * 5 + 160 * 3
    assert led.label_bytes == 150.0
    assert led.gossip_steps() == 8
    rounds = led.per_round()
    assert [r["round"] for r in rounds] == [0, 1]
    assert rounds[0]["gossip_bytes"] == 1600.0
    assert rounds[1]["labels_bytes"] == 150.0
    assert rounds[1]["labels_per_node"] == [100.0, 0.0, 50.0, 0.0]
    assert led.as_dict()["total_bytes"] == led.total_bytes


def test_ledger_compressed_and_stale_accounting():
    """DESIGN.md §9 wire accounting: ``payload_elems`` replaces the raw
    param count, ``index_bytes`` adds the int32 index rider, and stale
    senders ship nothing."""
    topo = Topology.make("ring", 4)
    dense = sched.gossip_bytes_per_step(topo, None, param_count=1000,
                                        elem_bytes=4)
    assert dense.tolist() == [8000] * 4           # deg 2 · 1000 · 4
    comp = sched.gossip_bytes_per_step(topo, None, param_count=1000,
                                       elem_bytes=4, payload_elems=10,
                                       index_bytes=4)
    assert comp.tolist() == [160] * 4             # deg 2 · 10 · (4+4)
    assert dense.sum() / comp.sum() == 50.0       # top-k 1% → 50×
    stale = np.array([False, False, True, False])
    st = sched.gossip_bytes_per_step(topo, None, 1000, 4, payload_elems=10,
                                     index_bytes=4, stale=stale)
    # the straggler ships nothing; its neighbours still send to it
    assert st.tolist() == [160, 160, 0, 160]


def test_ledger_mixed_traffic_per_round():
    """Gossip and label traffic landing in the *same* round bucket with a
    compressed wire: totals decompose exactly and per-round rows stay
    ordered with both kinds accounted."""
    topo = Topology.make("ring", 4)
    comp = sched.gossip_bytes_per_step(topo, None, param_count=1000,
                                       elem_bytes=4, payload_elems=10,
                                       index_bytes=4)
    led = sched.CommLedger(4, meta={"compression": "topk"})
    led.log_gossip(0, 0, 6, comp)                 # round 0: 6 steps
    lab = np.array([300.0, 0.0, 200.0, 100.0])
    led.log_labels(1, 6, lab)                     # the round fires at 6
    led.log_gossip(1, 6, 10, comp)                # round 1: 4 more steps
    led.log_labels(2, 10, lab * 2)
    assert led.total_bytes == led.gossip_bytes + led.label_bytes
    assert led.gossip_bytes == 160 * 4 * (6 + 4)
    assert led.label_bytes == 600.0 + 1200.0
    rows = led.per_round()
    assert [r["round"] for r in rows] == [0, 1, 2]
    # round 1 holds BOTH its label payload and the post-round gossip
    assert rows[1]["labels_bytes"] == 600.0
    assert rows[1]["gossip_bytes"] == 160 * 4 * 4
    assert rows[1]["steps"] == 4
    # round 2 is labels-only (schedule ended at the round step)
    assert rows[2]["gossip_bytes"] == 0.0
    assert rows[2]["labels_bytes"] == 1200.0
    assert rows[2]["labels_per_node"] == (lab * 2).tolist()


def test_ledger_status_attribution_per_round():
    """A 0-byte node is never ambiguous: gossip entries carrying STATUS_*
    codes let ``per_round`` attribute quiet steps as stale (frozen
    outgoing payload) vs inactive (churned out), and legacy entries
    without codes keep the columns at zero."""
    from repro.sched.ledger import (STATUS_ACTIVE, STATUS_INACTIVE,
                                    STATUS_STALE)
    led = sched.CommLedger(4)
    bps = np.array([100.0, 100.0, 0.0, 0.0])
    led.log_gossip(0, 0, 6, bps,
                   status=np.array([STATUS_ACTIVE, STATUS_ACTIVE,
                                    STATUS_STALE, STATUS_INACTIVE]))
    led.log_gossip(0, 6, 10, bps,
                   status=np.array([STATUS_ACTIVE, STATUS_ACTIVE,
                                    STATUS_ACTIVE, STATUS_INACTIVE]))
    led.log_gossip(1, 10, 12, bps)                # no status: unattributed
    rows = led.per_round()
    assert rows[0]["stale_steps_per_node"] == [0, 0, 6, 0]
    assert rows[0]["inactive_steps_per_node"] == [0, 0, 0, 10]
    assert rows[0]["steps"] == 10
    assert rows[1]["stale_steps_per_node"] == [0, 0, 0, 0]
    assert rows[1]["inactive_steps_per_node"] == [0, 0, 0, 0]
    # byte accounting is orthogonal to attribution
    assert rows[0]["gossip_bytes"] == bps.sum() * 10


def test_wire_elem_bytes():
    assert sched.wire_elem_bytes("float32", "bfloat16") == 4
    assert sched.wire_elem_bytes("native", "bfloat16") == 2
    assert sched.wire_elem_bytes("native", "float32") == 4


# ---------------------------------------------------------- frozen nodes
def test_frozen_step_holds_down_nodes():
    n = 3

    def fake_step(params, opt_state, batch, lr):
        upd = jax.tree.map(lambda x: x + 1.0, params)
        opt = {"m": opt_state["m"] + 2.0, "t": opt_state["t"] + 1}
        return upd, opt, jnp.asarray(0.0)

    fake_step.init_opt = lambda p: None
    active = np.array([True, False, True])
    frozen = driver.make_frozen_step(fake_step, active)
    params = {"w": jnp.zeros((n, 2))}
    opt = {"m": jnp.zeros((n,)), "t": jnp.zeros((), jnp.int32)}
    p1, o1, _ = frozen(params, opt, {}, 0.1)
    assert np.allclose(np.asarray(p1["w"]), [[1, 1], [0, 0], [1, 1]])
    assert np.allclose(np.asarray(o1["m"]), [2, 0, 2])
    assert int(o1["t"]) == 1                 # scalar leaves pass through


def test_masked_label_round_excludes_down_nodes():
    from repro.core import labeling
    rng = np.random.default_rng(0)
    n, P, C = 4, 12, 10
    pub_logits = jnp.asarray(rng.normal(size=(n, P, C)), jnp.float32)
    val_logits = jnp.asarray(rng.normal(size=(n, 8, C)), jnp.float32)
    topo = Topology.make("ring", n)
    active = np.array([True, True, False, True])
    out = labeling.label_round(pub_logits, val_logits, None, topo,
                               IDKDConfig(), backend="dense",
                               filter_ood=False, active=active)
    # down node contributes nothing and receives nothing
    assert not np.asarray(out.id_masks)[2].any()
    assert not (np.asarray(out.weights)[2] > 0).any()
    # its neighbours still hear from their other neighbour + themselves
    assert (np.asarray(out.weights)[1] > 0).any()


# ---------------------------------------- degenerate trajectory equivalence
@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_degenerate_schedule_reproduces_legacy_loop(tiny_data, mcfg,
                                                    backend):
    """A 1-round schedule at start_step must reproduce the pre-scheduler
    drivers exactly: this re-implements the seed's hand-rolled outer loop
    (eval_boundaries + one homogenization + sampler swap) against the
    same jitted steps and compares trajectories."""
    data, pub = tiny_data
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=3, alpha=0.05,
                       steps=8, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=4, temperature=10.0,
                                       label_topk=4, label_backend=backend))
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=3)
    res = sim.run()

    # ---- faithful legacy loop (what simulator.run did before the sched)
    from repro.core import labeling
    icfg = tcfg.idkd
    C = mcfg.num_classes
    params = sim._stacked_init()
    opt_state = sim.algo.init(params)
    key = jax.random.PRNGKey(tcfg.seed)
    priv_parts = driver.pad_partitions(sim.parts)
    sampler = driver.make_classification_sampler(
        priv_parts, data.train_x, data.train_y, C, tcfg.batch_size)
    runner = driver.make_runner(sim._plain_step, sampler, sim.lr_fn,
                                sim.driver_mode)
    acc_hist, loss_hist = [], []
    hom = None
    for a, b in driver.eval_boundaries(tcfg.steps, 3, icfg.start_step):
        if hom is None and a == icfg.start_step:
            hom = sim._homogenize(params, icfg)
            sparse_round = isinstance(hom, labeling.SparseHomogenizedSet)
            payload = (hom.labels if sparse_round
                       else np.asarray(hom.labels))
            pub_parts = driver.pad_partitions(
                [np.flatnonzero(w > 0) for w in np.asarray(hom.weights)])
            sampler = driver.make_homogenized_sampler(
                priv_parts, pub_parts, data.train_x, data.train_y, pub,
                np.asarray(hom.weights), payload, C, tcfg.batch_size)
            step_fn = (sim._sparse_kd_step if sparse_round
                       else sim._kd_step)
            runner = driver.make_runner(step_fn, sampler, sim.lr_fn,
                                        sim.driver_mode)
        params, opt_state, key, _ = runner(
            params, opt_state, key, jnp.asarray(a, jnp.int32), b - a)
        last = b - 1
        if last % 3 == 0 or last == tcfg.steps - 1:
            acc, nll = sim._eval(params)
            acc_hist.append(acc)
            loss_hist.append(nll)

    assert np.allclose(res.acc_history, acc_hist, atol=1e-5)
    assert np.allclose(res.loss_history, loss_hist, atol=1e-4)


# ------------------------------------------------------------ multi-round
def test_multi_round_refreshes_sampler_and_ledger(tiny_data, mcfg):
    data, pub = tiny_data
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=3, alpha=0.05,
                       steps=10, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=2, every_k_steps=3,
                                       num_rounds=3, temperature=10.0,
                                       label_topk=4,
                                       label_backend="sparse"))
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=4)
    r = sim.run()
    assert [d["step"] for d in r.rounds] == [2, 5, 8]
    assert np.isfinite(r.acc_history).all()
    label_rows = [row for row in r.ledger["per_round"]
                  if row["labels_bytes"] > 0]
    assert len(label_rows) == 3              # one label exchange per round
    assert r.label_bytes_total == sum(row["labels_bytes"]
                                      for row in label_rows)
    # gossip covers every training step across the buckets
    assert sum(row["steps"] for row in r.ledger["per_round"]) == tcfg.steps


# ------------------------------------------------------------------ churn
def test_churn_scenario_end_to_end_and_cheaper(tiny_data, mcfg):
    data, pub = tiny_data
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=4, alpha=0.05,
                       steps=10, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=3, every_k_steps=4,
                                       num_rounds=2, temperature=10.0))
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=4)
    static = sim.run()
    events = [sched.ChurnEvent(step=3, down=(3,)),
              sched.ChurnEvent(step=7, up=(3,))]
    schedule = sched.compile_schedule(
        tcfg.steps, 4, round_steps=sim.default_schedule().round_steps,
        events=events)
    churned = sim.run(schedule=schedule)
    assert np.isfinite(churned.acc_history).all()
    # the down window ships fewer parameter bytes than the static run
    assert churned.ledger["gossip_bytes"] < static.ledger["gossip_bytes"]
    per_node = np.sum([row["gossip_per_node"]
                       for row in churned.ledger["per_round"]], axis=0)
    assert per_node[3] < per_node[1]          # node 3 was silent for a span


def test_freeze_vs_isolate_node_semantics_end_to_end(tiny_data, mcfg):
    """Straggler (isolate) nodes keep taking local steps while off the
    wire; frozen nodes hold their params entirely. Verified end to end
    through the scheduler by capturing node params at the down boundary
    and at the end of the run (same seed → comparable captures)."""
    data, _ = tiny_data
    topo = Topology.make("ring", 4)
    W = topo.mixing_matrix(np.array([True, True, False, True]))
    assert W[2, 2] == 1.0 and W[2].sum() == 1.0   # identity row off-wire

    tcfg = TrainConfig(algorithm="dsgd", num_nodes=4, alpha=0.1, steps=6,
                       batch_size=8, lr=0.3, seed=7)

    def node2_params(mode):
        sim = DecentralizedSimulator(mcfg, tcfg, data, None, kd_mode=None,
                                     eval_every=5)
        schedule = sched.compile_schedule(
            tcfg.steps, 5,
            events=[sched.ChurnEvent(step=2, down=(2,), mode=mode)])
        at_down = sim.run(schedule=schedule,
                          capture_at=2).captured["params"]
        at_end = sim.run(schedule=schedule,
                         capture_at=tcfg.steps).captured["params"]
        return (np.asarray(jax.tree.leaves(at_down)[0][2], np.float32),
                np.asarray(jax.tree.leaves(at_end)[0][2], np.float32))

    frozen_down, frozen_end = node2_params("freeze")
    assert np.array_equal(frozen_down, frozen_end)       # held exactly
    iso_down, iso_end = node2_params("isolate")
    assert not np.array_equal(iso_down, iso_end)         # kept training


def test_mixed_churn_modes_coexist():
    """A later isolate event must not rewrite an earlier freeze event's
    semantics: each ChurnEvent's mode applies to its own nodes."""
    seen = []

    class Spy(sched.FederationHooks):
        def on_topology(self, topology, active, frozen, stale):
            seen.append(("topo", active.copy(), frozen.copy()))

        def runner(self, topology, active, frozen, stale):
            seen.append(("runner", active.copy(), frozen.copy()))
            return lambda p, o, k, s0, ns: (p, o, k, np.zeros(ns))

    s = sched.compile_schedule(6, 6, events=[
        sched.ChurnEvent(step=1, down=(1,), mode="freeze"),
        sched.ChurnEvent(step=2, down=(2,), mode="isolate")])
    topo = Topology.make("ring", 4)
    sched.run_schedule(s, Spy(), {}, {}, jax.random.PRNGKey(0),
                       topology=topo)
    runner_states = [x for x in seen if x[0] == "runner"]
    # after the second event: nodes 1 and 2 both down, only node 1 frozen
    _, active, frozen = runner_states[-1]
    assert not active[1] and not active[2]
    assert frozen[1] and not frozen[2]


# ----------------------------------------------------------------- rewire
def test_rewire_swaps_gossip_graph(tiny_data, mcfg):
    data, _ = tiny_data
    tcfg = TrainConfig(algorithm="dsgd", num_nodes=4, alpha=0.1, steps=6,
                       batch_size=8, lr=0.2, seed=7)
    sim = DecentralizedSimulator(mcfg, tcfg, data, None, kd_mode=None,
                                 eval_every=5)
    schedule = sched.compile_schedule(
        tcfg.steps, 5, events=[sched.RewireEvent(step=3, topology="full")])
    r = sim.run(schedule=schedule)
    assert np.isfinite(r.acc_history).all()
    full_key = Topology.make("full", 4).edge_key()
    assert any(k[0] == full_key for k in sim._fed._mixers)
    # ledger sees the degree jump: ring gossips 2 links/node, full 3
    rows = r.ledger["per_round"]
    assert rows[0]["gossip_per_node"][0] > 0


# ------------------------------------------------------- launch (LM) path
def test_lm_multi_round_churn_schedule():
    from repro.configs import get_config
    from repro.launch.train import run_training
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    tcfg = TrainConfig(num_nodes=2, steps=8, lr=0.1, alpha=0.1,
                       batch_size=4,
                       idkd=IDKDConfig(start_step=3, every_k_steps=3,
                                       num_rounds=2, label_topk=4,
                                       kd_weight=0.3))
    out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                       use_idkd=True, log_every=4, verbose=False,
                       events=[sched.ChurnEvent(step=4, down=(1,)),
                               sched.ChurnEvent(step=6, up=(1,))])
    assert all(np.isfinite(out["loss_history"]))
    led = out["ledger"]
    assert led["label_bytes"] > 0
    assert len([r for r in led["per_round"] if r["labels_bytes"] > 0]) == 2
    assert out["schedule"].round_steps == (3, 6)


# -------------------------------------------------- bench regression guard
def test_check_regression_extract_and_compare(capsys):
    from benchmarks.check_regression import compare, extract_metrics
    doc = {"meta": {"what": "x"},
           "cells": [
               {"path": "sim", "kd": False, "mode": "scan",
                "us_per_step": 100.0},
               {"scenario": "churn", "rounds_requested": 4,
                "us_per_step": 50.0, "wall_s": 1.0},
           ]}
    base = extract_metrics(doc)
    assert len(base) == 3
    fresh = {k: v * 1.6 for k, v in base.items()}
    assert compare(base, fresh, threshold=1.5) == 3
    assert compare(base, {k: v * 1.2 for k, v in base.items()},
                   threshold=1.5) == 0
    # partially disjoint names are reported but don't fail...
    partial = dict(base)
    partial["extra/us_per_step"] = 1.0
    assert compare(base, partial, threshold=1.5) == 0
    # ...but zero overlap (schema drift) fails loudly
    assert compare(base, {"other": 1.0}, threshold=1.5) == 1
