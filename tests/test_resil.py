"""Resilience chaos suite (DESIGN.md §12).

The load-bearing guarantees, in order of importance:

* **observation-only guards** — fixed-seed runs with the on-device
  health guard carry enabled are *bitwise* identical to guard-off runs
  (sim scan + shard drivers, and the LM launch path);
* **detected-corrupt ≡ drop** — an injected NaN/Inf/bitflip wire payload
  fails receive-side validation and is treated exactly as a dropped
  message: the two trajectories are bitwise equal, and with guards on
  the corrupting sender is attributed and quarantined within one
  segment;
* **durable crash recovery** — a mid-schedule ``crash`` fault kills the
  run; re-invoking with the same snapshot dir auto-resumes from the
  newest valid snapshot (restoring the mid-phase KD sampler ctx from
  the sidecar) and rejoins the uninterrupted trajectory;
* **rollback-on-divergence** — with receive-side validation off the
  corruption genuinely poisons receivers; the guard flush detects it,
  restores the pre-segment state, quarantines the attributed offender,
  and re-runs the segment clean.

Plus unit coverage for the fault/guard/snapshot building blocks.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core import mixing
from repro.core.simulator import DecentralizedSimulator
from repro.core.topology import Topology
from repro.data.synthetic import make_classification_data, make_public_data
from repro.obs import Telemetry, read_events, validate_runlog
from repro.resil import (GuardSpec, Resilience, SimulatedCrash, WireFault,
                         faults, guards)
from repro.resil.snapshot import SnapshotManager

N = 3
STEPS = 12


@pytest.fixture(scope="module")
def tiny_data():
    data = make_classification_data(image_size=8, n_train=512, n_val=64,
                                    n_test=300, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=96, kind="aligned", seed=1)
    return data, pub


@pytest.fixture(scope="module")
def mcfg():
    # im2col keeps the conv model on the scan/shard fast path on CPU
    return SMALL_CONFIG.replace(image_size=8, conv_backend="im2col")


def _tcfg() -> TrainConfig:
    return TrainConfig(algorithm="qg-dsgdm-n", num_nodes=N, alpha=0.05,
                       steps=STEPS, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=4, every_k_steps=4,
                                       num_rounds=2, temperature=10.0,
                                       label_topk=4,
                                       label_backend="sparse"))


def _sim(tiny_data, mcfg, **kw):
    data, pub = tiny_data
    return DecentralizedSimulator(mcfg, _tcfg(), data, pub, kd_mode="idkd",
                                  eval_every=3, **kw)


def _fault_schedule(spec: str):
    t = _tcfg()
    return sched.compile_schedule(
        t.steps, 3, round_steps=sched.idkd_round_steps(t.idkd, t.steps),
        events=sched.parse_faults(spec, t.num_nodes, t.steps),
        gossip="sync")


# ------------------------------------------------- guards are observers
@pytest.mark.parametrize("mode", ["scan", "shard"])
def test_guard_bitwise_noop(tiny_data, mcfg, mode):
    """Guard carry on, no fault: bitwise the base trajectory."""
    sim = _sim(tiny_data, mcfg, driver_mode=mode)
    base = sim.run()
    guarded = sim.run(resil=Resilience(guard=GuardSpec(
        loss_spike_factor=100.0, consensus_max=1e6)))
    assert base.acc_history == guarded.acc_history
    assert base.loss_history == guarded.loss_history


def test_lm_guard_bitwise_noop():
    from repro.configs import get_config
    from repro.launch.train import run_training
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    tcfg = TrainConfig(num_nodes=2, steps=6, lr=0.1, alpha=0.1, batch_size=4,
                       idkd=IDKDConfig(start_step=3, label_topk=4,
                                       kd_weight=0.3))
    hist = {}
    for resil in (None, Resilience(guard=GuardSpec())):
        out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                           use_idkd=True, log_every=2, verbose=False,
                           resil=resil)
        hist[resil is None] = out["loss_history"]
    assert hist[True] == hist[False]


# --------------------------------------------- corrupt ≡ drop + quarantine
def test_corrupt_equals_drop_bitwise(tiny_data, mcfg):
    """Receive-side validation turns a corrupted payload into a dropped
    one: the two runs (no guards — detection only) are bitwise equal."""
    sim = _sim(tiny_data, mcfg)
    runs = {}
    for spec in ("corrupt@5/1/nan", "drop@5/1"):
        runs[spec] = sim.run(schedule=_fault_schedule(spec))
    a, b = runs.values()
    assert a.acc_history == b.acc_history
    assert a.loss_history == b.loss_history
    assert all(np.isfinite(a.acc_history))


def test_corrupt_offender_quarantined(tiny_data, mcfg):
    """With guards on, wire attribution quarantines the corrupting
    sender at the first segment boundary after the fault — and nobody
    else; trajectory stays finite."""
    sim = _sim(tiny_data, mcfg)
    r = sim.run(schedule=_fault_schedule("corrupt@5/1/inf"),
                resil=Resilience(guard=GuardSpec()))
    assert all(np.isfinite(r.acc_history))
    q = np.sum([row["quarantined_steps_per_node"]
                for row in r.ledger["per_round"]], axis=0)
    assert q[1] > 0 and q[0] == 0 and q[2] == 0
    # fault at 5, segment boundaries every 3 ⇒ quarantined from step 7
    # on: the node sits out the remaining 5 steps
    assert q[1] == STEPS - 7


def test_drop_is_network_fault_no_quarantine(tiny_data, mcfg):
    """A dropped payload degrades the mix but indicts nobody: drop is a
    network fault, not sender misbehaviour."""
    sim = _sim(tiny_data, mcfg)
    r = sim.run(schedule=_fault_schedule("drop@5/1"),
                resil=Resilience(guard=GuardSpec()))
    q = np.sum([row["quarantined_steps_per_node"]
                for row in r.ledger["per_round"]], axis=0)
    assert not q.any()


# ------------------------------------------------ crash + auto-resume
def test_crash_auto_resume(tiny_data, mcfg, tmp_path):
    """Crash mid-segment after the second KD round; the resumed
    invocation restores params/opt/key/comm *and* the KD sampler ctx
    from the snapshot sidecar (step 7 is not a round boundary) and
    rejoins the uninterrupted trajectory."""
    sim = _sim(tiny_data, mcfg)
    base = sim.run()
    schedule = _fault_schedule("crash@9")
    res = Resilience(snapshot_dir=str(tmp_path), snapshot_every=3)
    with pytest.raises(SimulatedCrash):
        sim.run(schedule=schedule, resil=res)
    assert (tmp_path / "crash-00000009.tomb").exists()
    r = sim.run(schedule=schedule, resil=res)   # same invocation again
    tail = len(r.loss_history)
    assert tail >= 1
    assert np.allclose(r.loss_history, base.loss_history[-tail:],
                       rtol=1e-5)
    assert np.allclose(r.acc_history, base.acc_history[-len(r.acc_history):],
                       atol=1e-5)


# --------------------------------------------- rollback-on-divergence
def test_rollback_on_divergence(tiny_data, mcfg, tmp_path):
    """validate_wire=False lets the NaN corruption genuinely poison
    receivers; the guard flush detects the blowup, rolls the segment
    back to the pre-segment state, quarantines the attributed offender
    (max wire_invalid count — victims trip later), and the re-run stays
    finite."""
    sim = _sim(tiny_data, mcfg)
    tel = Telemetry(tmp_path)
    r = sim.run(schedule=_fault_schedule("corrupt@5/1/nan"),
                resil=Resilience(guard=GuardSpec(validate_wire=False),
                                 rollback=True),
                telemetry=tel)
    tel.close()
    assert all(np.isfinite(r.acc_history))
    assert all(np.isfinite(r.loss_history))
    q = np.sum([row["quarantined_steps_per_node"]
                for row in r.ledger["per_round"]], axis=0)
    assert q[1] > 0 and q[0] == 0 and q[2] == 0
    validate_runlog(tmp_path / "run.jsonl")
    rollbacks = read_events(tmp_path / "run.jsonl", "rollback")
    assert len(rollbacks) >= 1 and rollbacks[0]["retry"] == 1
    health = read_events(tmp_path / "run.jsonl", "health")
    assert any(e.get("action") == "quarantine" for e in health)


# -------------------------------------------------------- unit: faults
def test_wire_fault_frozen_hashable():
    wf = WireFault(drop=(3, 1, 1), corrupt=(2,), mode="inf")
    assert wf.drop == (1, 3) and wf.senders == (1, 2, 3)
    assert hash(wf) == hash(WireFault(drop=(1, 3), corrupt=(2,), mode="inf"))
    assert WireFault().is_noop() and not wf.is_noop()
    with pytest.raises(ValueError, match="corruption mode"):
        WireFault(corrupt=(0,), mode="gamma-ray")


def test_parse_faults():
    evs = sched.parse_faults("corrupt@8/2/nan, drop@5/0+3, crash@14", 4, 20)
    assert [(e.kind, e.step, e.nodes) for e in evs] == [
        ("corrupt", 8, (2,)), ("drop", 5, (0, 3)), ("crash", 14, ())]
    with pytest.raises(ValueError, match="malformed"):
        sched.parse_faults("corrupt@x", 4, 20)
    with pytest.raises(ValueError, match="unknown fault kind"):
        sched.parse_faults("melt@3", 4, 20)
    with pytest.raises(ValueError, match="outside"):
        sched.parse_faults("drop@5/9", 4, 20)
    with pytest.raises(ValueError, match="outside"):
        sched.parse_faults("drop@99/1", 4, 20)


@pytest.mark.parametrize("mode", ["nan", "inf", "bitflip"])
def test_validated_mixer_corrupt_equals_drop_unit(mode):
    """Per-leaf: every corruption mode fails validation and reduces to
    the masked-Metropolis drop of the same sender."""
    topo = Topology.make("ring", 5)
    W = topo.mixing_matrix()
    base = mixing.make_mixer(topo, backend="dense")
    x = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(5, 4, 3)),
                          jnp.float32)}
    corrupt = faults.make_validated_mixer(base, W,
                                          WireFault(corrupt=(2,), mode=mode))
    drop = faults.make_validated_mixer(base, W, WireFault(drop=(2,)))
    np.testing.assert_array_equal(np.asarray(corrupt(x)["w"]),
                                  np.asarray(drop(x)["w"]))
    # sender attribution: corruption indicts node 2; drop indicts nobody
    assert np.asarray(corrupt.wire_check(x)).tolist() == [
        False, False, True, False, False]
    assert not np.asarray(drop.wire_check(x)).any()


def test_validated_mixer_all_valid_is_base():
    topo = Topology.make("ring", 4)
    base = mixing.make_mixer(topo, backend="dense")
    wrapped = faults.make_validated_mixer(base, topo.mixing_matrix(),
                                          WireFault(drop=(3,)))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 6)),
                    jnp.float32)
    # huge-but-bounded values pass validation; the degraded path only
    # fires for the dropped sender, everything else mixes as base
    y = wrapped.mix_leaf(x)
    assert np.isfinite(np.asarray(y)).all()


def test_validated_mixer_propagation():
    """validate=False: the NaN payload genuinely reaches every receiver
    adjacent to the corrupting sender — and only those."""
    topo = Topology.make("ring", 5)
    base = mixing.make_mixer(topo, backend="dense")
    mix = faults.make_validated_mixer(base, topo.mixing_matrix(),
                                      WireFault(corrupt=(2,)),
                                      validate=False)
    x = jnp.ones((5, 3), jnp.float32)
    bad = ~np.isfinite(np.asarray(mix.mix_leaf(x))).all(axis=1)
    assert bad.tolist() == [False, True, False, True, False]
    with pytest.raises(ValueError, match="bitflip"):
        faults.make_validated_mixer(base, topo.mixing_matrix(),
                                    WireFault(corrupt=(2,), mode="bitflip"),
                                    validate=False)


def test_fault_rejected_under_shard(tiny_data, mcfg):
    sim = _sim(tiny_data, mcfg, driver_mode="shard")
    with pytest.raises(ValueError, match="shard"):
        sim.run(schedule=_fault_schedule("corrupt@5/1/nan"))


# -------------------------------------------------------- unit: guards
def test_guard_counters_unit():
    spec = GuardSpec(loss_spike_factor=3.0, warmup_steps=2)
    g = guards.init_node_guard(3)
    params = {"w": jnp.ones((3, 4))}
    grads = {"w": jnp.zeros((3, 4))}
    losses = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(4):
        g = guards.update(g, spec, losses, grads, params)
    s = guards.summarize(g)
    assert s["accum_steps"] == 4
    assert not guards.tripped_nodes(s).any()

    # node 1's loss goes NaN; node 2 spikes 10×; node 0 stays healthy
    g = guards.update(g, spec, jnp.asarray([1.0, jnp.nan, 10.0]),
                      grads, params)
    s = guards.summarize(g)
    assert s["nonfinite_loss"] == [0, 1, 0]
    assert s["loss_spike"] == [0, 0, 1]
    assert guards.tripped_nodes(s).tolist() == [False, True, True]

    # NaN gradient / param detection addresses the offending row only
    g2 = guards.update(guards.reset(g), spec, losses,
                       {"w": grads["w"].at[0, 0].set(jnp.nan)}, params)
    s2 = guards.summarize(g2)
    assert s2["nonfinite_grad"] == [1, 0, 0]
    assert guards.summarize(guards.reset(g2))["nonfinite_grad"] == [0, 0, 0]


def test_wire_offender_attribution():
    s = {k: [0, 0, 0] for k in guards.GUARD_COUNTERS}
    s["wire_invalid"] = [1, 3, 3]
    # poisoned victims fail wire checks too, but strictly later than the
    # true offender — only max-count senders are indicted
    assert guards.wire_offenders(s).tolist() == [False, True, True]
    s["wire_invalid"] = [0, 0, 0]
    assert not guards.wire_offenders(s).any()


# ----------------------------------------------------- unit: snapshots
def test_snapshot_manager_roundtrip_and_skip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0)}, "key": jax.random.PRNGKey(0)}
    mgr = SnapshotManager(tmp_path, every=0, keep=2)
    ctx = {"pub_idx": np.arange(4), "weights": np.ones((2, 4))}
    mgr.save(3, state, ctx=None, phase="plain")
    mgr.save(6, state, ctx=ctx, phase="kd_sparse", fired=1)
    assert mgr.steps() == [3, 6]

    like = jax.tree.map(jnp.zeros_like, state)
    out = mgr.load_latest(like)
    assert out["step"] == 6 and out["phase"] == "kd_sparse"
    assert out["fired"] == 1
    np.testing.assert_array_equal(out["ctx"]["pub_idx"], ctx["pub_idx"])
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["w"]),
                                  np.arange(6.0))

    # truncate the newest snapshot: load_latest skips it and falls back
    (tmp_path / "snap-00000006.npz").write_bytes(b"garbage")
    out = mgr.load_latest(like)
    assert out["step"] == 3 and out["ctx"] is None

    # pruning keeps the newest `keep`
    mgr.save(9, state)
    mgr.save(12, state)
    assert mgr.steps() == [9, 12]


def test_snapshot_ctx_checksum_rejected(tmp_path):
    state = {"w": jnp.arange(3.0)}
    mgr = SnapshotManager(tmp_path, keep=3)
    mgr.save(5, state, ctx={"labels": np.ones(4)}, phase="kd_dense")
    # tamper with the ctx sidecar: the recorded checksum no longer
    # matches, so the whole snapshot is skipped
    np.savez(tmp_path / "snap-00000005.ctx.npz", labels=np.zeros(4))
    assert mgr.load_latest(jax.tree.map(jnp.zeros_like, state)) is None


def test_crash_tombstones(tmp_path):
    mgr = SnapshotManager(tmp_path)
    assert not mgr.crash_seen(9)
    mgr.mark_crash(9)
    assert mgr.crash_seen(9) and not mgr.crash_seen(10)
