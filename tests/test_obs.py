"""Telemetry subsystem tests (DESIGN.md §11).

The load-bearing guarantee: telemetry is *observation only*. Fixed-seed
runs with the metrics bus / run log / trace spans on must be bitwise
identical to runs with them off — sim and LM paths, node-stacked and
sharded drivers (this file runs at 1 device under tier-1 and again at 8
devices in the shard CI job). Plus schema validation for the JSONL run
log and the Chrome trace, the jaxpr audit that the metrics carry adds
no public-stack-shaped intermediate, and the acceptance scenario: one
IDKD run whose run.jsonl alone reconstructs per-node consensus,
thresholds, selected counts, EF residual, and ledger bytes per round.
"""
import json
import logging

import jax
import numpy as np
import pytest

from repro.configs.base import IDKDConfig, TrainConfig
from repro.obs import (EVENT_SCHEMA, RunLog, Telemetry, TraceRecorder, log,
                       read_events, validate_runlog, validate_trace)

N = 4


# ------------------------------------------------------------ obs.log
def test_log_quiet_under_pytest():
    """Default level resolution sees the pytest env and gates at
    WARNING, so converted print sites stay silent in test runs."""
    assert log._default_level() == logging.WARNING


def test_log_set_level_roundtrip(capsys):
    logger = log.get_logger()
    before = logger.level
    try:
        log.set_level("DEBUG")
        assert logger.isEnabledFor(logging.DEBUG)
        log.set_level(logging.ERROR)
        assert not logger.isEnabledFor(logging.WARNING)
    finally:
        logger.setLevel(before)


# --------------------------------------------------------- obs.runlog
def test_runlog_emit_and_validate(tmp_path):
    path = tmp_path / "run.jsonl"
    rl = RunLog(path)
    rl.emit("run_meta", arch="x")
    rl.emit("metrics", step=10, loss=[1.0] * N, consensus=[0.1] * N)
    rl.emit("run_end", rounds=0)
    rl.close()
    counts = validate_runlog(path)
    assert counts == {"run_meta": 1, "metrics": 1, "run_end": 1}
    evs = read_events(path, "metrics")
    assert evs[0]["step"] == 10 and "t" in evs[0]


def test_runlog_rejects_bad_events(tmp_path):
    rl = RunLog(tmp_path / "run.jsonl")
    with pytest.raises(ValueError, match="unknown"):
        rl.emit("not_a_kind")
    with pytest.raises(ValueError, match="missing required"):
        rl.emit("metrics", step=1)          # no loss/consensus
    rl.close()


def test_validate_runlog_rejects_malformed(tmp_path):
    p = tmp_path / "run.jsonl"
    p.write_text("")
    with pytest.raises(ValueError, match="empty"):
        validate_runlog(p)
    p.write_text("not json\n")
    with pytest.raises(ValueError, match="bad JSON"):
        validate_runlog(p)
    p.write_text(json.dumps({"ev": "mystery", "t": 0.0}) + "\n")
    with pytest.raises(ValueError, match="unknown event"):
        validate_runlog(p)
    p.write_text(json.dumps({"ev": "metrics", "t": 0.0, "step": 1}) + "\n")
    with pytest.raises(ValueError, match="missing required"):
        validate_runlog(p)


# ---------------------------------------------------------- obs.trace
def test_trace_spans_export_and_validate(tmp_path):
    tr = TraceRecorder()
    with tr.span("outer", cat="test", idx=0):
        with tr.span("inner"):
            pass
    tr.instant("mark")
    out = tmp_path / "trace.json"
    tr.export(out)
    assert validate_trace(out) == 3
    doc = json.loads(out.read_text())
    durs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    assert durs["outer"]["dur"] >= durs["inner"]["dur"]
    assert durs["outer"]["args"]["idx"] == 0


def test_validate_trace_rejects_malformed(tmp_path):
    p = tmp_path / "trace.json"
    p.write_text(json.dumps({"traceEvents": "nope"}))
    with pytest.raises(ValueError):
        validate_trace(p)
    p.write_text(json.dumps(
        {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0}]}))
    with pytest.raises(ValueError):                 # X without dur/pid
        validate_trace(p)


# ----------------------------------------------------- obs.check CLI
def test_check_cli(tmp_path):
    from repro.obs.check import main
    assert main([str(tmp_path)]) == 1               # no run.jsonl yet
    rl = RunLog(tmp_path / "run.jsonl")
    rl.emit("run_meta")
    rl.close()
    assert main([str(tmp_path)]) == 0
    assert main([str(tmp_path), "--require-trace"]) == 1
    tr = TraceRecorder()
    with tr.span("s"):
        pass
    tr.export(tmp_path / "trace.json")
    assert main([str(tmp_path), "--require-trace"]) == 0


# ------------------------------------------------- metrics-bus invariant
def test_metrics_update_matches_consensus_distance():
    from repro.core.mixing import consensus_distance
    from repro.obs import metrics as obs_metrics
    rng = np.random.default_rng(0)
    params = {"w": rng.normal(size=(N, 6, 3)).astype(np.float32),
              "b": rng.normal(size=(N, 3)).astype(np.float32)}
    grads = jax.tree.map(np.ones_like, params)
    m = obs_metrics.init_node_metrics(N)
    m = obs_metrics.update(m, np.full((N,), 2.0, np.float32), grads, params)
    s = obs_metrics.summarize(m)
    assert s["accum_steps"] == 1
    np.testing.assert_allclose(
        s["consensus_total"], float(consensus_distance(params)), rtol=1e-5)
    np.testing.assert_allclose(s["loss"], [2.0] * N)
    total = sum(g.reshape(N, -1).sum(1) for g in jax.tree.leaves(grads))
    np.testing.assert_allclose(np.square(s["grad_norm"]) * 1, total,
                               rtol=1e-5)
    m = obs_metrics.reset(m)
    assert int(jax.device_get(m["steps"])) == 0


# ------------------------------------------------------- jaxpr audit
def _iter_avals(jaxpr):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval"):
                yield v.aval
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if isinstance(sub, jax.core.Jaxpr):
                    yield from _iter_avals(sub)
                elif inner is not None and isinstance(inner,
                                                      jax.core.Jaxpr):
                    yield from _iter_avals(inner)


def _dense_stack_avals(jaxpr, P, C):
    return [a.shape for a in _iter_avals(jaxpr)
            if getattr(a, "shape", ()) and a.shape[-1] == C
            and P in a.shape[:-1]]


def test_telemetry_step_jaxpr_has_no_public_stack():
    """Extending the PR 5 audit: the metrics update rides the KD step
    without materializing anything shaped like the full public logit
    stack — its intermediates are parameter- and (n,)-shaped only."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import driver
    from repro.core.algorithms import make_algorithm
    from repro.core.mixing import make_mixer
    from repro.core.topology import Topology
    from repro.launch.steps import stack_params
    from repro.models import build_model
    from repro.obs import metrics as obs_metrics

    n, B, S, P = 2, 2, 8, 16
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64, dtype="float32")
    model = build_model(cfg)
    icfg = IDKDConfig(label_topk=4, kd_weight=0.3)
    step = driver.make_step(model, make_algorithm("qg-dsgdm-n"),
                            make_mixer(Topology.make("ring", n)),
                            driver.lm_sparse_kd_adapter(icfg),
                            telemetry=True)
    assert step.metrics
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    opt = step.init_opt(params)
    m0 = obs_metrics.init_node_metrics(n)
    batch = {
        "tokens": jnp.zeros((n, B, S), jnp.int32),
        "labels": jnp.zeros((n, B, S), jnp.int32),
        "pub_tokens": jnp.zeros((n, 2, S), jnp.int32),
        "pub_vals": jnp.zeros((n, 2, S, 4), jnp.float32),
        "pub_idx": jnp.zeros((n, 2, S, 4), jnp.int32),
        "pub_w": jnp.ones((n, 2), jnp.float32),
    }
    jx = jax.make_jaxpr(step)(params, opt, batch,
                              jnp.asarray(0.1, jnp.float32), m0)
    assert not _dense_stack_avals(jx.jaxpr, P, cfg.vocab_size)


# ---------------------------------------- on/off trajectory invariance
def _sim_run(driver_mode, telemetry=None, **idkd_kw):
    from repro.configs.resnet20_cifar import SMALL_CONFIG
    from repro.core.simulator import DecentralizedSimulator
    from repro.data.synthetic import (make_classification_data,
                                      make_public_data)
    data = make_classification_data(image_size=8, n_train=256, n_val=64,
                                    n_test=128, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=64, kind="aligned", seed=1)
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=N, alpha=0.05,
                       steps=8, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=4, temperature=10.0,
                                       label_topk=4,
                                       label_backend="sparse", **idkd_kw))
    mcfg = SMALL_CONFIG.replace(image_size=8, conv_backend="im2col")
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=4, driver_mode=driver_mode)
    return sim.run(telemetry=telemetry)


@pytest.mark.parametrize("driver_mode", ["scan", "shard"])
def test_sim_trajectory_invariant_under_telemetry(driver_mode, tmp_path):
    """Fixed seeds, telemetry fully on vs fully off: identical
    accuracy / loss / consensus trajectories (scan and shard drivers —
    the shard case re-runs at 8 devices in the CI shard job)."""
    off = _sim_run(driver_mode)
    tel = Telemetry(tmp_path, trace=True, meta={"mode": driver_mode})
    on = _sim_run(driver_mode, telemetry=tel)
    tel.close()
    assert off.acc_history == on.acc_history
    assert off.loss_history == on.loss_history
    assert off.consensus_history == on.consensus_history
    counts = validate_runlog(tmp_path / "run.jsonl")
    assert counts["metrics"] > 0 and counts["accuracy"] > 0
    assert validate_trace(tmp_path / "trace.json") > 0
    # the metrics bus agrees with the host-side eval diagnostics: the
    # flush at each eval boundary reconstructs consensus distance
    flushes = {e["step"]: e for e in read_events(tmp_path / "run.jsonl",
                                                 "metrics")}
    evals = read_events(tmp_path / "run.jsonl", "accuracy")
    for ev, cons in zip(evals, on.consensus_history):
        flush = flushes[ev["step"] + 1]     # eval at stop-1, flush at stop
        np.testing.assert_allclose(flush["consensus_total"], cons,
                                   rtol=1e-4)
        np.testing.assert_allclose(ev["consensus"], cons, rtol=1e-6)


def _lm_run(telemetry=None):
    from repro.configs import get_config
    from repro.launch.train import run_training
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    tcfg = TrainConfig(num_nodes=2, steps=6, lr=0.1, alpha=0.1,
                       batch_size=4,
                       idkd=IDKDConfig(start_step=3, label_topk=4,
                                       kd_weight=0.3))
    out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                       use_idkd=True, log_every=2, verbose=False,
                       telemetry=telemetry)
    return out["loss_history"]


def test_lm_trajectory_invariant_under_telemetry(tmp_path):
    off = _lm_run()
    tel = Telemetry(tmp_path, trace=True)
    on = _lm_run(telemetry=tel)
    tel.close()
    assert off == on
    counts = validate_runlog(tmp_path / "run.jsonl")
    assert counts["labels"] == 1 and counts["metrics"] > 0
    lab = read_events(tmp_path / "run.jsonl", "labels")[0]
    assert len(lab["thresholds"]) == 2 and len(lab["selected"]) == 2
    assert 0.0 <= lab["topk_overlap"] <= 1.0


# --------------------------------------------- acceptance scenario
def test_acceptance_idkd_run_reconstructs_from_jsonl(tmp_path):
    """ISSUE 8 acceptance: 4 nodes, ring, 2 label rounds, top-k
    compressed gossip, one stale event — the emitted run.jsonl alone
    reconstructs per-node consensus distance, detector thresholds,
    selected counts, EF residual, and ledger bytes per round, and the
    trace JSON is Perfetto-loadable (validates as Chrome trace_event)."""
    from repro import sched
    from repro.configs.resnet20_cifar import SMALL_CONFIG
    from repro.core.simulator import DecentralizedSimulator
    from repro.data.synthetic import (make_classification_data,
                                      make_public_data)
    data = make_classification_data(image_size=8, n_train=256, n_val=64,
                                    n_test=128, noise=1.0, seed=0)
    pub = make_public_data(data, n_public=64, kind="aligned", seed=1)
    mcfg = SMALL_CONFIG.replace(image_size=8, cnn_stages=(1, 1, 1),
                                cnn_width=8, conv_backend="im2col")
    tcfg = TrainConfig(num_nodes=N, steps=12, batch_size=8, seed=4,
                       topology="ring", compression="topk",
                       compression_frac=0.05,
                       idkd=IDKDConfig(start_step=4, every_k_steps=4,
                                       num_rounds=2, label_topk=4,
                                       label_backend="sparse"))
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=4)
    schedule = sched.compile_schedule(
        tcfg.steps, 4, round_steps=sim.default_schedule().round_steps,
        events=[sched.ChurnEvent(step=2, down=(3,), mode="stale"),
                sched.ChurnEvent(step=8, up=(3,))], gossip=tcfg.gossip)
    tel = Telemetry(tmp_path, trace=True, meta={"scenario": "acceptance"})
    r = sim.run(schedule=schedule, telemetry=tel)
    tel.close()
    validate_runlog(tmp_path / "run.jsonl")
    assert validate_trace(tmp_path / "trace.json") > 0

    # label rounds: thresholds + per-node selected counts, both rounds
    labels = read_events(tmp_path / "run.jsonl", "labels")
    assert [e["round"] for e in labels] == [0, 1]
    for e in labels:
        assert len(e["thresholds"]) == N and len(e["selected"]) == N
    np.testing.assert_allclose(labels[-1]["thresholds"], r.thresholds,
                               rtol=1e-6)

    # metrics bus: per-node consensus + nonzero EF residual (top-k
    # compression leaves most coordinates in the error-feedback state)
    mets = read_events(tmp_path / "run.jsonl", "metrics")
    assert all(len(e["consensus"]) == N and len(e["ef_residual"]) == N
               for e in mets)
    assert any(max(e["ef_residual"]) > 0 for e in mets)

    # comm events reproduce the ledger's per-round gossip bytes and
    # attribute the stale node (status 1 while step 2..8 was in flight)
    comms = read_events(tmp_path / "run.jsonl", "comm")
    gossip = [e for e in comms if e["kind"] == "gossip"]
    by_round = {}
    for e in gossip:
        by_round[e["round"]] = (by_round.get(e["round"], 0)
                                + sum(e["per_node"]))
    for row in r.ledger["per_round"]:
        if row["gossip_bytes"]:
            np.testing.assert_allclose(by_round[row["round"]],
                                       row["gossip_bytes"])
    assert any(e["status"][3] == 1 for e in gossip)      # stale window
    stale_rows = [row for row in r.ledger["per_round"]
                  if any(row["stale_steps_per_node"])]
    assert stale_rows and stale_rows[0]["stale_steps_per_node"][3] > 0

    # topology events carry the mixing rows under churn
    topo_evs = read_events(tmp_path / "run.jsonl", "topology")
    assert len(topo_evs) == 2
    W = np.asarray(topo_evs[0]["mixing_rows"])
    assert W.shape == (N, N)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)


def test_telemetry_off_writes_nothing(tmp_path):
    """A Telemetry with events/metrics disabled is inert — and sim runs
    without the argument never touch the obs layer."""
    tel = Telemetry(None)
    assert tel.runlog is None and tel.tracer is None
    tel.event("run_end")                      # no-op, no crash
    with tel.span("x"):
        pass
    tel.close()
    assert list(tmp_path.iterdir()) == []
