"""Pallas flash-attention kernel vs pure-jnp oracle (interpret mode).

Sweeps shapes/dtypes per the assignment; also cross-checks the model's
chunked_attention (the XLA path used in the dry-run) against both.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.models.attention import chunked_attention


def _rand(shape, dtype, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape),
                       dtype)


@pytest.mark.parametrize("B,S,H,KVH,D", [
    (1, 128, 2, 2, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2:1
    (1, 256, 8, 1, 32),      # MQA (paligemma-style kv=1)
    (2, 128, 4, 4, 128),     # head_dim 128 (qwen3/nemo-style)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, S, H, KVH, D, dtype):
    q = _rand((B, S, H, D), dtype, 1)
    k = _rand((B, S, KVH, D), dtype, 2)
    v = _rand((B, S, KVH, D), dtype, 3)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert out.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("block", [32, 64, 128])
def test_flash_block_shape_invariance(block):
    q = _rand((1, 256, 2, 64), jnp.float32, 4)
    k = _rand((1, 256, 2, 64), jnp.float32, 5)
    v = _rand((1, 256, 2, 64), jnp.float32, 6)
    out = flash_attention(q, k, v, block_q=block, block_k=block,
                          interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_non_causal():
    q = _rand((1, 128, 2, 64), jnp.float32, 7)
    k = _rand((1, 128, 2, 64), jnp.float32, 8)
    v = _rand((1, 128, 2, 64), jnp.float32, 9)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_attention_matches_flash_ref():
    """The model's XLA chunked path is numerically the same algorithm."""
    q = _rand((2, 128, 4, 64), jnp.float32, 10)
    k = _rand((2, 128, 2, 64), jnp.float32, 11)
    v = _rand((2, 128, 2, 64), jnp.float32, 12)
    out = chunked_attention(q, k, v, causal=True, chunk=32)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
