import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixing import (consensus_distance, make_dense_mixer,
                               make_gather_mixer, make_mixer,
                               make_roll_mixer)
from repro.core.topology import Topology
from repro.launch.steps import consensus_params, stack_params


def _stacked(n, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n, 4, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}


def _tree_allclose(a, b, atol=1e-5):
    return all(np.allclose(np.asarray(x), np.asarray(y), atol=atol)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_dense_mixer_preserves_mean():
    n = 8
    W = Topology.make("ring", n).mixing_matrix()
    mix = make_dense_mixer(W)
    x = _stacked(n)
    y = mix(x)
    for k in x:
        assert np.allclose(np.asarray(y[k]).mean(0), np.asarray(x[k]).mean(0),
                           atol=1e-5)


def test_dense_mixer_reduces_consensus_distance():
    n = 8
    mix = make_dense_mixer(Topology.make("ring", n).mixing_matrix())
    x = _stacked(n)
    d0 = float(consensus_distance(x))
    d1 = float(consensus_distance(mix(x)))
    assert d1 < d0


def test_roll_mixer_equals_dense_ring_mixer():
    """The production roll/ppermute mixer must equal the dense MH ring W."""
    n = 8
    x = _stacked(n)
    roll_mix = make_roll_mixer(n)
    W = Topology.make("ring", n).mixing_matrix()  # ring: 1/3,1/3,1/3
    dense_mix = make_dense_mixer(W)
    assert _tree_allclose(roll_mix(x), dense_mix(x))


@pytest.mark.parametrize("n", [1, 2, 3])
def test_roll_mixer_small_n(n):
    x = _stacked(n)
    y = make_roll_mixer(n)(x)
    for k in x:
        assert np.allclose(np.asarray(y[k]).mean(0), np.asarray(x[k]).mean(0),
                           atol=1e-5)
    if n == 1:
        assert np.allclose(np.asarray(y["w"]), np.asarray(x["w"]))


# ----------------------------------------------------- make_mixer backends
@pytest.mark.parametrize("kind,n", [("ring", 8), ("torus", 9), ("full", 6),
                                    ("social", 15), ("chain", 5),
                                    ("exponential", 8)])
def test_gather_mixer_equals_dense(kind, n):
    """Neighbour-gather gossip == dense-W einsum on every topology."""
    topo = Topology.make(kind, n)
    x = _stacked(n, seed=n)
    dense = make_mixer(topo, backend="dense")(x)
    gather = make_mixer(topo, backend="gather")(x)
    assert _tree_allclose(dense, gather)


def test_roll_backend_matches_and_rejects_non_ring():
    topo = Topology.make("ring", 8)
    x = _stacked(8, seed=3)
    assert _tree_allclose(make_mixer(topo, backend="roll")(x),
                          make_mixer(topo, backend="dense")(x))
    with pytest.raises(ValueError, match="ring"):
        make_mixer(Topology.make("torus", 9), backend="roll")


def test_auto_backend_picks_roll_on_ring_gather_elsewhere(monkeypatch):
    ring, torus = Topology.make("ring", 6), Topology.make("torus", 9)
    xr, xt = _stacked(6, seed=1), _stacked(9, seed=2)
    assert _tree_allclose(make_mixer(ring)(xr),
                          make_mixer(ring, backend="dense")(xr))
    assert _tree_allclose(make_mixer(torus)(xt),
                          make_mixer(torus, backend="dense")(xt))
    # pin the *selection*, not just value equality (all backends agree
    # numerically, so a broken _is_ring would otherwise pass silently);
    # sentinels are functions because make_mixer tags its result with a
    # .remake handle
    from repro.core import mixing

    def roll_sentinel(tree):
        return "ROLL"

    def gather_sentinel(tree):
        return "GATHER"

    monkeypatch.setattr(mixing, "make_roll_mixer",
                        lambda n, wd="native": roll_sentinel)
    monkeypatch.setattr(mixing, "make_gather_mixer",
                        lambda t, wd="native", active=None: gather_sentinel)
    assert mixing.make_mixer(ring) is roll_sentinel
    assert mixing.make_mixer(torus) is gather_sentinel


def test_wire_dtype_native_close_to_f32_wire():
    """bf16 params: the native wire halves bytes; values stay close to the
    full-precision wire (f32 accumulate either way)."""
    topo = Topology.make("torus", 9)
    rng = np.random.default_rng(0)
    x = {"w": jnp.asarray(rng.normal(size=(9, 8, 4)), jnp.bfloat16)}
    y_native = make_gather_mixer(topo, wire_dtype="native")(x)
    y_f32 = make_gather_mixer(topo, wire_dtype="float32")(x)
    assert y_native["w"].dtype == jnp.bfloat16
    assert np.allclose(np.asarray(y_native["w"], np.float32),
                       np.asarray(y_f32["w"], np.float32), atol=0.1)


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown mixer backend"):
        make_mixer(Topology.make("ring", 4), backend="nope")


def test_ppermute_backend_rejects_non_ring_and_f32_wire():
    with pytest.raises(ValueError, match="ring"):
        make_mixer(Topology.make("torus", 9), backend="ppermute",
                   axis_names=("data",), axis_sizes=(9,))
    with pytest.raises(ValueError, match="wire_dtype"):
        make_mixer(Topology.make("ring", 4), backend="ppermute",
                   wire_dtype="float32",
                   axis_names=("data",), axis_sizes=(4,))


def test_ppermute_errors_name_the_fallback_backend():
    """Shard-mode misconfigurations must fail eagerly at make_mixer time
    with the node-stacked fallback named, not mid-schedule."""
    with pytest.raises(ValueError, match="gather"):
        make_mixer(Topology.make("torus", 9), backend="ppermute",
                   axis_names=("node",), axis_sizes=(9,))
    with pytest.raises(ValueError, match="gather"):
        make_mixer(Topology.make("ring", 4), backend="ppermute",
                   active=np.asarray([True, False, True, True]),
                   axis_names=("node",), axis_sizes=(4,))


def _shard_mix(mixer, tree, n_local):
    """Run a shard_map mixer on node-stacked data over however many
    devices divide the node axis (1 device → degenerate block mesh)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    n = jax.tree.leaves(tree)[0].shape[0]
    size = n // n_local
    mesh = Mesh(np.asarray(jax.devices()[:size]), ("node",))
    return jax.jit(shard_map(mixer, mesh=mesh, in_specs=(P("node"),),
                             out_specs=P("node"), check_rep=False))(tree)


@pytest.mark.parametrize("n", [2, 3, 8])
def test_block_ppermute_mixer_equals_dense_ring(n):
    """The block ppermute mixer (local node blocks, boundary rows via
    collective-permute) must equal the dense Metropolis ring mix —
    including the n == 2 half/half degenerate weights."""
    from repro.core.mixing import make_ppermute_mixer
    x = _stacked(n, seed=n)
    size = max(d for d in range(1, min(len(jax.devices()), n) + 1)
               if n % d == 0)
    mix = make_ppermute_mixer(("node",), (size,), local_nodes=n // size)
    out = _shard_mix(mix, x, n // size)
    ref = make_mixer(Topology.make("ring", n), backend="dense")(x)
    assert _tree_allclose(out, ref)


def test_psum_mixer_equals_dense_full():
    """Complete-graph shard gossip is one psum — must equal the full
    graph's (uniform 1/n) Metropolis einsum."""
    n = 6
    x = _stacked(n, seed=1)
    size = max(d for d in range(1, min(len(jax.devices()), n) + 1)
               if n % d == 0)
    mix = make_mixer(Topology.make("full", n), backend="ppermute",
                     axis_names=("node",), axis_sizes=(size,),
                     local_nodes=n // size)
    out = _shard_mix(mix, x, n // size)
    ref = make_mixer(Topology.make("full", n), backend="dense")(x)
    assert _tree_allclose(out, ref)


def test_every_backend_exposes_mix_leaf():
    """The per-leaf mixer protocol (mix.mix_leaf + tree.map equivalence)
    is what lets QG-DSGDm-N fuse the gossip mix into its whole-tree
    update pass — every backend must provide it."""
    topo = Topology.make("ring", 6)
    x = _stacked(6, seed=2)
    for backend in ("dense", "gather", "roll"):
        mix = make_mixer(topo, backend=backend)
        assert callable(mix.mix_leaf)
        leafwise = jax.tree.map(mix.mix_leaf, x)
        assert _tree_allclose(leafwise, mix(x))
    from repro.core.mixing import make_ppermute_mixer, make_psum_mixer
    assert callable(make_ppermute_mixer(("node",), (1,),
                                        local_nodes=6).mix_leaf)
    assert callable(make_psum_mixer("node", 6).mix_leaf)


def test_stack_and_consensus_roundtrip():
    p = {"a": jnp.ones((3, 2)), "b": jnp.arange(4.0)}
    s = stack_params(p, 5)
    assert s["a"].shape == (5, 3, 2)
    c = consensus_params(s)
    assert np.allclose(np.asarray(c["a"]), np.asarray(p["a"]))
