import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixing import consensus_distance, make_dense_mixer
from repro.core.topology import Topology
from repro.launch.steps import consensus_params, make_ring_mixer, stack_params


def _stacked(n, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n, 4, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)}


def test_dense_mixer_preserves_mean():
    n = 8
    W = Topology.make("ring", n).mixing_matrix()
    mix = make_dense_mixer(W)
    x = _stacked(n)
    y = mix(x)
    for k in x:
        assert np.allclose(np.asarray(y[k]).mean(0), np.asarray(x[k]).mean(0),
                           atol=1e-5)


def test_dense_mixer_reduces_consensus_distance():
    n = 8
    mix = make_dense_mixer(Topology.make("ring", n).mixing_matrix())
    x = _stacked(n)
    d0 = float(consensus_distance(x))
    d1 = float(consensus_distance(mix(x)))
    assert d1 < d0


def test_roll_mixer_equals_dense_ring_mixer():
    """The production roll/ppermute mixer must equal the dense MH ring W."""
    n = 8
    x = _stacked(n)
    roll_mix = make_ring_mixer(n)
    W = Topology.make("ring", n).mixing_matrix()  # ring: 1/3,1/3,1/3
    dense_mix = make_dense_mixer(W)
    ya, yb = roll_mix(x), dense_mix(x)
    for k in x:
        assert np.allclose(np.asarray(ya[k]), np.asarray(yb[k]), atol=1e-5)


@pytest.mark.parametrize("n", [1, 2, 3])
def test_roll_mixer_small_n(n):
    x = _stacked(n)
    y = make_ring_mixer(n)(x)
    for k in x:
        assert np.allclose(np.asarray(y[k]).mean(0), np.asarray(x[k]).mean(0),
                           atol=1e-5)
    if n == 1:
        assert np.allclose(np.asarray(y["w"]), np.asarray(x["w"]))


def test_stack_and_consensus_roundtrip():
    p = {"a": jnp.ones((3, 2)), "b": jnp.arange(4.0)}
    s = stack_params(p, 5)
    assert s["a"].shape == (5, 3, 2)
    c = consensus_params(s)
    assert np.allclose(np.asarray(c["a"]), np.asarray(p["a"]))
