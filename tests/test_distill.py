import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep: shim keeps collection
    from hypothesis_shim import given, settings, st


from repro.core.distill import (SparseLabels, average_labels, densify_labels,
                                kd_loss, label_bytes, soft_labels,
                                sparse_kd_loss, sparsify_labels)


def test_soft_labels_normalized():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(8, 10)) * 5)
    for T in (1.0, 10.0, 100.0):
        s = soft_labels(logits, T)
        assert np.allclose(np.asarray(s).sum(-1), 1.0, atol=1e-5)
    # higher temperature => flatter labels
    s1 = soft_labels(logits, 1.0)
    s100 = soft_labels(logits, 100.0)
    assert float(jnp.max(s100)) < float(jnp.max(s1))


def test_kd_loss_minimized_at_teacher():
    logits_t = jnp.asarray(np.random.default_rng(0).normal(size=(4, 10)))
    probs = soft_labels(logits_t, 2.0)
    l_same = kd_loss(logits_t, probs, 2.0).mean()
    l_diff = kd_loss(logits_t + 3.0 * jnp.asarray(
        np.random.default_rng(1).normal(size=(4, 10))), probs, 2.0).mean()
    assert float(l_same) < float(l_diff)


def test_average_labels_counts_only_contributors():
    labels = jnp.asarray([[[1.0, 0.0]], [[0.0, 1.0]], [[0.5, 0.5]]])  # (3,1,2)
    mask = jnp.asarray([[True], [True], [False]])
    avg, any_mask = average_labels(labels, mask)
    assert np.allclose(np.asarray(avg[0]), [0.5, 0.5])
    assert bool(any_mask[0])
    avg2, any2 = average_labels(labels, jnp.zeros((3, 1), bool))
    assert not bool(any2[0])


@given(c=st.integers(8, 64), k=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_sparsify_densify_roundtrip(c, k):
    """Property: densify(sparsify(p, k)) keeps exactly the top-k mass."""
    rng = np.random.default_rng(c * 100 + k)
    logits = jnp.asarray(rng.normal(size=(3, c)) * 3)
    probs = soft_labels(logits, 1.0)
    sp = sparsify_labels(probs, k)
    dense = densify_labels(sp, c)
    assert np.allclose(np.asarray(dense).sum(-1), 1.0, atol=1e-5)
    # support is the top-k of the original
    top = np.argsort(-np.asarray(probs), axis=-1)[:, :k]
    nz = np.asarray(dense) > 0
    for row in range(3):
        assert set(np.flatnonzero(nz[row])) <= set(top[row]) | set(
            np.flatnonzero(np.isclose(np.asarray(dense[row]), 0, atol=1e-12)))


def test_sparse_kd_equals_dense_when_full_k():
    rng = np.random.default_rng(0)
    C = 12
    t_logits = jnp.asarray(rng.normal(size=(5, C)) * 2)
    s_logits = jnp.asarray(rng.normal(size=(5, C)) * 2)
    probs = soft_labels(t_logits, 4.0)
    sp = sparsify_labels(probs, C)
    dense = kd_loss(s_logits, probs, 4.0)
    sparse = sparse_kd_loss(s_logits, sp, 4.0)
    assert np.allclose(np.asarray(dense), np.asarray(sparse), atol=1e-4)


def test_label_bytes_sparse_much_smaller():
    dense = label_bytes(1000, 151_936)
    sparse = label_bytes(1000, 151_936, topk=8)
    assert sparse < dense / 1000
