"""Sharded driver tests (driver_mode="shard", DESIGN.md §7).

* shard-vs-stacked fixed-seed trajectory equivalence — plain phase and
  sparse-KD phase, ring and complete-graph topologies, sim and LM paths.
  The node axis moves from a batch dimension (vmap on one device) to a
  placement dimension (shard_map over the node mesh); trajectories must
  match to float tolerance because the samplers consume identical PRNG
  key sequences and the ppermute/psum gossip equals the dense Metropolis
  mix.
* sharded label round: same D_ID masks, thresholds, weights, and
  per-node payload bytes as the node-stacked sparse backend; merged
  payloads agree after densification (contributor order along k may
  differ — every consumer accumulates duplicates).
* eager shard-mode validation: churn schedules, non-ring/complete
  topologies, and the dense label backend fail at construction / run
  start, naming the node-stacked fallback, instead of mid-schedule.
* im2col conv path: forward equality with lax.conv and the auto-mode
  runner resolution it unlocks.

The whole file runs on any device count: with one device the node mesh
is degenerate (shard_map still executes, the block holds every node);
CI's forced-8-device job (XLA_FLAGS=--xla_force_host_platform_device_
count=8) exercises the real multi-device placement and boundary-row
collectives on the same tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core import distill, driver, labeling
from repro.core.simulator import DecentralizedSimulator
from repro.core.topology import Topology
from repro.data.synthetic import make_classification_data, make_public_data
from repro.launch.mesh import make_node_mesh
from repro.sched import compile_schedule, parse_churn


@pytest.fixture(scope="module")
def tiny_data():
    data = make_classification_data(image_size=8, n_train=512, n_val=64,
                                    n_test=300, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=128, kind="aligned", seed=1)
    return data, pub


@pytest.fixture(scope="module")
def mcfg():
    # im2col keeps the conv model on the scan/shard fast path on CPU
    return SMALL_CONFIG.replace(image_size=8, conv_backend="im2col")


def _kd_tcfg(topology: str, n: int = 4) -> TrainConfig:
    return TrainConfig(algorithm="qg-dsgdm-n", num_nodes=n, alpha=0.05,
                       steps=8, batch_size=8, lr=0.3, seed=4,
                       topology=topology,
                       idkd=IDKDConfig(start_step=4, temperature=10.0,
                                       label_topk=4, label_backend="sparse"))


# ---------------------------------------------- shard == stacked (sim path)
@pytest.mark.parametrize("topology,n", [("ring", 4), ("full", 4),
                                        ("ring", 8)])
def test_sim_shard_equals_stacked_kd(tiny_data, mcfg, topology, n):
    """Fixed seeds → the shard_map runner reproduces the node-stacked
    scan runner through the plain phase, the homogenization round, and
    the sparse-KD phase, on both supported gossip graphs. The n=8 case
    exercises the *blocked* node layout wherever the device count is 2
    or 4 (local blocks > 1 row AND > 1 device — boundary-row ppermutes
    plus interior shifts; CI's shard8 job adds a forced-4-device run
    for exactly this regime)."""
    data, pub = tiny_data
    runs = {}
    for mode in ("scan", "shard"):
        sim = DecentralizedSimulator(mcfg, _kd_tcfg(topology, n), data, pub,
                                     kd_mode="idkd", eval_every=3,
                                     driver_mode=mode)
        runs[mode] = sim.run()
    assert np.allclose(runs["shard"].acc_history, runs["scan"].acc_history,
                       atol=1e-5)
    assert np.allclose(runs["shard"].loss_history, runs["scan"].loss_history,
                       atol=1e-4)
    assert np.allclose(runs["shard"].consensus_history,
                       runs["scan"].consensus_history, rtol=0.05, atol=1e-8)
    # ledger accounting is identical: same graph, same payload sizes
    assert runs["shard"].label_bytes_total == runs["scan"].label_bytes_total


def test_sim_shard_equals_stacked_plain(tiny_data, mcfg):
    data, _ = tiny_data
    tcfg = TrainConfig(algorithm="dsgd", num_nodes=4, alpha=0.1, steps=6,
                       batch_size=8, lr=0.2, seed=7)
    runs = {}
    for mode in ("scan", "shard"):
        sim = DecentralizedSimulator(mcfg, tcfg, data, None, kd_mode=None,
                                     eval_every=5, driver_mode=mode)
        runs[mode] = sim.run()
    assert np.allclose(runs["shard"].acc_history, runs["scan"].acc_history,
                       atol=1e-5)


# ----------------------------------------------- shard == stacked (LM path)
def test_lm_shard_equals_stacked():
    from repro.configs import get_config
    from repro.launch.train import run_training
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    tcfg = TrainConfig(num_nodes=2, steps=6, lr=0.1, alpha=0.1, batch_size=4,
                       idkd=IDKDConfig(start_step=3, label_topk=4,
                                       kd_weight=0.3))
    hist = {}
    for mode in ("scan", "shard"):
        out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                           use_idkd=True, log_every=2, verbose=False,
                           driver_mode=mode)
        hist[mode] = out["loss_history"]
    assert np.allclose(hist["shard"], hist["scan"], rtol=1e-4, atol=1e-5)


# --------------------------------------------------- sharded label round
@pytest.mark.parametrize("topology", ["ring", "full"])
def test_shard_label_round_matches_stacked_sparse(tiny_data, mcfg, topology):
    """score/select run shard-local and the exchange moves only top-k
    payloads — the result must agree with the node-stacked sparse
    backend: exact D_ID masks (→ exact per-node payload bytes), same
    thresholds/weights, and equal labels after densification (the
    contributor order along k differs, which no consumer observes)."""
    data, pub = tiny_data
    tcfg = _kd_tcfg(topology, n=4)
    cfg = tcfg.idkd
    sims = {}
    for mode in ("scan", "shard"):
        sims[mode] = DecentralizedSimulator(mcfg, tcfg, data, pub,
                                            kd_mode="idkd", eval_every=3,
                                            driver_mode=mode)
    params = sims["scan"]._stacked_init()
    hom_s = sims["scan"]._homogenize(params, cfg)
    hom_h = sims["shard"]._homogenize(params, cfg)
    assert isinstance(hom_h, labeling.SparseHomogenizedSet)
    id_s, id_h = np.asarray(hom_s.id_masks), np.asarray(hom_h.id_masks)
    assert np.array_equal(id_s, id_h)
    assert np.allclose(np.asarray(hom_s.thresholds),
                       np.asarray(hom_h.thresholds), atol=1e-5)
    assert np.array_equal(np.asarray(hom_s.weights),
                          np.asarray(hom_h.weights))
    # payload width: (max_degree + 1) · k on both paths
    k_out = (Topology.make(topology, 4).max_degree() + 1) * 4
    assert hom_h.labels.values.shape[-1] == k_out
    assert np.allclose(np.asarray(hom_s.densify(10)),
                       np.asarray(hom_h.densify(10)), atol=1e-5)
    # per-node wire bytes (the ledger's label accounting) match exactly
    bytes_s = [distill.label_bytes(int(c), 10, 4) for c in id_s.sum(1)]
    bytes_h = [distill.label_bytes(int(c), 10, 4) for c in id_h.sum(1)]
    assert bytes_s == bytes_h


# ------------------------------------------------- eager shard validation
def test_shard_rejects_churn_schedule_before_running(tiny_data, mcfg):
    data, pub = tiny_data
    tcfg = _kd_tcfg("ring")
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=3, driver_mode="shard")
    schedule = compile_schedule(
        tcfg.steps, 3, round_steps=(4,),
        events=parse_churn("1@2-5", tcfg.num_nodes, tcfg.steps))
    with pytest.raises(ValueError, match="churn"):
        sim.run(schedule)


def test_shard_rejects_unsupported_topology_and_dense_backend(tiny_data,
                                                              mcfg):
    data, pub = tiny_data
    with pytest.raises(ValueError, match="ring/complete"):
        DecentralizedSimulator(
            mcfg, _kd_tcfg("torus", n=9), data, pub, kd_mode="idkd",
            driver_mode="shard")
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=4, steps=8,
                       batch_size=8, seed=4,
                       idkd=IDKDConfig(start_step=4, label_backend="dense"))
    with pytest.raises(ValueError, match="sparse"):
        DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                               driver_mode="shard")


def test_shard_step_rejects_relaysgd(mcfg):
    from repro.core.algorithms import make_algorithm
    from repro.models import build_model
    topo = Topology.make("chain", 4)
    algo = make_algorithm("relaysgd", topology=topo)
    with pytest.raises(ValueError, match="scan"):
        driver.make_shard_step(build_model(mcfg), algo,
                               driver.classification_adapter,
                               mesh=make_node_mesh(4),
                               topology=Topology.make("ring", 4))


# ------------------------------------------------------------- node mesh
def test_make_node_mesh_divides_nodes():
    ndev = len(jax.devices())
    mesh = make_node_mesh(6)
    assert 6 % mesh.shape["node"] == 0
    assert mesh.shape["node"] == max(d for d in range(1, min(ndev, 6) + 1)
                                     if 6 % d == 0)
    assert make_node_mesh(1).shape["node"] == 1


def test_make_node_mesh_prime_warns():
    """A prime node count larger than the device pool has no non-trivial
    divisor: the mesh degrades to fewer devices and the warning names
    the size it picked instead of silently serializing."""
    import warnings
    ndev = len(jax.devices())
    prime = next(p for p in (3, 5, 7, 11, 13, 17) if p > ndev)
    if ndev < 2:
        # a 1-device mesh IS the best fit for a 1-device pool — no noise
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert make_node_mesh(prime).shape["node"] == 1
        return
    with pytest.warns(RuntimeWarning, match="no divisor") as rec:
        mesh = make_node_mesh(prime)
    assert mesh.shape["node"] == 1          # only divisor of prime <= ndev
    assert "using a 1-device node mesh" in str(rec[0].message)
    assert f"num_nodes={prime}" in str(rec[0].message)


def test_make_federation_mesh_factors_grid():
    from repro.launch.mesh import make_federation_mesh
    ndev = len(jax.devices())
    # model_parallel=1 degenerates to the plain 1-D node mesh
    m1 = make_federation_mesh(4, 1)
    assert m1.axis_names == ("node",)
    with pytest.raises(ValueError, match="model_parallel"):
        make_federation_mesh(4, ndev + 1)
    with pytest.raises(ValueError, match="model_parallel"):
        make_federation_mesh(4, 0)
    if ndev >= 2:
        m = make_federation_mesh(4, 2)
        assert m.axis_names == ("node", "model")
        assert m.shape["model"] == 2
        assert 4 % m.shape["node"] == 0
    if ndev >= 8:
        assert dict(make_federation_mesh(4, 2).shape) == \
            {"node": 4, "model": 2}
        assert dict(make_federation_mesh(4, 4).shape) == \
            {"node": 2, "model": 4}


# ------------------------------------------- 2-D mesh (node × model) runs
def _sim_run_2d(mcfg, tcfg, data, pub, model_parallel):
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=3, driver_mode="shard",
                                 model_parallel=model_parallel)
    return sim.run()


@pytest.mark.parametrize("topology", ["ring", "full"])
def test_sim_2d_mesh_equals_1d_shard(tiny_data, mcfg, topology):
    """model_parallel=2 shards every replica's params/optimizer over the
    mesh "model" axis; the trajectory must equal the 1-D shard runner
    exactly (the forward gathers full weights, grads slice back, and
    every elementwise/linear-mix op commutes with the slicing)."""
    if len(jax.devices()) < 2:
        pytest.skip("model_parallel=2 needs >= 2 devices")
    data, pub = tiny_data
    runs = {mp: _sim_run_2d(mcfg, _kd_tcfg(topology, 4), data, pub, mp)
            for mp in (1, 2)}
    assert np.allclose(runs[2].acc_history, runs[1].acc_history, atol=1e-5)
    assert np.allclose(runs[2].loss_history, runs[1].loss_history, atol=1e-4)
    assert np.allclose(runs[2].consensus_history, runs[1].consensus_history,
                       rtol=0.05, atol=1e-8)
    assert runs[2].label_bytes_total == runs[1].label_bytes_total


def test_sim_2d_mesh_compressed_gossip_equals_1d(tiny_data, mcfg):
    """Compressed delayed gossip on the 2-D mesh: the mixer's comm state
    stays full-width (model-replicated) so payload selection is identical
    on every model peer — trajectories match the 1-D shard run."""
    if len(jax.devices()) < 2:
        pytest.skip("model_parallel=2 needs >= 2 devices")
    data, pub = tiny_data
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=4, alpha=0.05,
                       steps=8, batch_size=8, lr=0.3, seed=4,
                       topology="ring", compression="topk",
                       compression_frac=0.25, gossip="delayed",
                       idkd=IDKDConfig(start_step=4, temperature=10.0,
                                       label_topk=4, label_backend="sparse"))
    runs = {mp: _sim_run_2d(mcfg, tcfg, data, pub, mp) for mp in (1, 2)}
    assert np.allclose(runs[2].acc_history, runs[1].acc_history, atol=1e-5)
    assert np.allclose(runs[2].loss_history, runs[1].loss_history, atol=1e-4)


def test_lm_2d_mesh_equals_1d_shard():
    """LM launch path under --model-parallel 2: vocab-sharded streaming
    label rounds + FSDP-sharded steps reproduce the 1-D shard run."""
    if len(jax.devices()) < 2:
        pytest.skip("model_parallel=2 needs >= 2 devices")
    from repro.configs import get_config
    from repro.launch.train import run_training
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    tcfg = TrainConfig(num_nodes=2, steps=6, lr=0.1, alpha=0.1, batch_size=4,
                       idkd=IDKDConfig(start_step=3, label_topk=4,
                                       kd_weight=0.3))
    hist = {}
    for mp in (1, 2):
        out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                           use_idkd=True, log_every=2, verbose=False,
                           driver_mode="shard", model_parallel=mp)
        hist[mp] = out["loss_history"]
    assert np.allclose(hist[2], hist[1], rtol=1e-4, atol=1e-5)


def test_2d_mesh_rejects_rewire_and_non_shard_driver(tiny_data, mcfg):
    """Eager 2-D validation: rewires name the 1-D fallback before the
    run starts, and model_parallel>1 without the shard driver fails at
    construction."""
    from repro import sched
    schedule = compile_schedule(
        8, 3, events=[sched.RewireEvent(step=4, topology="full")])
    with pytest.raises(ValueError, match="model-parallel 1"):
        sched.validate_shard_schedule(schedule, 4, 2)
    sched.validate_shard_schedule(schedule, 4, 1)     # 1-D still allows it
    data, pub = tiny_data
    with pytest.raises(ValueError, match="shard"):
        DecentralizedSimulator(mcfg, _kd_tcfg("ring"), data, pub,
                               kd_mode="idkd", driver_mode="scan",
                               model_parallel=2)


# ------------------------------------------------------------ im2col conv
def test_im2col_forward_matches_lax(mcfg):
    """The im2col conv path (patch-gather + matmul, no lax.conv) must
    reproduce the lax conv forward — including strided stage-transition
    blocks with projection shortcuts."""
    from repro.models import build_model
    cfg_lax = mcfg.replace(conv_backend="lax", cnn_stages=(1, 1))
    cfg_i2c = cfg_lax.replace(conv_backend="im2col")
    m_lax, m_i2c = build_model(cfg_lax), build_model(cfg_i2c)
    params = m_lax.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(4, cfg_lax.image_size, cfg_lax.image_size, 3)), jnp.float32)
    a, _ = m_lax.forward(params, {"images": x})
    b, _ = m_i2c.forward(params, {"images": x})
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_auto_mode_uses_scan_for_im2col_cnn():
    """driver_mode="auto" keeps lax-conv CNNs on the host runner on CPU
    (conv-in-while pathology) but lets im2col models onto the scan
    runner; explicit modes pass through untouched."""
    if jax.default_backend() != "cpu":
        pytest.skip("auto-mode conv fallback is CPU-specific")
    assert driver.resolve_runner_mode("auto", "cnn", "lax") == "host"
    assert driver.resolve_runner_mode("auto", "cnn", "im2col") == "scan"
    assert driver.resolve_runner_mode("auto", "dense") == "scan"
    assert driver.resolve_runner_mode("shard", "cnn", "lax") == "shard"
