"""Decentralized optimizers on heterogeneous quadratics.

Node i minimizes f_i(x) = ||x - c_i||²/2 with distinct targets c_i — the
global optimum is mean(c_i). Data-heterogeneity in miniature: plain DSGD
has a heterogeneity bias floor, D²/QGM should track the global optimum,
and all methods must reach consensus.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.mixing import make_dense_mixer
from repro.core.topology import Topology

N, DIM = 8, 4


def _setup(seed=0):
    rng = np.random.default_rng(seed)
    targets = jnp.asarray(rng.normal(size=(N, DIM)) * 2, jnp.float32)
    topo = Topology.make("ring", N)
    mix = make_dense_mixer(topo.mixing_matrix())
    params = {"x": jnp.zeros((N, DIM), jnp.float32)}
    return targets, topo, mix, params


def _grads(params, targets):
    return {"x": params["x"] - targets}


def _run(name, mix, targets, lr, steps=2500, momentum=0.9):
    algo = make_algorithm(name, momentum=momentum, weight_decay=0.0)
    params = {"x": jnp.zeros((N, DIM), jnp.float32)}
    state = algo.init(params)
    step = jax.jit(lambda p, g, s, l: algo.step(p, g, s, l, mix))
    for _ in range(steps):
        params, state = step(params, _grads(params, targets), state, lr)
    return np.asarray(params["x"])


@pytest.mark.parametrize("name,lr", [("dsgd", 0.05), ("dsgdm", 0.05),
                                     ("qg-dsgdm-n", 0.02), ("d2", 0.05),
                                     ("centralized", 0.05)])
def test_mean_iterate_reaches_global_optimum(name, lr):
    """Every method's *node-average* must reach the global optimum."""
    targets, topo, mix, params = _setup()
    if name == "centralized":
        mix = make_dense_mixer(np.full((N, N), 1.0 / N))
    x = _run(name, mix, targets, lr)
    opt = np.asarray(targets).mean(0)
    assert np.abs(x.mean(0) - opt).max() < 0.15, f"{name} biased mean"


@pytest.mark.parametrize("name", ["qg-dsgdm-n", "d2", "centralized"])
def test_bias_corrected_methods_reach_consensus(name):
    """D²/QGM remove the heterogeneity disagreement; plain DSGD retains an
    O(lr·heterogeneity) spread at constant lr (the failure the paper
    targets) — so consensus is asserted only for the corrected methods."""
    targets, topo, mix, params = _setup()
    if name == "centralized":
        mix = make_dense_mixer(np.full((N, N), 1.0 / N))
    lr = 0.02 if name == "qg-dsgdm-n" else 0.05
    x = _run(name, mix, targets, lr)
    assert np.abs(x - x.mean(0)).max() < 0.15, f"{name} no consensus"


def test_dsgd_heterogeneity_spread_shrinks_with_lr():
    """DSGD's consensus spread is O(lr): halving lr must shrink it."""
    targets, topo, mix, params = _setup()
    spread_hi = np.abs(_run("dsgd", mix, targets, 0.05)
                       - _run("dsgd", mix, targets, 0.05).mean(0)).max()
    spread_lo = np.abs(_run("dsgd", mix, targets, 0.01, steps=6000)
                       - _run("dsgd", mix, targets, 0.01,
                              steps=6000).mean(0)).max()
    assert spread_lo < 0.5 * spread_hi


def test_qgm_beats_dsgd_on_consensus():
    """The paper's base optimizer must dominate DSGD on disagreement."""
    targets, topo, mix, params = _setup(seed=7)
    x_dsgd = _run("dsgd", mix, targets, 0.05)
    x_qgm = _run("qg-dsgdm-n", mix, targets, 0.02)
    s_dsgd = np.abs(x_dsgd - x_dsgd.mean(0)).max()
    s_qgm = np.abs(x_qgm - x_qgm.mean(0)).max()
    assert s_qgm < s_dsgd


def test_relaysgd_on_chain():
    targets, _, _, params = _setup()
    topo = Topology.make("chain", N)
    algo = make_algorithm("relaysgd", topology=topo, momentum=0.9,
                          weight_decay=0.0)
    state = algo.init(params)
    step = jax.jit(lambda p, g, s, lr: algo.step(p, g, s, lr))
    for i in range(1500):
        params, state = step(params, _grads(params, targets), state, 0.05)
    x = np.asarray(params["x"])
    opt = np.asarray(targets).mean(0)
    assert np.abs(x - x.mean(0)).max() < 0.2
    assert np.abs(x.mean(0) - opt).max() < 0.2


def test_relaysgd_requires_tree():
    with pytest.raises(ValueError):
        make_algorithm("relaysgd", topology=Topology.make("ring", 8))


def test_qgm_fused_step_matches_unfused_reference():
    """The fused 4-pass QG-DSGDm-N step (ROADMAP thunk-floor item) must
    match the textbook unfused sequence — wd, grad-norm, scale, momentum
    axpy, half-step, mix, displacement EMA — bitwise on f32 params."""
    from repro.core.algorithms import (_apply_weight_decay, global_grad_norm,
                                       make_qg_dsgdm_n, tree_axpy,
                                       tree_scale, tree_sub)

    def unfused_step(params, grads, state, lr, mix, momentum=0.9,
                     weight_decay=1e-4, eps=1e-8):
        grads = _apply_weight_decay(params, grads, weight_decay)
        gn = global_grad_norm(grads)
        grads = tree_scale(1.0 / (gn + eps), grads)
        upd = tree_axpy(momentum, state["m"], grads)
        half = tree_axpy(-lr, upd, params)
        new_params = mix(half)
        d = tree_scale(1.0 / lr, tree_sub(params, new_params))
        m = jax.tree.map(
            lambda mi, di: (momentum * mi.astype(jnp.float32)
                            + (1 - momentum) * di.astype(jnp.float32)
                            ).astype(mi.dtype), state["m"], d)
        return new_params, {"m": m}

    targets, topo, mix, params = _setup(seed=3)
    params = {"x": jnp.asarray(
        np.random.default_rng(1).normal(size=(N, DIM)), jnp.float32)}
    algo = make_qg_dsgdm_n(momentum=0.9, weight_decay=1e-4)
    s_f = s_u = algo.init(params)
    p_f = p_u = params
    lr = jnp.asarray(0.07, jnp.float32)
    for t in range(4):
        g = _grads(p_u, targets)
        p_f, s_f = algo.step(p_f, _grads(p_f, targets), s_f, lr, mix)
        p_u, s_u = unfused_step(p_u, g, s_u, lr, mix)
    assert np.allclose(np.asarray(p_f["x"]), np.asarray(p_u["x"]),
                       atol=1e-6)
    assert np.allclose(np.asarray(s_f["m"]["x"]), np.asarray(s_u["m"]["x"]),
                       atol=1e-6)


def test_qgm_leaf_fused_mix_bitwise_equals_mix_then_update():
    """The per-leaf mixer protocol (mix.mix_leaf) lets QG-DSGDm-N fold
    half-step + gossip mix + displacement-EMA into one whole-tree
    traversal. The per-leaf op sequence is unchanged, so the fused pass
    must be *bitwise* equal to the mix-then-update form (an opaque mixer
    without mix_leaf), on every backend."""
    from repro.core.mixing import make_mixer
    from repro.core.algorithms import make_qg_dsgdm_n

    topo = Topology.make("ring", N)
    rng = np.random.default_rng(5)
    params = {"x": jnp.asarray(rng.normal(size=(N, DIM)), jnp.float32),
              "nested": {"y": jnp.asarray(rng.normal(size=(N, 3, 2)),
                                          jnp.float32)}}
    targets = jax.tree.map(
        lambda t: jnp.asarray(rng.normal(size=t.shape), jnp.float32), params)
    algo = make_qg_dsgdm_n(momentum=0.9, weight_decay=1e-4)
    lr = jnp.asarray(0.07, jnp.float32)
    for backend in ("dense", "gather", "roll"):
        mix = make_mixer(topo, backend=backend, wire_dtype="float32")
        assert callable(mix.mix_leaf)

        def opaque(tree, _mix=mix):        # same mixer, protocol hidden
            return _mix(tree)

        p_f = p_o = params
        s_f = s_o = algo.init(params)
        for _ in range(3):
            g_f = jax.tree.map(lambda p, t: p - t, p_f, targets)
            g_o = jax.tree.map(lambda p, t: p - t, p_o, targets)
            p_f, s_f = jax.jit(lambda p, g, s: algo.step(p, g, s, lr, mix)
                               )(p_f, g_f, s_f)
            p_o, s_o = jax.jit(lambda p, g, s: algo.step(p, g, s, lr,
                                                         opaque)
                               )(p_o, g_o, s_o)
        for a, b in zip(jax.tree.leaves((p_f, s_f)),
                        jax.tree.leaves((p_o, s_o))):
            assert np.array_equal(np.asarray(a), np.asarray(b)), backend


def test_qgm_momentum_tracks_displacement():
    """QGM buffer must be EMA of (x_t − x_{t+1})/lr, not the raw gradient."""
    targets, topo, mix, params = _setup()
    algo = make_algorithm("qg-dsgdm-n", momentum=0.5, weight_decay=0.0)
    state = algo.init(params)
    p1, s1 = algo.step(params, _grads(params, targets), state, 0.1, mix)
    d = (params["x"] - p1["x"]) / 0.1
    expect = 0.5 * state["m"]["x"] + 0.5 * d
    assert np.allclose(np.asarray(s1["m"]["x"]), np.asarray(expect), atol=1e-5)


def test_dsgd_heterogeneity_bias_vs_d2():
    """D² should out-track DSGD under strong heterogeneity (paper Table 1/2
    motivation) — measured as distance to the global optimum."""
    targets, topo, mix, params = _setup(seed=3)

    def run(name, lr=0.05, steps=800):
        algo = make_algorithm(name, momentum=0.0, weight_decay=0.0)
        st = algo.init({"x": jnp.zeros((N, DIM), jnp.float32)})
        p = {"x": jnp.zeros((N, DIM), jnp.float32)}
        step = jax.jit(lambda p_, g, s, l: algo.step(p_, g, s, l, mix))
        for _ in range(steps):
            p, st = step(p, _grads(p, targets), st, lr)
        return np.abs(np.asarray(p["x"]).mean(0)
                      - np.asarray(targets).mean(0)).max()

    # on this noiseless quadratic both converge; D² must not be worse
    assert run("d2") <= run("dsgd") + 1e-3
