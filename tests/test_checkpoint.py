import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import build_model


def test_roundtrip_simple(tmp_path):
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, step=7)
    like = jax.tree.map(jnp.zeros_like, params)
    restored, step = load_checkpoint(path, like)
    assert step == 7
    assert np.allclose(np.asarray(restored["a"]), np.asarray(params["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_roundtrip_model_params(tmp_path):
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "model")
    save_checkpoint(path, params, step=100)
    like = jax.tree.map(jnp.zeros_like, params)
    restored, step = load_checkpoint(path, like)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, restored)
    assert max(jax.tree.leaves(diffs)) == 0.0


def test_structure_mismatch_raises(tmp_path):
    path = str(tmp_path / "x")
    save_checkpoint(path, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"b": jnp.ones(3)})
