import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import make_classification_data, make_public_data
from repro.models import build_model


def test_roundtrip_simple(tmp_path):
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, step=7)
    like = jax.tree.map(jnp.zeros_like, params)
    restored, step = load_checkpoint(path, like)
    assert step == 7
    assert np.allclose(np.asarray(restored["a"]), np.asarray(params["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_roundtrip_model_params(tmp_path):
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "model")
    save_checkpoint(path, params, step=100)
    like = jax.tree.map(jnp.zeros_like, params)
    restored, step = load_checkpoint(path, like)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, restored)
    assert max(jax.tree.leaves(diffs)) == 0.0


def test_structure_mismatch_raises(tmp_path):
    path = str(tmp_path / "x")
    save_checkpoint(path, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"b": jnp.ones(3)})


def test_version_skew_rejected(tmp_path):
    """A checkpoint from an incompatible (or pre-versioning) layout is
    refused loudly instead of silently misloading."""
    import json
    path = str(tmp_path / "v")
    like = {"a": jnp.ones(3)}
    save_checkpoint(path, like)
    meta_path = path + ".meta.json"
    with open(meta_path) as f:
        meta = json.load(f)
    meta["version"] = 999
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="version"):
        load_checkpoint(path, like)
    del meta["version"]          # pre-versioning file: no key at all
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="pre-versioning"):
        load_checkpoint(path, like)


def test_checksum_mismatch_rejected(tmp_path):
    """Bit rot / post-save tampering of the npz payload is caught by the
    stored-vs-recomputed CRC before any value reaches the caller."""
    path = str(tmp_path / "c")
    like = {"a": jnp.ones(3), "b": jnp.zeros((2, 2))}
    save_checkpoint(path, like)
    npz = np.load(path + ".npz")
    flat = {k: npz[k] for k in npz.files}
    flat["a"] = flat["a"] + 1.0              # tamper one array
    np.savez(path + ".npz", **flat)
    with pytest.raises(ValueError, match="checksum mismatch"):
        load_checkpoint(path, like)


def test_resume_mid_schedule_matches_uninterrupted(tmp_path):
    """Save at a round boundary, restore into a *fresh* simulator, and
    rejoin the uninterrupted trajectory exactly: the scheduler re-fires
    the homogenization round at the resume step from the restored params,
    so the KD sampler state needs no checkpointing (DESIGN.md §6)."""
    data = make_classification_data(image_size=8, n_train=512, n_val=64,
                                    n_test=300, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=96, kind="aligned", seed=1)
    mcfg = SMALL_CONFIG.replace(image_size=8)
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=3, alpha=0.05,
                       steps=12, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=4, every_k_steps=4,
                                       num_rounds=2, temperature=10.0,
                                       label_topk=4,
                                       label_backend="sparse"))
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=3)
    full = sim.run(capture_at=8)             # 8 = the second round step
    assert full.captured is not None and full.captured["step"] == 8

    # roundtrip the whole training state through the npz checkpoint
    path = str(tmp_path / "mid_schedule")
    state = {"params": full.captured["params"],
             "opt_state": full.captured["opt_state"],
             "key": full.captured["key"]}
    save_checkpoint(path, state, step=full.captured["step"])
    fresh = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                   eval_every=3)
    like = {"params": fresh._stacked_init(),
            "opt_state": fresh.algo.init(fresh._stacked_init()),
            "key": jax.random.PRNGKey(0)}
    restored, step = load_checkpoint(path, like)
    resumed = fresh.run(resume={**restored, "step": step})

    tail = len(resumed.acc_history)
    assert tail >= 1
    assert np.allclose(resumed.acc_history, full.acc_history[-tail:],
                       atol=1e-5)
    assert np.allclose(resumed.loss_history, full.loss_history[-tail:],
                       atol=1e-4)
    # the resumed ledger only covers the resumed span
    assert sum(r["steps"] for r in resumed.ledger["per_round"]) == 4

    # resuming anywhere past a round that is not itself a round boundary
    # must refuse (the sampler payload would be stale)
    with pytest.raises(ValueError, match="round boundary"):
        fresh.run(resume={**restored, "step": 7})
    # a capture point inside the resumed-over span can never fire
    with pytest.raises(ValueError, match="skipped by"):
        fresh.run(resume={**restored, "step": step}, capture_at=4)
