"""Compressed / compute-overlapped gossip tests (DESIGN.md §9).

* spec plumbing: compression spec normalization and the payload byte
  math the ledger consumes (top-k 1% → ≥10× fewer gossip bytes);
* oracle equivalences: the stateful-but-uncompressed sync mixer is
  bitwise the plain backend; ``frac=1, γ=1`` top-k recovers the dense
  Metropolis mix; delayed gossip's step 0 mixes the exact init;
* error feedback: the ``x - x̂`` gap drains to zero on fixed params —
  every cut coordinate eventually crosses the wire;
* random-k: deterministic from a given comm state, keys advance;
* the bound-mixer recorder rejects double-mixing algorithms (gradient
  tracking) and never-mixing ones (RelaySGD) loudly;
* the shard_map twin reproduces node-stacked trajectories;
* end-to-end: top-k 1% LM run lands in the dense run's loss band at a
  fraction of the ledger bytes; delayed-vs-sync divergence is bounded;
  stale (straggler) churn keeps the node training while its neighbours
  mix its frozen payload and the ledger charges it nothing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sched
from repro.configs.base import TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core import driver, mixing
from repro.core.algorithms import make_algorithm
from repro.core.simulator import DecentralizedSimulator
from repro.core.topology import Topology
from repro.data.synthetic import make_classification_data


@pytest.fixture(scope="module")
def tiny_data():
    return make_classification_data(image_size=8, n_train=512, n_val=64,
                                    n_test=300, noise=0.8, seed=0)


@pytest.fixture(scope="module")
def mcfg():
    return SMALL_CONFIG.replace(image_size=8)


def _stacked(n, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n, 29)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)}


def _run_stateful(mix, tree, steps=1, comm=None):
    comm = mix.init_state(tree) if comm is None else comm
    x = tree
    for _ in range(steps):
        b = mix.bind(comm)
        x = b(x)
        comm = b.finalize()
    return x, comm


# ------------------------------------------------------------ spec + bytes
def test_normalize_compression_specs():
    assert mixing.normalize_compression(None) is None
    assert mixing.normalize_compression("none") is None
    assert mixing.normalize_compression(("none", 0.5)) is None
    assert mixing.normalize_compression("topk") == ("topk", 0.01)
    assert mixing.normalize_compression("topk:0.1") == ("topk", 0.1)
    assert mixing.normalize_compression(("randk", 0.05)) == ("randk", 0.05)
    with pytest.raises(ValueError, match="unknown compression kind"):
        mixing.normalize_compression("lz4")
    with pytest.raises(ValueError, match="fraction"):
        mixing.normalize_compression(("topk", 0.0))
    with pytest.raises(ValueError, match="fraction"):
        mixing.normalize_compression("topk:1.5")


def test_payload_byte_math():
    tree = _stacked(4)                       # per-node leaves: 29 + 5
    assert mixing.payload_elem_count(tree, None) == 34
    # top-k 1% keeps max(1, round(.01·size)) per leaf -> 1 + 1
    assert mixing.payload_elem_count(tree, ("topk", 0.01)) == 2
    # round() is banker's: k(29,.5)=14, k(5,.5)=2
    assert mixing.payload_elem_count(tree, ("topk", 0.5)) == 14 + 2
    single = {k: v[0] for k, v in tree.items()}
    assert mixing.payload_elem_count(single, ("topk", 0.01),
                                     node_stacked=False) == 2
    # ledger view: value+index pairs must still win ≥10× at 1% f32
    dense_bytes = 34 * 4
    comp_bytes = 2 * (4 + 4)
    assert dense_bytes / comp_bytes >= 8     # tiny leaves; real nets ~50×
    assert mixing.payload_k(100, 0.01) == 1
    assert mixing.payload_k(100, 1.0) == 100
    assert mixing.payload_k(3, 0.01) == 1    # never zero


# ---------------------------------------------------------------- oracles
def test_stateful_uncompressed_sync_is_plain_bitwise():
    topo = Topology.make("ring", 4)
    tree = _stacked(4)
    mix = mixing.make_mixer(topo, "roll", stateful=True)
    assert mix.stateful
    y, comm = _run_stateful(mix, tree)
    ref = mixing.make_mixer(topo, "roll")(tree)
    for a, b in zip(jax.tree.leaves(y), jax.tree.leaves(ref)):
        assert bool(jnp.array_equal(a, b))
    # prev snapshot advanced to the pre-mix params
    for p, t in zip(jax.tree.leaves(comm["prev"]), jax.tree.leaves(tree)):
        assert bool(jnp.array_equal(p, t))


def test_topk_full_fraction_recovers_dense_mix():
    topo = Topology.make("ring", 4)
    tree = _stacked(4)
    mix = mixing.make_mixer(topo, "dense", compression=("topk", 1.0))
    y, _ = _run_stateful(mix, tree)
    ref = mixing.make_mixer(topo, "dense")(tree)
    for a, b in zip(jax.tree.leaves(y), jax.tree.leaves(ref)):
        assert jnp.allclose(a, b, atol=1e-6)


def test_delayed_step0_mixes_exact_init():
    """x̂₀ = x₀, so the first delayed step equals the dense sync mix —
    staleness only sets in once estimates start lagging."""
    topo = Topology.make("ring", 4)
    tree = _stacked(4)
    mix = mixing.make_mixer(topo, "dense", compression=("topk", 0.2),
                            gossip="delayed")
    y, _ = _run_stateful(mix, tree)
    ref = mixing.make_mixer(topo, "dense")(tree)
    for a, b in zip(jax.tree.leaves(y), jax.tree.leaves(ref)):
        assert jnp.allclose(a, b, atol=1e-6)


def test_error_feedback_gap_drains():
    """Hold params fixed and keep gossiping: the shared estimates must
    converge to the params (implicit EF — cut coordinates stay in the
    gap and ride later deltas until everything crossed the wire)."""
    topo = Topology.make("ring", 4)
    tree = _stacked(4)
    mix = mixing.make_mixer(topo, "dense", compression=("topk", 0.1))
    comm = mix.init_state(tree)
    x = jax.tree.map(lambda t: t + 1.0, tree)     # move x off x̂
    gap0 = None
    for _ in range(30):
        b = mix.bind(comm)
        b(x)
        comm = b.finalize()
        gap = max(float(jnp.abs(jnp.asarray(t).reshape(4, -1) - h).max())
                  for t, h in zip(jax.tree.leaves(x),
                                  jax.tree.leaves(comm["hat"])))
        gap0 = gap if gap0 is None else gap0
    assert gap0 > 0.5            # the gap was real after one step
    assert gap < 1e-5            # and fully drained after 30


def test_randk_deterministic_and_key_advances():
    topo = Topology.make("ring", 4)
    tree = _stacked(4)
    mix = mixing.make_mixer(topo, "dense", compression=("randk", 0.3))
    comm = mix.init_state(tree)
    x = jax.tree.map(lambda t: t * 2.0, tree)    # nonzero x - x̂ deltas
    y1, c1 = _run_stateful(mix, x, comm=comm)
    y2, c2 = _run_stateful(mix, x, comm=comm)
    for a, b in zip(jax.tree.leaves(y1), jax.tree.leaves(y2)):
        assert bool(jnp.array_equal(a, b))
    assert not bool(jnp.array_equal(c1["key"], comm["key"]))
    # same estimates, advanced key -> a different random selection
    y3, _ = _run_stateful(mix, x, comm={**comm, "key": c1["key"]})
    assert not all(bool(jnp.array_equal(a, b)) for a, b in
                   zip(jax.tree.leaves(y1), jax.tree.leaves(y3)))


def test_unbound_stateful_mixer_rejects_direct_call():
    mix = mixing.make_mixer(Topology.make("ring", 4), "dense",
                            compression=("topk", 0.5))
    with pytest.raises(TypeError, match="bind"):
        mix(_stacked(4))


# ------------------------------------------------- incompatible algorithms
def test_recorder_rejects_double_and_missing_mixes():
    topo = Topology.make("ring", 4)
    tree = _stacked(4)
    mix = mixing.make_mixer(topo, "dense", compression=("topk", 0.5))
    comm = mix.init_state(tree)
    bound = mix.bind(comm)
    bound(tree)
    with pytest.raises(ValueError, match="more leaves"):
        bound.mix_leaf(jax.tree.leaves(tree)[0])
    partial = mix.bind(comm)
    partial.mix_leaf(jax.tree.leaves(tree)[0])
    with pytest.raises(ValueError, match="never mixed"):
        partial.finalize()


def test_gradient_tracking_rejected_with_compression(tiny_data, mcfg):
    """Gradient tracking mixes params AND trackers each step — two
    whole-tree mixes per bind — which the per-leaf wire state cannot
    express; the recorder must reject it at trace time."""
    from repro.models import build_model
    from repro.launch.steps import stack_params
    data = tiny_data
    model = build_model(mcfg)
    topo = Topology.make("ring", 4)
    mix = mixing.make_mixer(topo, "dense", compression=("topk", 0.1))
    algo = make_algorithm("gradient-tracking")
    step = driver.make_step(model, algo, mix, driver.classification_adapter)
    assert step.comm
    params = stack_params(model.init(jax.random.PRNGKey(0)), 4)
    comm = step.init_comm(params)
    batch = {"images": jnp.asarray(data.train_x[:32]).reshape(
                 (4, 8) + data.train_x.shape[1:]),
             "labels": jax.nn.one_hot(
                 jnp.asarray(data.train_y[:32]).reshape(4, 8),
                 mcfg.num_classes),
             "weights": jnp.ones((4, 8), jnp.float32)}
    with pytest.raises(ValueError, match="more leaves"):
        step(params, step.init_opt(params), batch,
             jnp.asarray(0.1, jnp.float32), comm)


# ----------------------------------------------------- shard_map twin
@pytest.mark.parametrize("topo_name,comp,gossip", [
    ("ring", ("topk", 0.2), "sync"),
    ("ring", ("topk", 0.2), "delayed"),
    ("ring", None, "delayed"),
    ("ring", ("randk", 0.3), "sync"),
    ("full", ("topk", 0.2), "sync"),
    ("full", ("topk", 0.2), "delayed"),
])
def test_shard_twin_matches_stacked(topo_name, comp, gossip):
    """The compressed ppermute mixer must reproduce the node-stacked
    compressed trajectory to float tolerance (same estimates, same
    payload selection) — over however many host devices divide the node
    axis (1 device → degenerate block mesh, same code path)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh
    from repro.launch.sharding import node_stacked_specs
    n = 4
    topo = Topology.make(topo_name, n)
    tree = _stacked(n, seed=3)
    ms = mixing.make_mixer(topo, "dense", compression=comp, gossip=gossip,
                           stateful=True)
    xs, _ = _run_stateful(ms, tree, steps=3)

    size = max(d for d in range(1, min(len(jax.devices()), n) + 1)
               if n % d == 0)
    mesh = Mesh(np.asarray(jax.devices()[:size]), ("node",))
    mp = mixing.make_mixer(topo, "ppermute", compression=comp,
                           gossip=gossip, stateful=True,
                           axis_names=("node",), axis_sizes=(size,),
                           local_nodes=n // size)
    comm = mp.init_state(tree)

    def body(x, c):
        b = mp.bind(c)
        y = b(x)
        return y, b.finalize()

    sx = node_stacked_specs(tree, n, "node")
    sc = node_stacked_specs(comm, n, "node")
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(sx, sc),
                          out_specs=(sx, sc), check_rep=False))
    xp = tree
    for _ in range(3):
        xp, comm = f(xp, comm)
    for a, b in zip(jax.tree.leaves(xs), jax.tree.leaves(xp)):
        assert jnp.allclose(a, b, atol=2e-5), float(jnp.abs(a - b).max())


# ----------------------------------------------------------- end to end
def _tiny_lm_cfg():
    from repro.configs import get_config
    return get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")


def _lm_run(tcfg, **kw):
    from repro.launch.train import run_training
    return run_training(_tiny_lm_cfg(), tcfg, seq_len=16, n_seqs=64,
                        n_public=8, log_every=6, verbose=False, **kw)


def test_lm_topk_reduces_bytes_and_stays_in_band():
    """The acceptance A/B: top-k 1% on the ring LM config ships ≥10×
    fewer ledger gossip bytes than the dense f32 wire, with the
    fixed-seed final loss inside the dense run's noise band."""
    mk = lambda **kw: TrainConfig(                       # noqa: E731
        num_nodes=4, steps=12, lr=0.1, alpha=0.1, batch_size=4,
        topology="ring", seed=3, **kw)
    dense = _lm_run(mk())
    topk = _lm_run(mk(compression="topk", compression_frac=0.01))
    db = dense["ledger"]["gossip_bytes"]
    cb = topk["ledger"]["gossip_bytes"]
    assert db / cb >= 10.0, (db, cb)
    assert topk["ledger"]["meta"]["compression"] == "topk"
    assert topk["ledger"]["meta"]["compression_frac"] == 0.01
    l_dense = dense["loss_history"][-1]
    l_topk = topk["loss_history"][-1]
    assert np.isfinite(l_topk)
    assert abs(l_topk - l_dense) < 0.25, (l_dense, l_topk)


def test_lm_delayed_vs_sync_bounded_divergence():
    """One-step-stale gossip must track the sync trajectory: bounded
    loss divergence, same byte accounting, finite throughout (the sync
    path is the equivalence oracle — band, not bitwise)."""
    mk = lambda **kw: TrainConfig(                       # noqa: E731
        num_nodes=4, steps=12, lr=0.1, alpha=0.1, batch_size=4,
        topology="ring", seed=3, **kw)
    sync = _lm_run(mk())
    delayed = _lm_run(mk(gossip="delayed"))
    assert delayed["ledger"]["meta"]["gossip"] == "delayed"
    assert delayed["ledger"]["gossip_bytes"] == \
        sync["ledger"]["gossip_bytes"]
    l_sync = sync["loss_history"][-1]
    l_delayed = delayed["loss_history"][-1]
    assert np.isfinite(l_delayed)
    assert abs(l_delayed - l_sync) < 0.25, (l_sync, l_delayed)
    # params diverge but stay in a consensus ball
    d = mixing.consensus_distance(
        {"p": jnp.stack([jnp.ravel(jax.tree.leaves(sync["params"])[0]),
                         jnp.ravel(jax.tree.leaves(
                             delayed["params"])[0])])})
    assert float(d) < 1.0


def test_sim_schedule_gossip_mismatch_raises(tiny_data, mcfg):
    tcfg = TrainConfig(algorithm="dsgd", num_nodes=4, alpha=0.1, steps=6,
                       batch_size=8, lr=0.2, seed=7, gossip="delayed")
    sim = DecentralizedSimulator(mcfg, tcfg, tiny_data, None, kd_mode=None,
                                 eval_every=5)
    bad = sched.compile_schedule(tcfg.steps, 5)          # sync schedule
    with pytest.raises(ValueError, match="gossip"):
        sim.run(schedule=bad)
    r = sim.run()                                        # default agrees
    assert np.isfinite(r.loss_history).all()
    assert r.ledger["meta"]["gossip"] == "delayed"


def test_stale_straggler_end_to_end(tiny_data, mcfg):
    """mode="stale" churn: the straggler keeps *training* (unlike
    freeze), the run stays finite with neighbours consuming its frozen
    payload, and the ledger charges the stale sender zero bytes for the
    window."""
    tcfg = TrainConfig(algorithm="dsgd", num_nodes=4, alpha=0.1, steps=6,
                       batch_size=8, lr=0.3, seed=7,
                       compression="topk", compression_frac=0.1)

    def node2(mode):
        sim = DecentralizedSimulator(mcfg, tcfg, tiny_data, None,
                                     kd_mode=None, eval_every=5)
        schedule = sched.compile_schedule(
            tcfg.steps, 5, events=[sched.ChurnEvent(step=2, down=(2,),
                                                    mode=mode)])
        down = sim.run(schedule=schedule, capture_at=2)
        end = sim.run(schedule=schedule, capture_at=tcfg.steps)
        return (np.asarray(jax.tree.leaves(
                    down.captured["params"])[0][2], np.float32),
                np.asarray(jax.tree.leaves(
                    end.captured["params"])[0][2], np.float32),
                end)

    s_down, s_end, stale_run = node2("stale")
    assert not np.array_equal(s_down, s_end)     # the straggler trains
    assert np.isfinite(stale_run.acc_history).all()
    # the straggler ships nothing during its window, neighbours still do
    per_node = np.sum([row["gossip_per_node"]
                       for row in stale_run.ledger["per_round"]], axis=0)
    assert per_node[2] < per_node[1]
    f_down, f_end, _ = node2("freeze")
    assert np.array_equal(f_down, f_end)         # freeze really holds


def test_stale_payload_frozen_for_neighbours():
    """While a node is stale its x̂ row (the payload neighbours mix) must
    not move, and it must resume updating once the node is fresh again."""
    topo = Topology.make("ring", 4)
    tree = _stacked(4)
    stale = np.zeros(4, bool)
    stale[2] = True
    mix = mixing.make_mixer(topo, "dense", compression=("topk", 0.5),
                            stale=stale)
    comm = mix.init_state(tree)
    x = jax.tree.map(lambda t: t * 2.0, tree)
    _, c1 = _run_stateful(mix, x, comm=comm)
    h0 = jax.tree.leaves(comm["hat"])[0]
    h1 = jax.tree.leaves(c1["hat"])[0]
    assert bool(jnp.array_equal(h0[2], h1[2]))       # frozen payload
    assert not bool(jnp.array_equal(h0[0], h1[0]))   # fresh rows move
    # back to fresh: remake without the stale mask, row catches up
    fresh_mix = mix.remake()
    _, c2 = _run_stateful(fresh_mix, x, comm=c1)
    h2 = jax.tree.leaves(c2["hat"])[0]
    assert not bool(jnp.array_equal(h1[2], h2[2]))
