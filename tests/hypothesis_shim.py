"""Minimal stand-in for ``hypothesis`` when it is not installed.

Property tests decorated with the real ``@given`` sweep randomized examples;
under the shim they still *collect* normally and individually skip at run
time, so a missing dev dependency costs a few skipped sweeps instead of
erroring entire test modules out of collection. ``pip install -r
requirements-dev.txt`` restores the real property sweeps (CI does).

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypothesis_shim import given, settings, st
"""
from __future__ import annotations

import functools
import inspect

import pytest


class _Strategy:
    """Opaque placeholder for a hypothesis strategy object."""

    def __init__(self, name: str):
        self._name = name

    def __repr__(self):
        return self._name

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return _Strategy(f"{self._name}.{name}")


class _StrategiesModule:
    def __getattr__(self, name):
        return _Strategy(f"st.{name}")


st = _StrategiesModule()
strategies = st


def settings(*args, **kwargs):
    """No-op replacement for ``hypothesis.settings`` used as a decorator."""

    def deco(fn):
        return fn

    return deco


def given(*args, **kwargs):
    """Replacement for ``hypothesis.given``: the test collects but skips."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            pytest.skip("hypothesis not installed (see requirements-dev.txt)")

        # zero-arg signature so pytest does not treat the strategy params
        # (alpha, n_nodes, ...) as fixtures
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
