"""Unified decentralized driver tests (core/driver.py).

* scan-driver vs host-loop equivalence on fixed seeds — both runners
  consume identical PRNG key sequences, so trajectories must match to
  float tolerance (sim + LM paths, plain + KD phases);
* launch params-gossip and label-exchange share one ``tcfg.topology``;
* the T²-scaled KD temperature convention, pinned across both drivers;
* deterministic test-set eval (no wraparound double-counting);
* on-device sampler unit behaviour.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core import distill, driver
from repro.core.mixing import make_dense_mixer
from repro.core.simulator import DecentralizedSimulator
from repro.core.topology import Topology
from repro.data.synthetic import make_classification_data, make_public_data
from repro.launch.train import make_gossip_mixer, run_training


@pytest.fixture(scope="module")
def tiny_data():
    data = make_classification_data(image_size=8, n_train=512, n_val=64,
                                    n_test=300, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=128, kind="aligned", seed=1)
    return data, pub


@pytest.fixture(scope="module")
def mcfg():
    return SMALL_CONFIG.replace(image_size=8)


# ------------------------------------------------- scan == host (sim path)
@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_sim_scan_equals_host(tiny_data, mcfg, backend):
    """Same seeds → identical trajectories from the scan and host runners,
    through both the plain phase and the KD phase (label backend dense or
    sparse payloads)."""
    data, pub = tiny_data
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=3, alpha=0.05,
                       steps=8, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=4, temperature=10.0,
                                       label_topk=4, label_backend=backend))
    runs = {}
    for mode in ("scan", "host"):
        sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                     eval_every=3, driver_mode=mode)
        runs[mode] = sim.run()
    assert np.allclose(runs["scan"].acc_history, runs["host"].acc_history,
                       atol=1e-5)
    assert np.allclose(runs["scan"].loss_history, runs["host"].loss_history,
                       atol=1e-4)
    # consensus distances are ~1e-6 (same-init nodes barely diverge in 8
    # steps): compare loosely — fp reassociation between the scan-compiled
    # and per-step-compiled executables moves the last couple of digits
    assert np.allclose(runs["scan"].consensus_history,
                       runs["host"].consensus_history, rtol=0.05, atol=1e-8)


def test_sim_plain_scan_equals_host(tiny_data, mcfg):
    data, _ = tiny_data
    tcfg = TrainConfig(algorithm="dsgd", num_nodes=3, alpha=0.1, steps=6,
                       batch_size=8, lr=0.2, seed=7)
    runs = {}
    for mode in ("scan", "host"):
        sim = DecentralizedSimulator(mcfg, tcfg, data, None, kd_mode=None,
                                     eval_every=5, driver_mode=mode)
        runs[mode] = sim.run()
    assert np.allclose(runs["scan"].acc_history, runs["host"].acc_history,
                       atol=1e-5)


# -------------------------------------------------- scan == host (LM path)
def _lm_cfg():
    from repro.configs import get_config
    return get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")


@pytest.mark.parametrize("use_idkd", [False, True])
def test_lm_scan_equals_host(use_idkd):
    cfg = _lm_cfg()
    tcfg = TrainConfig(num_nodes=2, steps=6, lr=0.1, alpha=0.1, batch_size=4,
                       idkd=IDKDConfig(start_step=3, label_topk=4,
                                       kd_weight=0.3))
    hist = {}
    for mode in ("scan", "host"):
        out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                           use_idkd=use_idkd, log_every=2, verbose=False,
                           driver_mode=mode)
        hist[mode] = out["loss_history"]
    assert np.allclose(hist["scan"], hist["host"], rtol=1e-4, atol=1e-5)


# ------------------------------------------ launch topology unification
def test_launch_gossip_follows_tcfg_topology():
    """The launch driver's params-gossip mixer is built from
    ``tcfg.topology`` — the same graph the IDKD label exchange uses — not
    a hardwired ring."""
    tcfg = TrainConfig(num_nodes=9, topology="torus")
    topo, mixer = make_gossip_mixer(tcfg)
    assert topo.name == "torus9"
    rng = np.random.default_rng(0)
    x = {"w": jnp.asarray(rng.normal(size=(9, 5, 3)), jnp.float32)}
    torus_ref = make_dense_mixer(topo.mixing_matrix())(x)
    ring_ref = make_dense_mixer(
        Topology.make("ring", 9).mixing_matrix())(x)
    assert np.allclose(np.asarray(mixer(x)["w"]), np.asarray(torus_ref["w"]),
                       atol=1e-5)
    assert not np.allclose(np.asarray(mixer(x)["w"]),
                           np.asarray(ring_ref["w"]), atol=1e-3)


def test_run_training_shares_topology_with_label_round():
    """End to end on a non-ring graph: run_training reports the one
    Topology object used for both gossip and the label round."""
    cfg = _lm_cfg()
    tcfg = TrainConfig(num_nodes=4, steps=4, lr=0.1, batch_size=4,
                       topology="full",
                       idkd=IDKDConfig(start_step=2, label_topk=4,
                                       kd_weight=0.3))
    out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                       use_idkd=True, log_every=2, verbose=False)
    assert out["topology"].name == "full4"
    assert all(np.isfinite(out["loss_history"]))


# ------------------------------------------------ KD temperature convention
class _ToyLM:
    """Minimal model: fixed logits, fixed base loss — isolates the KD term."""
    BASE = 2.5

    def __init__(self, vocab=16):
        self.vocab = vocab

    def forward(self, params, batch):
        B, S = batch["tokens"].shape
        logits = jnp.broadcast_to(params["w"], (B, S, self.vocab))
        return logits, jnp.zeros(())

    def loss(self, params, batch):
        return jnp.asarray(self.BASE) + 0.0 * params["w"].sum(), {}


def test_kd_temperature_convention():
    """Both drivers use distill's T²-scaled KD losses verbatim: the LM
    adapter's KD term carries Hinton's T² factor (the seed divided it
    back out, so sim and launch disagreed by T² = 100 at T = 10)."""
    T, kd_w = 10.0, 0.5
    icfg = IDKDConfig(temperature=T, kd_weight=kd_w, label_topk=4)
    model = _ToyLM()
    params = {"w": jnp.linspace(-1.0, 1.0, model.vocab)}
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.zeros((2, 3), jnp.int32),
        "labels": jnp.zeros((2, 3), jnp.int32),
        "pub_tokens": jnp.zeros((2, 3), jnp.int32),
        "pub_vals": jnp.asarray(rng.dirichlet(np.ones(4), size=(2, 3)),
                                jnp.float32),
        "pub_idx": jnp.asarray(rng.integers(0, 16, size=(2, 3, 4)),
                               jnp.int32),
        "pub_w": jnp.asarray([1.0, 0.5], jnp.float32),
    }
    loss = driver.lm_sparse_kd_adapter(icfg)(model)(params, batch)
    logits, _ = model.forward(params, {"tokens": batch["pub_tokens"]})
    kd = distill.sparse_kd_loss(
        logits, distill.SparseLabels(batch["pub_vals"], batch["pub_idx"]), T)
    kd = float(jnp.sum(kd.mean(-1) * batch["pub_w"])
               / jnp.sum(batch["pub_w"]))
    expected = _ToyLM.BASE + kd_w * kd
    assert float(loss) == pytest.approx(expected, rel=1e-5)
    # the un-T²-scaled (seed launch) convention must NOT match
    assert float(loss) != pytest.approx(_ToyLM.BASE + kd_w * kd / T ** 2,
                                        rel=1e-3)
    # and distill itself pins the T² factor: kd_loss == T² · soft-CE
    sl = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    probs = distill.soft_labels(sl, T)
    ce = -jnp.sum(probs * jax.nn.log_softmax(sl / T, -1), -1)
    assert np.allclose(np.asarray(distill.kd_loss(sl, probs, T)),
                       np.asarray(T ** 2 * ce), rtol=1e-5)


# ----------------------------------------------------- deterministic eval
def test_eval_covers_test_set_deterministically(tiny_data, mcfg):
    """_eval == exact full-test-set metrics when eval_batches suffices
    (no 256-batch wraparound double-counting; N=300 exercises the ragged
    final batch)."""
    data, _ = tiny_data
    tcfg = TrainConfig(num_nodes=3, steps=2, batch_size=8, seed=0)
    sim = DecentralizedSimulator(mcfg, tcfg, data, None, eval_batches=50)
    params = sim._stacked_init()
    acc, nll = sim._eval(params)
    mean_p = jax.tree.map(lambda t: jnp.mean(t, axis=0), params)
    logits, _ = sim.model.forward(mean_p,
                                  {"images": jnp.asarray(data.test_x)})
    acc_ref = float(jnp.mean(
        (jnp.argmax(logits, -1) == jnp.asarray(data.test_y))
        .astype(jnp.float32)))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll_ref = float(-jnp.mean(jnp.take_along_axis(
        logp, jnp.asarray(data.test_y)[:, None], 1)))
    assert acc == pytest.approx(acc_ref, abs=1e-5)
    assert nll == pytest.approx(nll_ref, abs=1e-4)
    # repeated calls are deterministic
    assert sim._eval(params) == (acc, nll)


# ------------------------------------------------------ on-device sampling
def test_sample_partition_respects_membership():
    parts = driver.pad_partitions([np.asarray([5, 6, 7]),
                                   np.asarray([10]),
                                   np.asarray([], np.int64)])
    idx = np.asarray(driver.sample_partition(parts, jax.random.PRNGKey(0),
                                             batch_size=64))
    assert idx.shape == (3, 64)
    assert set(idx[0]) <= {5, 6, 7}
    assert set(idx[1]) == {10}
    assert set(idx[2]) == {0}            # empty partition → masked index 0
    assert int(parts.size[2]) == 0


def test_samplers_reject_empty_private_partition():
    """The host samplers raised on empty partitions (np choice); the
    device samplers must too, instead of silently training on index 0."""
    parts = driver.pad_partitions([np.arange(4), np.asarray([], np.int64)])
    x = np.zeros((4, 2, 2, 1), np.float32)
    y = np.zeros((4,), np.int64)
    with pytest.raises(ValueError, match="empty private"):
        driver.make_classification_sampler(parts, x, y, 4, 2)
    with pytest.raises(ValueError, match="empty private"):
        driver.make_lm_sampler(parts, np.zeros((4, 9), np.int32), 2)


def test_homogenized_sampler_merges_sources():
    rng = np.random.default_rng(0)
    n, B, C, P = 2, 256, 4, 6
    train_x = rng.normal(size=(12, 2, 2, 1)).astype(np.float32)
    train_y = rng.integers(0, C, size=12)
    public_x = rng.normal(size=(P, 2, 2, 1)).astype(np.float32) + 100.0
    weights = np.asarray([[1, 1, 0, 0, 1, 0], [0, 0, 0, 0, 0, 0]],
                         np.float32)
    priv = driver.pad_partitions([np.arange(6), np.arange(6, 12)])
    pub = driver.pad_partitions([np.flatnonzero(w) for w in weights])
    labels = rng.dirichlet(np.ones(C), size=(n, P)).astype(np.float32)
    sample = driver.make_homogenized_sampler(
        priv, pub, train_x, train_y, public_x, weights, labels, C, B)
    batch = sample(jax.random.PRNGKey(1), jnp.asarray(0))
    is_pub = np.asarray(batch["is_pub"])
    # node 1 has an empty D_ID → never draws public
    assert not is_pub[1].any() and is_pub[0].any()
    # images selected from the right source (public shifted by +100)
    assert (np.asarray(batch["images"])[is_pub] > 50).all()
    assert (np.asarray(batch["images"])[~is_pub] < 50).all()
    # private rows carry one-hot labels, weight 1
    lab = np.asarray(batch["labels"])
    assert np.allclose(lab[~is_pub].max(-1), 1.0)
    assert np.allclose(np.asarray(batch["weights"])[~is_pub], 1.0)
