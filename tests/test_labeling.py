"""Unified labeling engine: backend equivalence + engine-specific behavior.

The dense backend is the numerical oracle; the fused (msp_select-kernel
dataflow) and sparse (top-k wire format) backends must agree with it —
exactly on the D_ID masks, allclose on the averaged labels when k = C
(lossless sparsification) — across detectors, topologies, and the
``kd_mode="vanilla"`` no-filter branch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import IDKDConfig, TrainConfig
from repro.core import distill, labeling
from repro.core.labeling import (SparseHomogenizedSet, exchange_dense,
                                 exchange_sparse, label_round)
from repro.core.topology import Topology

N, P, C = 4, 48, 10


@pytest.fixture(scope="module")
def logits():
    rng = np.random.default_rng(0)
    pub = jnp.asarray(rng.normal(size=(N, P, C)) * 3, jnp.float32)
    val = jnp.asarray(rng.normal(size=(N, 16, C)) * 5, jnp.float32)
    cal = jnp.asarray(rng.normal(size=(N, 16, C)) * 0.5, jnp.float32)
    return pub, val, cal


@pytest.mark.parametrize("topo_kind", ["ring", "full"])
@pytest.mark.parametrize("detector", ["msp", "energy"])
@pytest.mark.parametrize("backend", ["fused", "sparse"])
def test_backends_match_dense_oracle(logits, topo_kind, detector, backend):
    pub, val, cal = logits
    topo = Topology.make(topo_kind, N)
    cfg = IDKDConfig(detector=detector, label_topk=C)   # k=C: lossless
    ref = label_round(pub, val, cal, topo, cfg, backend="dense")
    out = label_round(pub, val, cal, topo, cfg, backend=backend)
    assert isinstance(out, SparseHomogenizedSet)
    np.testing.assert_array_equal(np.asarray(out.id_masks),
                                  np.asarray(ref.id_masks))
    np.testing.assert_array_equal(np.asarray(out.weights),
                                  np.asarray(ref.weights))
    np.testing.assert_allclose(np.asarray(out.thresholds),
                               np.asarray(ref.thresholds), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out.densify(C)),
                               np.asarray(ref.labels), atol=1e-4)


@pytest.mark.parametrize("backend", ["dense", "fused", "sparse"])
def test_vanilla_branch_keeps_everything(logits, backend):
    """kd_mode="vanilla": no OoD filter — all samples kept, t = 0."""
    pub, val, cal = logits
    topo = Topology.make("ring", N)
    cfg = IDKDConfig(label_topk=C)
    out = label_round(pub, val, cal, topo, cfg, backend=backend,
                      filter_ood=False)
    assert np.asarray(out.id_masks).all()
    assert (np.asarray(out.weights) == 1.0).all()
    assert (np.asarray(out.thresholds) == 0.0).all()


def test_sparse_backend_payload_stays_topk(logits):
    """With k < C the sparse payload is (max_deg+1)·k wide — never a
    (n, P, C) densification."""
    pub, val, cal = logits
    topo = Topology.make("ring", N)
    out = label_round(pub, val, cal, topo, IDKDConfig(label_topk=4),
                      backend="sparse")
    k_out = (topo.max_degree() + 1) * 4
    assert out.labels.values.shape == (N, P, k_out)
    assert out.labels.indices.shape == (N, P, k_out)
    assert k_out < C * N
    # kept samples' merged payloads are convex combinations: sum to 1
    sums = np.asarray(out.labels.values).sum(-1)
    w = np.asarray(out.weights)
    np.testing.assert_allclose(sums[w > 0], 1.0, atol=1e-4)
    assert np.allclose(sums[w == 0], 0.0, atol=1e-6)


def test_exchange_dense_matches_bruteforce():
    """Gather/scan exchange == explicit per-node neighbour averaging."""
    rng = np.random.default_rng(3)
    topo = Topology.make("social", 15)
    mask = jnp.asarray(rng.random((15, 20)) > 0.5)
    labels = jnp.asarray(rng.random((15, 20, 6)), jnp.float32)
    avg, w = exchange_dense(topo, mask, labels)
    m = np.asarray(mask, np.float32)
    lf = np.asarray(labels)
    for i in range(15):
        contributors = [i] + topo.neighbors(i)
        num = sum(m[j][:, None] * lf[j] for j in contributors)
        cnt = sum(m[j] for j in contributors)
        expect = num / np.maximum(cnt, 1.0)[:, None]
        np.testing.assert_allclose(np.asarray(avg[i]), expect, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(w[i]), (cnt > 0))


def test_exchange_sparse_matches_dense_exchange():
    """Sparse concat-exchange densifies to exactly the dense exchange of
    the densified inputs (duplicate indices accumulate)."""
    rng = np.random.default_rng(4)
    topo = Topology.make("ring", 6)
    k = 3
    probs = jnp.asarray(rng.random((6, 10, 8)), jnp.float32)
    probs = probs / probs.sum(-1, keepdims=True)
    sp = distill.sparsify_labels(probs, k)
    mask = jnp.asarray(rng.random((6, 10)) > 0.3)
    merged, w_s = exchange_sparse(topo, mask, sp)
    dense_in = distill.densify_labels(sp, 8)
    avg_d, w_d = exchange_dense(topo, mask, dense_in)
    np.testing.assert_array_equal(np.asarray(w_s), np.asarray(w_d))
    np.testing.assert_allclose(np.asarray(distill.densify_labels(merged, 8)),
                               np.asarray(avg_d), atol=1e-5)


def test_lm_rank4_logits_supported():
    """(n, P, S, V) stacks: sequence confidence + per-token sparse labels."""
    rng = np.random.default_rng(5)
    topo = Topology.make("ring", N)
    S, V = 6, 16
    pub = jnp.asarray(rng.normal(size=(N, 8, S, V)) * 2, jnp.float32)
    prv = jnp.asarray(rng.normal(size=(N, 4, S, V)) * 3, jnp.float32)
    cfg = IDKDConfig(label_topk=V)
    ref = label_round(pub, prv, pub, topo, cfg, backend="dense")
    out = label_round(pub, prv, pub, topo, cfg, backend="sparse")
    assert out.labels.values.shape[:3] == (N, 8, S)
    np.testing.assert_array_equal(np.asarray(out.id_masks),
                                  np.asarray(ref.id_masks))
    np.testing.assert_allclose(np.asarray(out.densify(V)),
                               np.asarray(ref.labels), atol=1e-4)


@pytest.mark.parametrize("backend", ["dense", "fused", "sparse"])
def test_cal_none_means_public_set(logits, backend):
    """cal_logits=None == passing the public logits (D_C = D_P), and the
    reuse survives jit (no object-identity dependence)."""
    pub, val, _ = logits
    topo = Topology.make("ring", N)
    cfg = IDKDConfig(label_topk=C)
    explicit = label_round(pub, val, pub, topo, cfg, backend=backend)
    reused = label_round(pub, val, None, topo, cfg, backend=backend)
    jitted = jax.jit(lambda p, v: label_round(p, v, None, topo, cfg,
                                              backend=backend))(pub, val)
    for out in (reused, jitted):
        np.testing.assert_allclose(np.asarray(out.thresholds),
                                   np.asarray(explicit.thresholds),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out.id_masks),
                                      np.asarray(explicit.id_masks))


def test_unknown_backend_raises(logits):
    pub, val, cal = logits
    with pytest.raises(ValueError, match="backend"):
        label_round(pub, val, cal, Topology.make("ring", N), IDKDConfig(),
                    backend="nope")


def test_simulator_runs_sparse_backend():
    """End-to-end: the simulator trains through the sparse KD step with
    top-k payloads (labels never densified to (n, P, C))."""
    from repro.configs.resnet20_cifar import SMALL_CONFIG
    from repro.core.simulator import DecentralizedSimulator
    from repro.data.synthetic import (make_classification_data,
                                      make_public_data)
    data = make_classification_data(image_size=8, n_train=256, n_val=64,
                                    n_test=128, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=96, kind="aligned", seed=1)
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=4, alpha=0.05,
                       steps=8, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=4, temperature=10.0,
                                       label_topk=4, label_backend="sparse"))
    sim = DecentralizedSimulator(SMALL_CONFIG.replace(image_size=8), tcfg,
                                 data, pub, kd_mode="idkd", eval_every=7)
    r = sim.run()
    assert 0.0 < r.id_fraction <= 1.0
    assert np.isfinite(r.loss_history).all()
    assert r.post_hist is not None and np.isfinite(r.post_hist).all()
    # top-k wire accounting: far below the dense label payload
    dense_bytes = distill.label_bytes(96, 10)
    assert r.label_bytes_total < 4 * dense_bytes
