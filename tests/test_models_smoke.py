"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch is instantiated in its REDUCED variant (≤2 layers,
d_model ≤ 512, ≤4 experts) and runs one forward + one decentralized train
step on CPU, asserting output shapes and absence of NaNs. The FULL configs
are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, get_config
from repro.configs.base import TrainConfig
from repro.launch.steps import make_train_step, stack_params
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, nodes=None):
    lead = (nodes, B) if nodes else (B,)
    tok_shape = lead + ((S, cfg.num_codebooks) if cfg.num_codebooks > 1
                        else (S,))
    batch = {
        "tokens": jax.random.randint(KEY, tok_shape, 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, tok_shape, 0, cfg.vocab_size),
    }
    if cfg.arch_type == "vlm":
        batch["patch_embeddings"] = jax.random.normal(
            KEY, lead + (cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
    if cfg.cross_attention:
        batch["conditioning"] = jax.random.normal(
            KEY, lead + (cfg.cross_attn_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe.enabled:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    B, S = 2, 16
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch} produced NaNs"
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))


# default gossip-step coverage: one arch per family (dense / SSM / VLM);
# the rest are the slow grid (pytest -m slow). Every arch still gets its
# forward/loss smoke test by default.
_FAST_STEP_ARCHS = {"qwen3-1.7b", "mamba2-780m", "paligemma-3b"}


@pytest.mark.parametrize("arch", [
    a if a in _FAST_STEP_ARCHS else pytest.param(a,
                                                 marks=pytest.mark.slow)
    for a in ASSIGNED_ARCHS])
def test_reduced_decentralized_train_step(arch):
    """One QG-DSGDm-N gossip step over 4 nodes: params move, stay finite."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    nodes = 4
    tcfg = TrainConfig(num_nodes=nodes, lr=0.05)
    step = jax.jit(make_train_step(model, tcfg, nodes))
    params = stack_params(model.init(KEY), nodes)
    opt = step.init_opt(params)
    batch = _batch(cfg, nodes=nodes)
    new_params, new_opt, metrics = step(params, opt, batch,
                                        jnp.asarray(0.05, jnp.float32))
    assert bool(jnp.isfinite(metrics["loss"]))
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0, f"{arch} params did not move"
    finite = jax.tree.map(lambda t: bool(jnp.isfinite(
        t.astype(jnp.float32)).all()), new_params)
    assert all(jax.tree.leaves(finite)), f"{arch} NaN params after step"


def test_resnet_smoke():
    cfg = get_config("resnet20-cifar").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = {"images": jax.random.normal(
        KEY, (2, cfg.image_size, cfg.image_size, 3)),
        "labels": jnp.asarray([0, 1])}
    logits, _ = model.forward(params, batch)
    assert logits.shape == (2, cfg.num_classes)
    loss, m = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B, ctx = 2, 8
    st = model.init_decode_state(B, ctx)
    tok_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, 1)
    tok = jax.random.randint(KEY, tok_shape, 0, cfg.vocab_size)
    mem = (jax.random.normal(KEY, (B, cfg.cross_attn_len, cfg.d_model),
                             jnp.float32) if cfg.cross_attention else None)
    logits, st = model.decode_step(params, tok, st, memory=mem)
    assert bool(jnp.isfinite(logits).all())
    assert logits.shape[-1] == cfg.vocab_size


def test_param_count_analytic_close_to_actual():
    """Analytic count (used by Table 6 comm cost) ≈ real leaf sizes."""
    for arch in ["qwen3-1.7b", "phi3-mini-3.8b"]:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        actual = sum(x.size for x in jax.tree.leaves(model.init(KEY)))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, arch


def test_remat_policy_values_agree_and_validate():
    """remat_policy only changes what the backward pass recomputes:
    "nothing" (+ its legacy alias "full"), "dots", and "everything"
    must produce identical losses and gradients; unknown names fail
    with the valid choices."""
    base = get_config("qwen3-1.7b").reduced().replace(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=64, dtype="float32", remat=True, scan_layers=True)
    batch = _batch(base.replace(remat=False))
    out = {}
    for pol in ("nothing", "full", "dots", "everything"):
        model = build_model(base.replace(remat_policy=pol))
        params = model.init(KEY)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch)[0])(params)
        out[pol] = (loss, grads)
    ref_loss, ref_grads = out["nothing"]
    assert bool(jnp.isfinite(ref_loss))
    for pol, (loss, grads) in out.items():
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-6)
        for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(ref_grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
    with pytest.raises(ValueError, match="remat_policy"):
        model = build_model(base.replace(remat_policy="bogus"))
        model.loss(model.init(KEY), batch)
