import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep: shim keeps collection
    from hypothesis_shim import given, settings, st


from repro.data.dirichlet import dirichlet_partition, partition_stats
from repro.data.pipeline import HomogenizedSampler, NodeSampler
from repro.data.synthetic import (make_classification_data, make_lm_data,
                                  make_public_data)


@given(alpha=st.floats(0.05, 10.0), n_nodes=st.integers(2, 16))
@settings(max_examples=15, deadline=None)
def test_partition_disjoint_and_covering(alpha, n_nodes):
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=500)
    parts = dirichlet_partition(labels, n_nodes, alpha, rng)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)  # disjoint + covering


def test_skew_monotone_in_alpha():
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 10, size=4000)

    def skew(alpha):
        parts = dirichlet_partition(labels, 8, alpha,
                                    np.random.default_rng(42))
        h = partition_stats(labels, parts, 10)
        return np.mean(0.5 * np.abs(h - 0.1).sum(-1))

    assert skew(0.05) > skew(1.0) > skew(100.0)


def test_classification_data_learnable_structure():
    d = make_classification_data(n_train=512, n_test=128, noise=0.3)
    # nearest-mean classifier should beat chance by a lot
    dists = ((d.test_x[:, None] - d.class_means[None]) ** 2
             ).reshape(len(d.test_y), 10, -1).sum(-1)
    acc = (dists.argmin(1) == d.test_y).mean()
    assert acc > 0.8


def test_public_data_kinds():
    d = make_classification_data(n_train=256, n_test=64)
    for kind in ("aligned", "shifted", "noise"):
        pub = make_public_data(d, n_public=128, kind=kind)
        assert pub.shape == (128, 16, 16, 3)
        assert np.isfinite(pub).all()


def test_lm_data_topic_structure():
    tokens, topics = make_lm_data(vocab=100, seq_len=32, n_seqs=64,
                                  num_topics=10)
    assert tokens.shape == (64, 32)
    assert (tokens >= 0).all() and (tokens < 100).all()
    # sequences of topic t concentrate in slice [10t, 10(t+1))
    t0 = tokens[topics == 0]
    if len(t0):
        in_slice = ((t0 >= 0) & (t0 < 10)).mean()
        assert in_slice > 0.5


def test_node_sampler_shapes():
    parts = [np.arange(10), np.arange(10, 30)]
    s = NodeSampler(parts, batch_size=8, seed=0)
    idx = s.sample()
    assert idx.shape == (2, 8)
    assert (idx[0] < 10).all() and (idx[1] >= 10).all()


def test_homogenized_sampler_mixes_sources():
    parts = [np.arange(10), np.arange(10, 20)]
    w = np.ones((2, 50), np.float32)
    s = HomogenizedSampler(parts, w, batch_size=64, seed=0)
    priv, pub, is_pub = s.sample()
    assert is_pub.mean() > 0.5  # public pool much larger than private
    assert (pub < 50).all()


def test_homogenized_sampler_refresh_swaps_round_state():
    """refresh() is the host-side repeated-round path: a new round's
    D_ID selection and payload replace the old without resetting the
    per-node RNG streams."""
    rng = np.random.default_rng(0)
    parts = [np.arange(10), np.arange(10, 20)]
    w1 = np.zeros((2, 50), np.float32)
    w1[:, :10] = 1.0
    lab1 = rng.dirichlet(np.ones(4), size=(2, 50)).astype(np.float32)
    s = HomogenizedSampler(parts, w1, batch_size=64, seed=0,
                           public_labels=lab1)
    _, pub1, is_pub1 = s.sample()
    assert (pub1[is_pub1] < 10).all()
    w2 = np.zeros((2, 50), np.float32)
    w2[:, 40:] = 1.0
    lab2 = rng.dirichlet(np.ones(4), size=(2, 50)).astype(np.float32)
    s.refresh(w2, public_labels=lab2)
    _, pub2, is_pub2 = s.sample()
    assert (pub2[is_pub2] >= 40).all()       # draws follow the new D_ID
    assert np.allclose(s.gather_public(pub2),
                       lab2[np.arange(2)[:, None], pub2])
    # RNG streams advance across a refresh — same round state again does
    # not replay the previous draws
    s.refresh(w2, public_labels=lab2)
    _, pub3, _ = s.sample()
    assert not np.array_equal(pub2, pub3)
