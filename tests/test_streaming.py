"""Streaming label rounds (DESIGN.md §8): head_select kernel parity,
streaming == one-shot equivalence, the no-dense-stack jaxpr audit, and
end-to-end trajectory equality of the streaming vs one-shot rounds.

* ``head_select`` (vocab-tiled fused select from hidden states) must
  match its jnp oracle in interpret mode — fixed shapes plus a
  hypothesis sweep over scales/temperatures/k, same style as
  ``tests/test_kernels_msp.py``.
* ``streaming_label_round`` must reproduce the one-shot fused backend
  of ``label_round`` to float tolerance — classifier (n, P, C) and LM
  (n, P, S, V) stacks, ring + complete graphs, including a public-set
  size that is *not* a multiple of the microbatch (ragged tail).
* The jaxpr of the streaming round must contain **no** intermediate
  shaped like the public logit stack — the audit walks every sub-jaxpr
  (scan bodies included) and is validated against the one-shot path,
  where the forbidden shape *is* present.
* Fixed-seed end-to-end trajectories (simulator and LM launch,
  node-stacked and shard drivers) with streaming rounds must match the
  ``stream_labels=False`` one-shot rounds to float tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep: shim keeps collection
    from hypothesis_shim import given, settings, st

from repro.configs.base import IDKDConfig, ModelConfig, TrainConfig
from repro.core import labeling
from repro.core.topology import Topology
from repro.kernels.head_select import head_select, head_select_ref
from repro.models import build_model

N = 4


# ------------------------------------------------------ head_select kernel
def _check_head(h, w, b, T, k, det="msp", block_rows=4, block_c=64):
    conf, vals, idx = head_select(h, w, b, temperature=T, k=k,
                                  block_rows=block_rows, block_c=block_c,
                                  interpret=True, detector=det)
    cr, vr, ir = head_select_ref(h, w, b, temperature=T, k=k, detector=det)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vr), atol=1e-5)
    assert (np.asarray(idx) == np.asarray(ir)).all()


@pytest.mark.parametrize("rows,D,C,k,bc", [(16, 32, 200, 4, 64),
                                           (8, 16, 50, 8, 16),
                                           (24, 64, 1024, 8, 256)])
@pytest.mark.parametrize("T", [1.0, 10.0])
def test_head_select_matches_ref(rows, D, C, k, bc, T):
    """Vocab-tiled kernel == oracle, including ragged C (200 % 64 != 0)."""
    rng = np.random.default_rng(rows + C)
    h = jnp.asarray(rng.normal(size=(rows, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, C)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
    _check_head(h, w, b, T, k, block_c=bc)


@pytest.mark.parametrize("det", ["msp", "energy"])
def test_head_select_detector_matches_ref(det):
    """Both OoD detectors fall out of the one online-softmax carry."""
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(size=(8, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 96)) * 0.5, jnp.float32)
    _check_head(h, w, None, 5.0, 4, det=det, block_c=32)


def test_head_select_single_vocab_block():
    """block_c >= C degenerates to the unblocked msp_select dataflow."""
    rng = np.random.default_rng(9)
    h = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 40)), jnp.float32)
    _check_head(h, w, None, 10.0, 4, block_c=512)


# ----------------------------------------- vocab-sharded stats + merge
def _merged_shards(h, w, b, S, T, k, det):
    """Emulate the 2-D label round's vocab sharding in pure numpy/jnp:
    pad W to S equal column shards (padded bias = NEG_INF so fake
    columns self-mask), per-shard raw stats, offset local indices to
    global, merge across shards."""
    from repro.kernels.head_select import (NEG_INF, head_select_stats_ref,
                                           merge_head_stats)
    C = w.shape[1]
    w_sh = -(-C // S)
    pad = S * w_sh - C
    wp = np.pad(np.asarray(w), ((0, 0), (0, pad)))
    bv = np.zeros(C, np.float32) if b is None else np.asarray(b)
    bp = np.pad(bv, (0, pad), constant_values=NEG_INF)
    k_loc = min(k, w_sh)
    ms, zs, tvs, tis = [], [], [], []
    for s in range(S):
        m, z, tv, ti = head_select_stats_ref(
            jnp.asarray(h), jnp.asarray(wp[:, s * w_sh:(s + 1) * w_sh]),
            jnp.asarray(bp[s * w_sh:(s + 1) * w_sh]), k=k_loc)
        ms.append(m)
        zs.append(z)
        tvs.append(tv)
        tis.append(ti + s * w_sh)
    return merge_head_stats(jnp.stack(ms), jnp.stack(zs), jnp.stack(tvs),
                            jnp.stack(tis), temperature=T, k=k,
                            detector=det)


@pytest.mark.parametrize("det", ["msp", "energy"])
@pytest.mark.parametrize("C,S,k", [(50, 4, 4),    # ragged: 50 % 4 != 0
                                   (64, 4, 8),    # exact split
                                   (10, 3, 8),    # k > shard width (k_loc=4)
                                   (96, 2, 1)])
def test_merge_head_stats_matches_unsharded_ref(det, C, S, k):
    """The cross-shard online-softmax merge == the unsharded oracle:
    same confidences, renormalized top-k payloads, and *global* vocab
    indices — including ragged vocab tails (C % S != 0, where padded
    columns must self-mask out of both z and the top-k) and shards
    narrower than k."""
    rng = np.random.default_rng(C * 7 + S)
    h = jnp.asarray(rng.normal(size=(12, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, C)) * 0.5, jnp.float32)
    b = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
    conf, vals, idx = _merged_shards(h, w, b, S, 5.0, k, det)
    cr, vr, ir = head_select_ref(h, w, b, temperature=5.0, k=k,
                                 detector=det)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(cr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vr), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ir))


def test_merge_head_stats_no_bias_matches_ref():
    """bias=None on the sharded path (zeros + NEG_INF padding) == the
    no-bias oracle."""
    rng = np.random.default_rng(42)
    h = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 50)), jnp.float32)
    conf, vals, idx = _merged_shards(h, w, None, 4, 10.0, 4, "msp")
    cr, vr, ir = head_select_ref(h, w, temperature=10.0, k=4)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(cr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vr), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ir))


def test_head_select_raw_stats_matches_stats_ref():
    """The kernel's raw_stats mode (what the vocab-sharded round feeds
    the merge on TPU) == the jnp stats oracle: pre-softmax m/z and raw
    top-k logits, not finalized payloads."""
    from repro.kernels.head_select import head_select_stats_ref
    rng = np.random.default_rng(5)
    h = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 80)) * 0.5, jnp.float32)
    b = jnp.asarray(rng.normal(size=(80,)), jnp.float32)
    m, z, tv, ti = head_select(h, w, b, temperature=7.0, k=4,
                               block_rows=4, block_c=32, interpret=True,
                               raw_stats=True)
    mr, zr, tvr, tir = head_select_stats_ref(h, w, b, k=4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(tvr), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(tir))


@given(scale=st.floats(0.1, 4.0), T=st.floats(0.5, 20.0),
       k=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_head_select_property(scale, T, k):
    """Hypothesis sweep over scales/temperatures/k: kernel == oracle and
    payloads are sorted, renormalized convex weights."""
    rng = np.random.default_rng(int(scale * 100) + k)
    h = jnp.asarray(rng.normal(size=(8, 16)) * scale, jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 72)), jnp.float32)
    conf, vals, idx = head_select(h, w, temperature=T, k=k, block_rows=4,
                                  block_c=32, interpret=True)
    cr, vr, ir = head_select_ref(h, w, temperature=T, k=k)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vr), atol=1e-5)
    v = np.asarray(vals)
    assert (np.diff(v, axis=-1) <= 1e-6).all()
    np.testing.assert_allclose(v.sum(-1), 1.0, atol=1e-4)


# ------------------------------------------------- fixtures (tiny models)
@pytest.fixture(scope="module")
def cls_setup():
    rng = np.random.default_rng(0)
    mcfg = ModelConfig(arch_type="cnn", cnn_stages=(1,), cnn_width=8,
                       image_size=8, num_classes=10)
    model = build_model(mcfg)
    params = jax.vmap(model.init)(
        jax.random.split(jax.random.PRNGKey(0), N))
    P = 52                                 # not a multiple of microbatch 8
    pub = jnp.asarray(rng.normal(size=(P, 8, 8, 3)), jnp.float32)
    val = jnp.asarray(rng.normal(size=(N, 6, 8, 8, 3)), jnp.float32)
    return model, params, pub, val


@pytest.fixture(scope="module")
def lm_setup():
    rng = np.random.default_rng(1)
    mcfg = ModelConfig(arch_type="dense", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                       dtype="float32", remat=False)
    model = build_model(mcfg)
    params = jax.vmap(model.init)(
        jax.random.split(jax.random.PRNGKey(1), N))
    pub = jnp.asarray(rng.integers(0, 64, size=(21, 6)), jnp.int32)
    val = jnp.asarray(rng.integers(0, 64, size=(N, 4, 6)), jnp.int32)
    return model, params, pub, val


def _one_shot(model, params, pub, val, topo, cfg, key=None):
    """The one-shot fused reference: full logit stacks into label_round."""
    fwd = jax.vmap(lambda p, x: model.forward(
        p, {model.input_key: x})[0])
    n = jax.tree.leaves(params)[0].shape[0]
    pub_b = jnp.broadcast_to(pub[None], (n,) + pub.shape)
    return labeling.label_round(fwd(params, pub_b), fwd(params, val),
                                None, topo, cfg, backend="fused")


def _assert_rounds_match(out, ref, C):
    assert isinstance(out, labeling.SparseHomogenizedSet)
    np.testing.assert_allclose(np.asarray(out.thresholds),
                               np.asarray(ref.thresholds), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out.id_masks),
                                  np.asarray(ref.id_masks))
    np.testing.assert_array_equal(np.asarray(out.weights),
                                  np.asarray(ref.weights))
    np.testing.assert_allclose(np.asarray(out.densify(C)),
                               np.asarray(ref.densify(C)), atol=1e-5)


# ------------------------------------------- streaming == one-shot rounds
@pytest.mark.parametrize("topo_kind", ["ring", "full"])
@pytest.mark.parametrize("mb", [8, 52, 64])
def test_streaming_matches_one_shot_classifier(cls_setup, topo_kind, mb):
    """(n, P, C) stacks: P=52 is ragged at mb=8 (6 full chunks + tail 4),
    exact at mb=52, single-chunk at mb=64 > P."""
    model, params, pub, val = cls_setup
    topo = Topology.make(topo_kind, N)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=mb)
    ref = _one_shot(model, params, pub, val, topo, cfg)
    out = labeling.streaming_label_round(model, params, pub, val, topo, cfg)
    _assert_rounds_match(out, ref, 10)


@pytest.mark.parametrize("topo_kind", ["ring", "full"])
def test_streaming_matches_one_shot_lm(lm_setup, topo_kind):
    """(n, P, S, V) stacks: per-token payloads, sequence confidence =
    mean over S; P=21 is ragged at mb=8."""
    model, params, pub, val = lm_setup
    topo = Topology.make(topo_kind, N)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=8)
    ref = _one_shot(model, params, pub, val, topo, cfg)
    out = labeling.streaming_label_round(model, params, pub, val, topo, cfg)
    assert out.labels.values.shape[:3] == (N, 21, 6)
    _assert_rounds_match(out, ref, 64)


def test_streaming_detectors_and_vanilla(cls_setup):
    """Energy detector and the filter_ood=False baseline stream too."""
    model, params, pub, val = cls_setup
    topo = Topology.make("ring", N)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=8, detector="energy")
    ref = _one_shot(model, params, pub, val, topo,
                    IDKDConfig(label_topk=4, detector="energy"))
    out = labeling.streaming_label_round(model, params, pub, val, topo, cfg)
    _assert_rounds_match(out, ref, 10)
    out = labeling.streaming_label_round(model, params, pub, val, topo, cfg,
                                         filter_ood=False)
    assert np.asarray(out.id_masks).all()
    assert (np.asarray(out.thresholds) == 0.0).all()


def test_streaming_active_mask(cls_setup):
    """Churn: a down node contributes and receives nothing."""
    model, params, pub, val = cls_setup
    topo = Topology.make("ring", N)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=16)
    active = np.array([True, False, True, True])
    out = labeling.streaming_label_round(model, params, pub, val, topo, cfg,
                                         active=active)
    assert not np.asarray(out.id_masks)[1].any()
    assert (np.asarray(out.weights)[1] == 0).all()


def test_shard_streaming_matches_stacked(cls_setup):
    """The shard twin (scan inside shard_map, top-k-only exchange) equals
    the node-stacked streaming round on any device count."""
    from repro.launch.mesh import make_node_mesh
    model, params, pub, val = cls_setup
    mesh = make_node_mesh(N)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=8)
    for topo_kind in ("ring", "full"):
        topo = Topology.make(topo_kind, N)
        ref = labeling.streaming_label_round(model, params, pub, val, topo,
                                             cfg)
        out = labeling.shard_streaming_label_round(
            model, params, pub, val, topo, cfg, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(out.id_masks),
                                      np.asarray(ref.id_masks))
        np.testing.assert_allclose(np.asarray(out.thresholds),
                                   np.asarray(ref.thresholds), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(out.weights),
                                      np.asarray(ref.weights))
        np.testing.assert_allclose(np.asarray(out.densify(10)),
                                   np.asarray(ref.densify(10)), atol=1e-5)


@pytest.mark.parametrize("setup_name,C", [("cls_setup", 10),
                                          ("lm_setup", 64)])
def test_shard_streaming_2d_mesh_matches_stacked(request, setup_name, C):
    """The vocab-sharded round on the 2-D (node, model) mesh — per-shard
    head passes merged with the online-softmax streaming math — equals
    the node-stacked streaming round, classifier and LM stacks. C=10
    over model=2 shards ragged-free; vocab=64 splits exactly; both hit
    the NEG_INF-padded tail when the device pool forces model > C
    factors."""
    if len(jax.devices()) < 2:
        pytest.skip("model axis needs >= 2 devices")
    from repro.launch.mesh import make_federation_mesh
    model, params, pub, val = request.getfixturevalue(setup_name)
    mesh = make_federation_mesh(N, 2)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=8)
    for topo_kind in ("ring", "full"):
        topo = Topology.make(topo_kind, N)
        ref = labeling.streaming_label_round(model, params, pub, val, topo,
                                             cfg)
        out = labeling.shard_streaming_label_round(
            model, params, pub, val, topo, cfg, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(out.id_masks),
                                      np.asarray(ref.id_masks))
        np.testing.assert_allclose(np.asarray(out.thresholds),
                                   np.asarray(ref.thresholds), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(out.weights),
                                      np.asarray(ref.weights))
        np.testing.assert_allclose(np.asarray(out.densify(C)),
                                   np.asarray(ref.densify(C)), atol=1e-5)


# --------------------------------------------------------- jaxpr audit
def _iter_avals(jaxpr):
    """Every intermediate aval in a jaxpr, sub-jaxprs (scan bodies,
    branches, pjit calls) included."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval"):
                yield v.aval
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if isinstance(sub, jax.core.Jaxpr):
                    yield from _iter_avals(sub)
                elif inner is not None and isinstance(inner,
                                                      jax.core.Jaxpr):
                    yield from _iter_avals(inner)


def _dense_stack_avals(jaxpr, P, C):
    """Intermediates that hold a public logit stack: last dim C with the
    full public axis P also present (e.g. (n, P, C) or (n, P, S, C))."""
    return [a.shape for a in _iter_avals(jaxpr)
            if getattr(a, "shape", ()) and a.shape[-1] == C
            and P in a.shape[:-1]]


def test_streaming_jaxpr_has_no_dense_stack(cls_setup, lm_setup):
    """The shape audit: no (n, P, C)- or (n, P, S, V)-shaped intermediate
    anywhere in the streaming round's jaxpr — validated against the
    one-shot round, where the stack IS present."""
    topo = Topology.make("ring", N)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=8)
    for setup, C in ((cls_setup, 10), (lm_setup, 64)):
        model, params, pub, val = setup
        P = pub.shape[0]
        stream_jaxpr = jax.make_jaxpr(
            lambda pr, pb, vl: labeling.streaming_label_round(
                model, pr, pb, vl, topo, cfg))(params, pub, val)
        assert not _dense_stack_avals(stream_jaxpr.jaxpr, P, C), \
            _dense_stack_avals(stream_jaxpr.jaxpr, P, C)
        one_shot_jaxpr = jax.make_jaxpr(
            lambda pr, pb, vl: _one_shot(model, pr, pb, vl, topo, cfg))(
                params, pub, val)
        assert _dense_stack_avals(one_shot_jaxpr.jaxpr, P, C), \
            "audit is blind: one-shot stack not detected"


def test_shard_streaming_jaxpr_has_no_dense_stack(cls_setup):
    """Same audit through shard_map: the scan inside the shard body
    keeps every logit intermediate at microbatch width."""
    from repro.launch.mesh import make_node_mesh
    model, params, pub, val = cls_setup
    topo = Topology.make("ring", N)
    cfg = IDKDConfig(label_topk=4, stream_microbatch=8)
    jx = jax.make_jaxpr(
        lambda pr, pb, vl: labeling.shard_streaming_label_round(
            model, pr, pb, vl, topo, cfg, mesh=make_node_mesh(N)))(
                params, pub, val)
    assert not _dense_stack_avals(jx.jaxpr, pub.shape[0], 10)


# --------------------------------------- end-to-end trajectory equality
def _sim_result(stream: bool, driver_mode: str):
    from repro.configs.resnet20_cifar import SMALL_CONFIG
    from repro.core.simulator import DecentralizedSimulator
    from repro.data.synthetic import (make_classification_data,
                                      make_public_data)
    data = make_classification_data(image_size=8, n_train=256, n_val=64,
                                    n_test=128, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=96, kind="aligned", seed=1)
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", num_nodes=4, alpha=0.05,
                       steps=8, batch_size=8, lr=0.3, seed=4,
                       idkd=IDKDConfig(start_step=4, temperature=10.0,
                                       label_topk=4, label_backend="sparse",
                                       stream_labels=stream,
                                       stream_microbatch=40))  # 96 ragged
    mcfg = SMALL_CONFIG.replace(image_size=8, conv_backend="im2col")
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode="idkd",
                                 eval_every=3, driver_mode=driver_mode)
    return sim.run()


@pytest.mark.parametrize("driver_mode", ["scan", "shard"])
def test_sim_trajectory_streaming_equals_one_shot(driver_mode):
    """Simulator end-to-end on fixed seeds: the streaming round and the
    one-shot round produce the same training trajectory, node-stacked
    and sharded."""
    stream = _sim_result(True, driver_mode)
    one_shot = _sim_result(False, driver_mode)
    np.testing.assert_allclose(stream.acc_history, one_shot.acc_history,
                               atol=1e-5)
    np.testing.assert_allclose(stream.loss_history, one_shot.loss_history,
                               atol=1e-4)
    np.testing.assert_allclose(stream.thresholds, one_shot.thresholds,
                               atol=1e-5)
    assert stream.label_bytes_total == one_shot.label_bytes_total


def _lm_history(stream: bool, driver_mode: str):
    from repro.configs import get_config
    from repro.launch.train import run_training
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    tcfg = TrainConfig(num_nodes=2, steps=6, lr=0.1, alpha=0.1,
                       batch_size=4,
                       idkd=IDKDConfig(start_step=3, label_topk=4,
                                       kd_weight=0.3, stream_labels=stream,
                                       stream_microbatch=3))  # 8 ragged
    out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                       use_idkd=True, log_every=2, verbose=False,
                       driver_mode=driver_mode)
    return out["loss_history"]


@pytest.mark.parametrize("driver_mode", ["scan", "shard"])
def test_lm_trajectory_streaming_equals_one_shot(driver_mode):
    """LM launch end-to-end on fixed seeds, node-stacked and sharded."""
    np.testing.assert_allclose(_lm_history(True, driver_mode),
                               _lm_history(False, driver_mode),
                               rtol=1e-4, atol=1e-5)
