"""Pallas SSD scan kernel vs the sequential-recurrence oracle, plus the
model's chunked jnp dual form (repro.models.ssm.ssd_chunked)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref
from repro.models.ssm import ssd_chunked


def _inputs(B, S, H, P, N, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)), dtype)
    dta = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.2, dtype)
    b = jnp.asarray(rng.normal(size=(B, S, H, N)), dtype)
    c = jnp.asarray(rng.normal(size=(B, S, H, N)), dtype)
    return xdt, dta, b, c


@pytest.mark.parametrize("B,S,H,P,N", [
    (1, 128, 2, 16, 8),
    (2, 256, 4, 64, 16),     # hymba-like (P=64, N=16)
    (1, 256, 2, 64, 128),    # mamba2-like state (N=128)
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_kernel_matches_sequential_ref(B, S, H, P, N, dtype):
    xdt, dta, b, c = _inputs(B, S, H, P, N, dtype)
    y = ssd_scan(xdt, dta, b, c, chunk=64, interpret=True)
    y_ref, _ = ssd_scan_ref(xdt, dta, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssd_chunk_size_invariance(chunk):
    xdt, dta, b, c = _inputs(1, 128, 2, 16, 8, seed=3)
    y = ssd_scan(xdt, dta, b, c, chunk=chunk, interpret=True)
    y_ref, _ = ssd_scan_ref(xdt, dta, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)


def test_model_chunked_dual_matches_sequential():
    """repro.models.ssm.ssd_chunked (the XLA dual form used inside
    ssm_forward) against the sequential recurrence oracle.

    ssd_chunked(x, dt, a_log, b, c) computes the recurrence with
    xdt = x·dt and dta = dt·(−exp(a_log)); drive the oracle with those."""
    rng = np.random.default_rng(4)
    B, S, H, P, N = 2, 128, 3, 16, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) * 0.5 + 0.1,
                     jnp.float32)
    a_log = jnp.asarray(rng.normal(size=(H,)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)  # 1 group
    c = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
    y_model, st_model = ssd_chunked(x, dt, a_log, b, c, chunk=32)
    a = -jnp.exp(a_log)
    b_h = jnp.broadcast_to(b, (B, S, H, N))
    c_h = jnp.broadcast_to(c, (B, S, H, N))
    y_ref, st_ref = ssd_scan_ref(x * dt[..., None], dt * a, b_h, c_h)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st_model), np.asarray(st_ref),
                               atol=2e-3, rtol=2e-3)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in two with state carry == one pass (oracle)."""
    xdt, dta, b, c = _inputs(1, 128, 2, 8, 4, seed=5)
    y_full, st_full = ssd_scan_ref(xdt, dta, b, c)
    y1, st1 = ssd_scan_ref(xdt[:, :64], dta[:, :64], b[:, :64], c[:, :64])
    y2, st2 = ssd_scan_ref(xdt[:, 64:], dta[:, 64:], b[:, 64:], c[:, 64:],
                           initial_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4)
