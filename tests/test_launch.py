"""Launch-layer unit tests that need no devices: input specs, shape
support rules, config registry, param-count analytics, HLO parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, ASSIGNED_ARCHS, LONG_CONTEXT_VARIANTS,
                           SHAPES, get_config, shape_supported)
from repro.launch import input_specs as ispec
from repro.launch.dryrun import collective_bytes
from repro.models import build_model


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        assert cfg.source, f"{a} missing source citation"


def test_long_context_support_rules():
    """long_500k runs for SSM/hybrid/sliding-window, skips pure full-attn."""
    runs = [a for a in ASSIGNED_ARCHS
            if shape_supported(get_config(a, shape="long_500k"),
                               SHAPES["long_500k"])]
    assert set(runs) == {"mamba2-780m", "hymba-1.5b", "mistral-nemo-12b"}
    # the mistral long-context variant is the sliding-window config
    assert LONG_CONTEXT_VARIANTS["mistral-nemo-12b"].sliding_window == 4096


def test_exact_assigned_configs():
    """Spot-check the assignment table numbers."""
    c = get_config("deepseek-v3-671b")
    assert (c.num_layers, c.d_model, c.num_heads) == (61, 7168, 128)
    assert (c.moe.num_experts, c.moe.num_experts_per_tok) == (256, 8)
    c = get_config("arctic-480b")
    assert (c.num_layers, c.moe.num_experts, c.moe.num_experts_per_tok) == \
        (35, 128, 2)
    c = get_config("hymba-1.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == \
        (32, 1600, 25, 5)
    c = get_config("mamba2-780m")
    assert c.ssm.state_size == 128 and c.is_attention_free
    c = get_config("paligemma-3b")
    assert c.num_kv_heads == 1 and c.vocab_size == 257_216


def test_param_counts_at_scale():
    """Analytic totals near the models' nameplate sizes."""
    approx = {
        "deepseek-v3-671b": (671e9, 0.10),
        "arctic-480b": (480e9, 0.15),
        "mistral-nemo-12b": (12e9, 0.15),
        "phi3-mini-3.8b": (3.8e9, 0.15),
        "qwen1.5-0.5b": (0.46e9, 0.25),
        "mamba2-780m": (0.78e9, 0.25),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, f"{arch}: {n:.3e}"


def test_train_specs_shapes():
    cfg = get_config("qwen3-1.7b")
    specs = ispec.train_specs(cfg, SHAPES["train_4k"], num_nodes=16)
    assert specs["tokens"].shape == (16, 16, 4096)
    assert specs["tokens"].dtype == jnp.int32
    cfg = get_config("musicgen-medium")
    specs = ispec.train_specs(cfg, SHAPES["train_4k"], num_nodes=16)
    assert specs["tokens"].shape == (16, 16, 4096, 4)
    assert specs["conditioning"].shape == (16, 16, 64, 1536)
    cfg = get_config("paligemma-3b")
    specs = ispec.train_specs(cfg, SHAPES["train_4k"], num_nodes=16)
    assert specs["patch_embeddings"].shape == (16, 16, 256, 2048)


def test_decode_specs_use_eval_shape_only():
    """decode_specs must not allocate: works on a reduced model and
    returns ShapeDtypeStructs for the full cache pytree."""
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    tok, state, extras = ispec.decode_specs(cfg, SHAPES["decode_32k"], model)
    assert tok.shape == (128, 1)
    leaves = jax.tree.leaves(state)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    kv = state[0]["kv"]
    assert kv.k.shape[2] == 32_768          # (L, B, cap, KVH, hd)


def test_collective_parser_counts_while_loops():
    hlo = """
HloModule m

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(28)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (a: bf16[2,2]) -> bf16[2,2] {
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %cp = f32[64]{0} collective-permute(%a), source_target_pairs={{0,1}}
  ROOT %r = bf16[2,2] copy(%a)
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 2 * 28     # ×28 trip count
    assert out["collective-permute"] == 64 * 4          # entry: ×1


def test_reduced_variant_bounds():
    for a in ASSIGNED_ARCHS:
        r = get_config(a).reduced()
        assert r.num_layers <= 2
        assert r.d_model <= 512
        if r.moe.enabled:
            assert r.moe.num_experts <= 4
