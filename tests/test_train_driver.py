"""LLM decentralized trainer driver smoke: a few steps incl. an IDKD
label-exchange round with top-k sparse labels."""
import pytest

from repro.configs import get_config
from repro.configs.base import IDKDConfig, TrainConfig
from repro.launch.train import run_training


def _tiny(arch):
    return get_config(arch).reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")


# qwen covers the sparse-label IDKD path by default; the SSM variant is
# the slow full-grid run (pytest -m slow)
@pytest.mark.parametrize("arch", [
    "qwen3-1.7b",
    pytest.param("mamba2-780m", marks=pytest.mark.slow)])
def test_run_training_with_idkd(arch):
    cfg = _tiny(arch)
    tcfg = TrainConfig(num_nodes=2, steps=6, lr=0.1, alpha=0.1, batch_size=4,
                       idkd=IDKDConfig(start_step=3, label_topk=4,
                                       kd_weight=0.3))
    out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                       use_idkd=True, log_every=2, verbose=False)
    assert len(out["loss_history"]) >= 2
    assert all(l == l for l in out["loss_history"])  # no NaNs


def test_run_training_plain():
    cfg = _tiny("qwen1.5-0.5b")
    tcfg = TrainConfig(num_nodes=2, steps=4, lr=0.1, batch_size=4)
    out = run_training(cfg, tcfg, seq_len=16, n_seqs=32, n_public=8,
                       use_idkd=False, log_every=2, verbose=False)
    assert out["loss_history"][-1] == out["loss_history"][-1]
