"""Tests for the beyond-paper extensions: gradient tracking, energy OoD
detector, exponential / time-varying topologies, grouped MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import IDKDConfig
from repro.core.algorithms import make_algorithm
from repro.core.mixing import make_dense_mixer
from repro.core.ood import confidence, energy_score, msp_confidence
from repro.core.topology import TimeVaryingTopology, Topology
from repro.models.moe import init_moe, moe_forward

N, DIM = 8, 4


def test_gradient_tracking_removes_heterogeneity_bias():
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(N, DIM)) * 2, jnp.float32)
    mix = make_dense_mixer(Topology.make("ring", N).mixing_matrix())
    algo = make_algorithm("gradient-tracking", weight_decay=0.0)
    params = {"x": jnp.zeros((N, DIM), jnp.float32)}
    state = algo.init(params)
    step = jax.jit(lambda p, g, s, lr: algo.step(p, g, s, lr, mix))
    for _ in range(3000):
        params, state = step(params, {"x": params["x"] - targets}, state,
                             0.05)
    x = np.asarray(params["x"])
    opt = np.asarray(targets).mean(0)
    assert np.abs(x - x.mean(0)).max() < 0.1, "GT should reach consensus"
    assert np.abs(x.mean(0) - opt).max() < 0.1


def test_energy_detector_separates_like_msp():
    rng = np.random.default_rng(1)
    conf_logits = jnp.asarray(rng.normal(size=(64, 10)) + 6 *
                              jax.nn.one_hot(jnp.arange(64) % 10, 10))
    diffuse_logits = jnp.asarray(rng.normal(size=(64, 10)) * 0.1)
    for det in ("msp", "energy"):
        cid = confidence(conf_logits, det)
        cod = confidence(diffuse_logits, det)
        assert float(jnp.mean(cid)) > float(jnp.mean(cod)), det


def test_energy_score_matches_definition():
    logits = jnp.asarray(np.random.default_rng(2).normal(size=(4, 7)))
    e = energy_score(logits, temperature=2.0)
    expect = 2.0 * jax.nn.logsumexp(logits / 2.0, axis=-1)
    assert np.allclose(np.asarray(e), np.asarray(expect), atol=1e-6)


def test_exponential_graph_better_spectral_gap():
    ring = Topology.make("ring", 16)
    exp = Topology.make("exponential", 16)
    assert exp.spectral_gap() > 2 * ring.spectral_gap()
    W = exp.mixing_matrix()
    assert np.allclose(W.sum(1), 1.0) and np.allclose(W, W.T)


def test_time_varying_one_peer_mixes_fast():
    tv = TimeVaryingTopology(16)
    x = np.random.default_rng(3).normal(size=16)
    y = x.copy()
    for t in range(4 * tv.num_rounds):
        y = tv.mixing_matrix(t) @ y
    assert np.abs(y - x.mean()).max() < 1e-3
    # each round is sparse: degree ≤ 2
    topo = tv.round_topology(0)
    assert max(topo.degree(i) for i in range(16)) <= 2


def test_grouped_moe_dispatch_matches_global():
    cfg = get_config("arctic-480b").reduced().replace(dtype="float32")
    base = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0),
                 cfg.replace(moe=base), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y1, _ = moe_forward(p, x, cfg.replace(
        moe=dataclasses.replace(base, dispatch_groups=1)))
    y4, _ = moe_forward(p, x, cfg.replace(
        moe=dataclasses.replace(base, dispatch_groups=4)))
    assert np.allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


def test_idkd_with_energy_detector():
    """homogenization_round accepts detector='energy' end-to-end."""
    from repro.core.idkd import homogenization_round
    rng = np.random.default_rng(4)
    topo = Topology.make("ring", 4)
    pub = jnp.asarray(rng.normal(size=(4, 32, 10)) * 3)
    val = jnp.asarray(rng.normal(size=(4, 16, 10)) * 5)
    cal = jnp.asarray(rng.normal(size=(4, 16, 10)) * 0.5)
    out = homogenization_round(pub, val, cal, topo,
                               IDKDConfig(detector="energy"))
    assert out.labels.shape == (4, 32, 10)
    assert np.isfinite(np.asarray(out.thresholds)).all()
