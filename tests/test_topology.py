import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep: shim keeps collection
    from hypothesis_shim import given, settings, st


from repro.core.topology import Topology, hierarchical_ring_edges


@pytest.mark.parametrize("kind,n", [("ring", 8), ("ring", 16), ("ring", 32),
                                    ("chain", 16), ("full", 8),
                                    ("social", 15), ("torus", 16)])
def test_mixing_matrix_doubly_stochastic(kind, n):
    topo = Topology.make(kind, n)
    W = topo.mixing_matrix()
    assert np.allclose(W.sum(axis=0), 1.0)
    assert np.allclose(W.sum(axis=1), 1.0)
    assert np.allclose(W, W.T)
    assert (W >= 0).all()
    assert topo.is_connected()


def test_spectral_gap_ordering():
    """Better-connected graphs have larger spectral gaps (paper §4.1)."""
    ring = Topology.make("ring", 16).spectral_gap()
    torus = Topology.make("torus", 16).spectral_gap()
    full = Topology.make("full", 16).spectral_gap()
    assert ring < torus < full
    assert full == pytest.approx(1.0)


def test_social_graph_matches_florentine():
    topo = Topology.make("social", 15)
    assert topo.n == 15
    # Medici is the hub of the Florentine marriage network
    degrees = [topo.degree(i) for i in range(15)]
    assert max(degrees) == 6
    assert topo.is_connected()


def test_chain_is_tree_ring_is_not():
    assert Topology.make("chain", 8).is_tree()
    assert not Topology.make("ring", 8).is_tree()


def test_hierarchical_ring():
    edges = hierarchical_ring_edges(2, 16)
    topo = Topology(32, edges, "hier")
    assert topo.is_connected()
    W = topo.mixing_matrix()
    assert np.allclose(W.sum(axis=1), 1.0)


@given(n=st.integers(3, 64))
@settings(max_examples=20, deadline=None)
def test_ring_mixing_converges_to_consensus(n):
    """Property: W^k x -> mean(x) for any connected gossip graph."""
    W = Topology.make("ring", n).mixing_matrix()
    x = np.random.default_rng(n).normal(size=(n,))
    y = x.copy()
    for _ in range(200 * n):
        y = W @ y
    assert np.allclose(y, x.mean(), atol=1e-3)
