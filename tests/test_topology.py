import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep: shim keeps collection
    from hypothesis_shim import given, settings, st


from repro.core.topology import Topology, hierarchical_ring_edges


@pytest.mark.parametrize("kind,n", [("ring", 8), ("ring", 16), ("ring", 32),
                                    ("chain", 16), ("full", 8),
                                    ("social", 15), ("torus", 16)])
def test_mixing_matrix_doubly_stochastic(kind, n):
    topo = Topology.make(kind, n)
    W = topo.mixing_matrix()
    assert np.allclose(W.sum(axis=0), 1.0)
    assert np.allclose(W.sum(axis=1), 1.0)
    assert np.allclose(W, W.T)
    assert (W >= 0).all()
    assert topo.is_connected()


def test_spectral_gap_ordering():
    """Better-connected graphs have larger spectral gaps (paper §4.1)."""
    ring = Topology.make("ring", 16).spectral_gap()
    torus = Topology.make("torus", 16).spectral_gap()
    full = Topology.make("full", 16).spectral_gap()
    assert ring < torus < full
    assert full == pytest.approx(1.0)


def test_social_graph_matches_florentine():
    topo = Topology.make("social", 15)
    assert topo.n == 15
    # Medici is the hub of the Florentine marriage network
    degrees = [topo.degree(i) for i in range(15)]
    assert max(degrees) == 6
    assert topo.is_connected()


def test_chain_is_tree_ring_is_not():
    assert Topology.make("chain", 8).is_tree()
    assert not Topology.make("ring", 8).is_tree()


def test_hierarchical_ring():
    edges = hierarchical_ring_edges(2, 16)
    topo = Topology(32, edges, "hier")
    assert topo.is_connected()
    W = topo.mixing_matrix()
    assert np.allclose(W.sum(axis=1), 1.0)


def _check_masked_doubly_stochastic(topo, act):
    """Survivor block symmetric doubly stochastic; down nodes identity."""
    W = topo.mixing_matrix(act)
    assert (W >= -1e-12).all()
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    for i in np.flatnonzero(~act):
        row = np.zeros(topo.n)
        row[i] = 1.0
        np.testing.assert_array_equal(W[i], row)
        np.testing.assert_array_equal(W[:, i], row)


@pytest.mark.parametrize("kind,n", [("chain", 2), ("full", 2), ("ring", 3),
                                    ("full", 5), ("torus", 16)])
def test_masked_metropolis_corner_cases(kind, n):
    """The quarantine/churn masks the resilience layer feeds to
    ``mixing_matrix`` hit these corners deterministically: the minimal
    graph, a single survivor (all-but-one-down), and one down node."""
    topo = Topology.make(kind, n)
    lone = np.zeros(n, bool)
    lone[0] = True
    _check_masked_doubly_stochastic(topo, lone)          # all-but-one down
    one_out = np.ones(n, bool)
    one_out[-1] = False
    _check_masked_doubly_stochastic(topo, one_out)
    _check_masked_doubly_stochastic(topo, np.ones(n, bool))


@given(n=st.integers(2, 32), seed=st.integers(0, 2**31 - 1),
       p_down=st.floats(0.0, 0.95))
@settings(max_examples=20, deadline=None)
def test_masked_metropolis_doubly_stochastic_on_survivors(n, seed, p_down):
    """Property: for any availability mask (churn ∧ ¬quarantine), the
    masked Metropolis matrix is symmetric doubly stochastic on the
    survivor subgraph with identity rows for every down node — the
    invariant both the churn machinery and the fault-injection
    degraded mixer (``W_eff``) rely on."""
    kinds = ["chain", "full"] + (["ring"] if n >= 3 else [])
    topo = Topology.make(kinds[seed % len(kinds)], n)
    rng = np.random.default_rng(seed)
    act = rng.random(n) >= p_down
    if not act.any():
        act[int(rng.integers(n))] = True     # at least one survivor
    _check_masked_doubly_stochastic(topo, act)


@given(n=st.integers(3, 64))
@settings(max_examples=20, deadline=None)
def test_ring_mixing_converges_to_consensus(n):
    """Property: W^k x -> mean(x) for any connected gossip graph."""
    W = Topology.make("ring", n).mixing_matrix()
    x = np.random.default_rng(n).normal(size=(n,))
    y = x.copy()
    for _ in range(200 * n):
        y = W @ y
    assert np.allclose(y, x.mean(), atol=1e-3)
