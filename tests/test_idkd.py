import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import IDKDConfig
from repro.core.idkd import (class_histogram, homogenization_round,
                             skew_metric)
from repro.core.topology import Topology


def _make_logits(n, P, C, confident_frac, seed=0):
    """Public logits where a known fraction is high-confidence."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(n, P, C)).astype(np.float32)
    n_conf = int(P * confident_frac)
    for i in range(n):
        cls = rng.integers(0, C, size=n_conf)
        logits[i, :n_conf, :] = -5.0
        logits[i, np.arange(n_conf), cls] = 8.0
    return jnp.asarray(logits)


def test_homogenization_round_filters_low_confidence():
    n, P, C = 4, 64, 10
    topo = Topology.make("ring", n)
    pub = _make_logits(n, P, C, confident_frac=0.5)
    # private val: confident (ID-like); calibration: diffuse (OoD-like)
    val = _make_logits(n, 32, C, confident_frac=1.0, seed=1)
    cal = _make_logits(n, 32, C, confident_frac=0.0, seed=2)
    out = homogenization_round(pub, val, cal, topo, IDKDConfig())
    masks = np.asarray(out.id_masks)
    # the confident half is kept, the diffuse half dropped
    assert masks[:, :32].mean() > 0.9
    assert masks[:, 32:].mean() < 0.1
    # weights: union of self + 2 ring neighbours
    w = np.asarray(out.weights)
    assert w.shape == (n, P)
    assert ((w == 0) | (w == 1)).all()
    # labels normalized where weighted
    lbl = np.asarray(out.labels)
    sums = lbl.sum(-1)
    assert np.allclose(sums[w > 0], 1.0, atol=1e-4)


def test_label_average_over_ring_neighbors():
    """Hand-check line 14: node 0's labels = mean over {0,1,n-1} ∩ ID."""
    n, P, C = 4, 8, 4
    topo = Topology.make("ring", n)
    pub = _make_logits(n, P, C, confident_frac=1.0)
    val = _make_logits(n, 8, C, confident_frac=1.0, seed=1)
    cal = _make_logits(n, 8, C, confident_frac=0.0, seed=2)
    out = homogenization_round(pub, val, cal, topo, IDKDConfig())
    from repro.core.distill import soft_labels
    labels = np.asarray(soft_labels(pub, IDKDConfig().temperature))
    expect = labels[[0, 1, 3]].mean(0)  # self + both neighbours, all ID
    assert np.allclose(np.asarray(out.labels[0]), expect, atol=1e-4)


def test_class_histogram_soft_counting():
    hard = jnp.asarray([0, 0, 1])
    soft = jnp.asarray([[0.5, 0.5, 0.0]])
    h = class_histogram(hard, soft, jnp.asarray([1.0]), num_classes=3)
    expect = np.asarray([2.5, 1.5, 0.0]) / 4.0
    assert np.allclose(np.asarray(h), expect, atol=1e-6)


def test_skew_metric_uniform_is_zero():
    uniform = jnp.ones((4, 10)) / 10.0
    assert float(skew_metric(uniform)) == pytest.approx(0.0, abs=1e-6)
    peaked = jnp.zeros((4, 10)).at[:, 0].set(1.0)
    assert float(skew_metric(peaked)) > 0.8
