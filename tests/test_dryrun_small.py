"""Distribution-correctness tests on a small host-device mesh.

Runs in a subprocess so XLA_FLAGS can request 8 CPU devices without
polluting the main test process (smoke tests must see 1 device).
Asserts the two structural properties of the decentralized HLO:
  * gossip mixing lowers to collective-permute between node groups,
  * there is NO cross-node all-reduce of gradients (gossip replaces it).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.launch import input_specs as ispec
    from repro.launch import sharding as shd
    from repro.launch.dryrun import collective_bytes
    from repro.launch.steps import make_train_step
    from repro.models import build_model

    # importing repro.launch.dryrun forces 512 host devices; use 8 of them
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:8]).reshape(4, 2), ("data", "model"))
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    nodes = 4
    step = make_train_step(model, TrainConfig(num_nodes=nodes), nodes)
    p_spec = ispec.stacked_params_specs(model, nodes)
    opt_spec = jax.eval_shape(step.init_opt, p_spec)
    batch = {
        "tokens": jax.ShapeDtypeStruct((nodes, 2, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((nodes, 2, 16), jnp.int32),
    }
    p_sh = shd.param_shardings(p_spec, mesh, "replica")
    b_sh = shd.batch_shardings(batch, mesh, "replica")
    opt_sh = shd.param_shardings(opt_spec, mesh, "replica")
    with mesh:
        lowered = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh, None),
                          out_shardings=(p_sh, opt_sh, None)).lower(
            p_spec, opt_spec, batch, jax.ShapeDtypeStruct((), jnp.float32))
        compiled = lowered.compile()
    colls = collective_bytes(compiled.as_text())
    print("RESULT:" + json.dumps(colls))
""")


@pytest.fixture(scope="module")
def hlo_collectives():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_gossip_lowers_to_collective_permute(hlo_collectives):
    assert hlo_collectives["collective-permute"] > 0, \
        "ring gossip must appear as collective-permute in the HLO"


def test_collective_permute_dominates_allreduce(hlo_collectives):
    """Decentralized training must not all-reduce parameters/gradients
    across nodes; the remaining all-reduce traffic (loss metric, TP partial
    sums) must be far smaller than the gossip parameter exchange."""
    cp = hlo_collectives["collective-permute"]
    ar = hlo_collectives["all-reduce"]
    assert cp > 2 * ar, f"all-reduce {ar} vs ppermute {cp}"
