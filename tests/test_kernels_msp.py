"""Pallas msp_select kernel vs oracle (interpret mode) + hypothesis sweep."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep: shim keeps collection
    from hypothesis_shim import given, settings, st


from repro.kernels.msp_select import msp_select, msp_select_ref


def _check(logits, T, k, block_n=4):
    conf, vals, idx = msp_select(logits, temperature=T, k=k,
                                 block_n=block_n, interpret=True)
    cr, vr, ir = msp_select_ref(logits, temperature=T, k=k)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vr), atol=1e-5)
    assert (np.asarray(idx) == np.asarray(ir)).all()


@pytest.mark.parametrize("N,C,k", [(16, 64, 4), (8, 1024, 8), (32, 257, 2)])
@pytest.mark.parametrize("T", [1.0, 10.0])
def test_msp_select_matches_ref(N, C, k, T):
    logits = jnp.asarray(np.random.default_rng(N + C).normal(size=(N, C)) * 4,
                         jnp.float32)
    _check(logits, T, k)


@pytest.mark.parametrize("det", ["msp", "energy"])
def test_msp_select_detector_matches_ref(det):
    """Both OoD detectors come out of the kernel's one fused pass."""
    logits = jnp.asarray(np.random.default_rng(7).normal(size=(16, 96)) * 4,
                         jnp.float32)
    conf, vals, idx = msp_select(logits, temperature=10.0, k=4, block_n=4,
                                 interpret=True, detector=det)
    cr, vr, ir = msp_select_ref(logits, temperature=10.0, k=4, detector=det)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(cr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vr), atol=1e-5)


def test_msp_select_bf16_logits():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(8, 128)) * 4,
                         jnp.bfloat16)
    conf, vals, idx = msp_select(logits.astype(jnp.float32),
                                 temperature=10.0, k=4, block_n=4,
                                 interpret=True)
    assert conf.shape == (8,)


@given(scale=st.floats(0.1, 8.0), k=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_msp_select_property(scale, k):
    """Property sweep: values sorted desc, renormalized to 1."""
    logits = jnp.asarray(
        np.random.default_rng(int(scale * 100)).normal(size=(8, 96)) * scale,
        jnp.float32)
    conf, vals, idx = msp_select(logits, temperature=5.0, k=k, block_n=4,
                                 interpret=True)
    v = np.asarray(vals)
    assert (np.diff(v, axis=-1) <= 1e-6).all()          # descending
    np.testing.assert_allclose(v.sum(-1), 1.0, atol=1e-4)
    assert ((np.asarray(conf) > 0) & (np.asarray(conf) <= 1 + 1e-6)).all()
