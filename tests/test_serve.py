"""Batched serving driver smoke (tiny config, few tokens)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import BatchedServer, Request


@pytest.fixture(scope="module")
def server():
    cfg = get_config("qwen1.5-0.5b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    return BatchedServer(cfg, batch_slots=2, context=32)


def test_serves_all_requests(server):
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, 128, 4).astype(np.int32), 3)
            for i in range(3)]
    out = server.submit_all(reqs)
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 3 for v in out.values())
    assert all(0 <= t < 128 for v in out.values() for t in v)


def test_greedy_decode_deterministic(server):
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 128, 4).astype(np.int32)
    out1 = server.submit_all([Request(0, prompt.copy(), 4)])
    out2 = server.submit_all([Request(0, prompt.copy(), 4)])
    assert out1[0] == out2[0]
