"""Decode-vs-forward consistency: token-by-token decoding with the KV/SSM
cache must reproduce the full-sequence forward logits. This covers the KV
cache, ring-buffer sliding windows, the MLA absorbed decode form, and the
SSD recurrent step against its chunked dual form."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(7)


def _roundtrip(arch, S=12, B=2, atol=2e-2, cfg_fn=None):
    if S <= 8:          # fast mode: single sequence, same cache coverage
        B = 1
    cfg = get_config(arch).reduced().replace(dtype="float32", attn_chunk=4)
    if cfg_fn is not None:
        cfg = cfg_fn(cfg)
    model = build_model(cfg)
    params = model.init(KEY)
    tok_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, S)
    tokens = jax.random.randint(KEY, tok_shape, 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    mem = None
    if cfg.arch_type == "vlm":
        batch["patch_embeddings"] = jax.random.normal(
            KEY, (B, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
    if cfg.cross_attention:
        mem = jax.random.normal(KEY, (B, cfg.cross_attn_len, cfg.d_model),
                                jnp.float32)
        batch["conditioning"] = mem
    full_logits, _ = model.forward(params, batch)

    st = model.init_decode_state(B, S)
    outs = []
    for t in range(S):
        tk = tokens[:, t:t + 1]
        logits, st = model.decode_step(params, tk, st, memory=mem)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    assert err < atol, f"{arch}: decode/forward mismatch {err}"


# fast default: 6-token single-sequence roundtrips; the original
# 12-token B=2 runs are the slow grid (`pytest -m slow`) — fewer eager
# decode steps, same cache machinery exercised
SEQ_MODES = [pytest.param(6, id="fast"),
             pytest.param(12, id="full", marks=pytest.mark.slow)]


# qwen3 (GQA + qk-norm) and mistral (sliding window) cover the dense
# cache variants by default; the remaining dense archs are the slow grid
@pytest.mark.parametrize("S", SEQ_MODES)
@pytest.mark.parametrize("arch", [
    "qwen3-1.7b", "mistral-nemo-12b",
    pytest.param("qwen1.5-0.5b", marks=pytest.mark.slow),
    pytest.param("phi3-mini-3.8b", marks=pytest.mark.slow)])
def test_dense_decode_matches_forward(arch, S):
    _roundtrip(arch, S=S)


@pytest.mark.parametrize("S", SEQ_MODES)
def test_mla_absorbed_decode_matches_expanded_forward(S):
    import dataclasses

    def ample_capacity(cfg):
        # forward drops tokens at finite capacity; decode (1 token) never
        # does — equivalence requires no drops
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=8.0))
    _roundtrip("deepseek-v3-671b", S=S, atol=5e-2, cfg_fn=ample_capacity)


@pytest.mark.parametrize("S", SEQ_MODES)
def test_ssm_recurrence_matches_chunked_dual(S):
    _roundtrip("mamba2-780m", S=S, atol=5e-2)


@pytest.mark.parametrize("S", SEQ_MODES)
def test_musicgen_decode_with_cross_attention(S):
    _roundtrip("musicgen-medium", S=S, atol=5e-2)


def test_sliding_window_ring_buffer():
    """Windowed decode must equal windowed forward once the ring buffer
    wraps (S > window)."""
    cfg = get_config("mistral-nemo-12b").reduced().replace(
        dtype="float32", sliding_window=6, attn_chunk=4)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 1, 14
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _ = model.forward(params, {"tokens": tokens})
    st = model.init_decode_state(B, S)
    # ring-buffer capacity must be the window, not the context
    kv = st[0]["kv"]
    assert kv.k.shape[2] == 6      # (L, B, cap, KVH, hd) -> cap axis
    outs = []
    for t in range(S):
        logits, st = model.decode_step(params, tokens[:, t:t + 1], st)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    assert err < 2e-2, f"windowed decode mismatch {err}"
