"""End-to-end driver (deliverable b): trains the paper's model for a few
hundred decentralized steps on a 8-node ring with α=0.05 non-IID data and
compares QG-DSGDm-N, vanilla KD, and QG-IDKD — the paper's Table 2 row at
reduced scale — then saves the consensus checkpoint.

The federation scheduler flags exercise the dynamic settings end to end:
``--rounds K`` re-homogenizes K times (spaced ``every_k_steps`` apart,
fit evenly into the post-start span by default), and ``--churn`` drops
nodes mid-run (``node@down-up`` spec, e.g. ``7@120-200``), with masked
Metropolis gossip holding the survivors doubly stochastic. The per-round
communication ledger is printed for the IDKD run.

    PYTHONPATH=src python examples/decentralized_cifar_idkd.py \
        [--steps 300] [--rounds 3] [--churn 7@120-200]
"""
import argparse

import jax.numpy as jnp

from repro import sched
from repro.checkpoint import save_checkpoint
from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.idkd import skew_metric
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import make_classification_data, make_public_data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=4)   # paper seeds: 4, 34, 5
    ap.add_argument("--rounds", type=int, default=1,
                    help="IDKD homogenization rounds (re-labeled each time)")
    ap.add_argument("--every-k", type=int, default=0,
                    help="steps between rounds (default: fit evenly)")
    ap.add_argument("--churn", default="",
                    help="churn spec node@down-up[,...], e.g. 7@120-200")
    ap.add_argument("--telemetry", default="", metavar="DIR",
                    help="write the QG-IDKD run's telemetry (run.jsonl + "
                         "trace.json, DESIGN.md §11) under DIR")
    args = ap.parse_args()

    data = make_classification_data(image_size=8, n_train=1024, n_val=256,
                                    n_test=512, noise=2.2, seed=0)
    public = make_public_data(data, n_public=768, kind="aligned", seed=1)
    mcfg = SMALL_CONFIG.replace(image_size=8)
    start = int(args.steps * 0.6)
    every_k = args.every_k or sched.fit_every_k(args.steps, start,
                                                args.rounds)
    churn = (sched.parse_churn(args.churn, args.nodes, args.steps)
             if args.churn else ())

    results = {}
    for name, (algo, kd) in {
        "QG-DSGDm-N": ("qg-dsgdm-n", None),
        "QG-DSGDm-N + KD": ("qg-dsgdm-n", "vanilla"),
        "QG-IDKD (ours)": ("qg-dsgdm-n", "idkd"),
    }.items():
        tcfg = TrainConfig(algorithm=algo, num_nodes=args.nodes,
                           alpha=args.alpha, steps=args.steps, batch_size=16,
                           lr=0.5, seed=args.seed,
                           idkd=IDKDConfig(start_step=start,
                                           temperature=10.0,
                                           every_k_steps=every_k,
                                           num_rounds=args.rounds))
        sim = DecentralizedSimulator(mcfg, tcfg, data, public, kd_mode=kd,
                                     eval_every=max(args.steps // 6, 1))
        schedule = sched.compile_schedule(
            tcfg.steps, sim.eval_every,
            round_steps=sim.default_schedule().round_steps, events=churn)
        telemetry = None
        if args.telemetry and kd == "idkd":
            from repro.obs import Telemetry
            telemetry = Telemetry(args.telemetry, trace=True,
                                  meta={"method": name, "steps": args.steps,
                                        "nodes": args.nodes,
                                        "alpha": args.alpha})
        try:
            r = sim.run(schedule=schedule, telemetry=telemetry)
        finally:
            if telemetry is not None:
                telemetry.close()
        results[name] = r
        extra = ""
        if r.post_hist is not None:
            extra = (f"  skew {float(skew_metric(jnp.asarray(r.pre_hist))):.3f}"
                     f"->{float(skew_metric(jnp.asarray(r.post_hist))):.3f}"
                     f"  id_frac {r.id_fraction:.2f}"
                     f"  rounds {len(r.rounds)}")
        print(f"{name:18s} acc={r.final_acc*100:6.2f}%  "
              f"curve={[round(a, 2) for a in r.acc_history]}{extra}",
              flush=True)

    idkd_run = results["QG-IDKD (ours)"]
    print("\nper-round communication ledger (QG-IDKD):")
    for row in idkd_run.ledger["per_round"]:
        print(f"  round {row['round']}: {row['gossip_bytes']/1e6:8.2f} MB "
              f"gossip over {row['steps']} steps, "
              f"{row['labels_bytes']/1e3:8.2f} kB labels")

    best = max(results.items(), key=lambda kv: kv[1].final_acc)
    print(f"\nbest method: {best[0]} ({best[1].final_acc*100:.2f}%)")
    save_checkpoint("experiments/e2e_consensus", best[1].__dict__.get(
        "params", {"acc": jnp.asarray(best[1].final_acc)}), step=args.steps)
    print("checkpoint written to experiments/e2e_consensus.npz")


if __name__ == "__main__":
    main()
