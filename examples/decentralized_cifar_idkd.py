"""End-to-end driver (deliverable b): trains the paper's model for a few
hundred decentralized steps on a 8-node ring with α=0.05 non-IID data and
compares QG-DSGDm-N, vanilla KD, and QG-IDKD — the paper's Table 2 row at
reduced scale — then saves the consensus checkpoint.

    PYTHONPATH=src python examples/decentralized_cifar_idkd.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.idkd import skew_metric
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import make_classification_data, make_public_data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=4)   # paper seeds: 4, 34, 5
    args = ap.parse_args()

    data = make_classification_data(image_size=8, n_train=1024, n_val=256,
                                    n_test=512, noise=2.2, seed=0)
    public = make_public_data(data, n_public=768, kind="aligned", seed=1)
    mcfg = SMALL_CONFIG.replace(image_size=8)

    results = {}
    for name, (algo, kd) in {
        "QG-DSGDm-N": ("qg-dsgdm-n", None),
        "QG-DSGDm-N + KD": ("qg-dsgdm-n", "vanilla"),
        "QG-IDKD (ours)": ("qg-dsgdm-n", "idkd"),
    }.items():
        tcfg = TrainConfig(algorithm=algo, num_nodes=args.nodes,
                           alpha=args.alpha, steps=args.steps, batch_size=16,
                           lr=0.5, seed=args.seed,
                           idkd=IDKDConfig(start_step=int(args.steps * 0.6),
                                           temperature=10.0))
        sim = DecentralizedSimulator(mcfg, tcfg, data, public, kd_mode=kd,
                                     eval_every=max(args.steps // 6, 1))
        r = sim.run()
        results[name] = r
        extra = ""
        if r.post_hist is not None:
            extra = (f"  skew {float(skew_metric(jnp.asarray(r.pre_hist))):.3f}"
                     f"->{float(skew_metric(jnp.asarray(r.post_hist))):.3f}"
                     f"  id_frac {r.id_fraction:.2f}")
        print(f"{name:18s} acc={r.final_acc*100:6.2f}%  "
              f"curve={[round(a, 2) for a in r.acc_history]}{extra}",
              flush=True)

    best = max(results.items(), key=lambda kv: kv[1].final_acc)
    print(f"\nbest method: {best[0]} ({best[1].final_acc*100:.2f}%)")
    save_checkpoint("experiments/e2e_consensus", best[1].__dict__.get(
        "params", {"acc": jnp.asarray(best[1].final_acc)}), step=args.steps)
    print("checkpoint written to experiments/e2e_consensus.npz")


if __name__ == "__main__":
    main()
