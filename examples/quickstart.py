"""Quickstart: the IDKD framework in ~60 lines.

Builds a 4-node ring, trains the paper's ResNet-EvoNorm on synthetic
non-IID data with QG-DSGDm-N, runs one IDKD homogenization round, and
prints the effect on the class distribution and accuracy.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.idkd import skew_metric
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import make_classification_data, make_public_data


def main():
    # 1. synthetic CIFAR-like data + an unlabeled public set
    data = make_classification_data(image_size=8, n_train=1024, n_test=512,
                                    noise=1.6, seed=0)
    public = make_public_data(data, n_public=512, kind="aligned", seed=1)

    # 2. a 4-node ring with highly skewed (Dirichlet α=0.05) private shards
    tcfg = TrainConfig(algorithm="qg-dsgdm-n", topology="ring", num_nodes=4,
                       alpha=0.05, steps=120, batch_size=16, lr=0.5,
                       idkd=IDKDConfig(start_step=80, temperature=10.0))
    mcfg = SMALL_CONFIG.replace(image_size=8)

    # 3. decentralized training with the IDKD homogenization round at step 80
    sim = DecentralizedSimulator(mcfg, tcfg, data, public, kd_mode="idkd",
                                 eval_every=40)
    result = sim.run()

    pre = float(skew_metric(jnp.asarray(result.pre_hist)))
    post = float(skew_metric(jnp.asarray(result.post_hist)))
    print(f"accuracy history : {[round(a, 3) for a in result.acc_history]}")
    print(f"final consensus accuracy: {result.final_acc:.3f}")
    print(f"class-skew (TV from uniform): {pre:.3f} -> {post:.3f}")
    print(f"public samples kept by MSP detector: {result.id_fraction:.2f}")
    print(f"per-node MSP thresholds: {np.round(result.thresholds, 3)}")


if __name__ == "__main__":
    main()
