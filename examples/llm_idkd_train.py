"""IDKD on a language model: decentralized next-token training over a
non-IID topic-partitioned corpus with top-k sparse label exchange
(the framework's beyond-paper LLM adaptation, DESIGN.md §3).

    PYTHONPATH=src python examples/llm_idkd_train.py --arch qwen3-1.7b
"""
import argparse

from repro.configs import get_config
from repro.configs.base import IDKDConfig, TrainConfig
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    help="any assigned architecture id")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--nodes", type=int, default=4)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    tcfg = TrainConfig(num_nodes=args.nodes, steps=args.steps, lr=0.1,
                       alpha=0.1, batch_size=8,
                       idkd=IDKDConfig(start_step=args.steps // 2,
                                       label_topk=8, kd_weight=0.3))
    out = run_training(cfg, tcfg, seq_len=48, n_seqs=256, n_public=32,
                       use_idkd=True, log_every=5)
    print(f"loss history: {[round(x, 3) for x in out['loss_history']]}")


if __name__ == "__main__":
    main()
