"""Batched serving example: decode a few requests against a reduced model
with the KV-cache/SSM-state decode path (the one dryrun.py proves at
32k/524k context on the production mesh).

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-780m
"""
import argparse

from repro.launch.serve import main as serve_main
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args, rest = ap.parse_known_args()
    sys.argv = ["serve", "--arch", args.arch, "--requests", "4",
                "--slots", "2", "--prompt-len", "6", "--gen-len", "8"] + rest
    serve_main()


if __name__ == "__main__":
    main()
