"""Roofline report generator (deliverable g).

Reads the dry-run JSONs (experiments/dryrun/*.json) and renders the
per-(arch × shape × mesh) roofline table:

    compute_s   = HLO_FLOPs_per_device / 197e12
    memory_s    = HLO_bytes_per_device / 819e9
    collective_s= collective_bytes_per_device / 50e9

plus MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (serve)
and the useful-FLOP ratio. Single-pod rows form the §Roofline table;
multi-pod rows prove the pod axis shards.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str = "single") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


PEAK = 197e12


def effective(r: Dict) -> Dict:
    """Effective roofline terms.

    XLA's CPU cost_analysis undercounts FLOPs relative to the TPU backend
    (several archs show HLO_FLOPs below the analytic 6·N·D floor), so the
    effective compute term is max(HLO term, MODEL_FLOPS term) and the
    dominant bound is re-derived from it."""
    comp = max(r["compute_s"], r["model_flops_per_device"] / PEAK)
    terms = {"compute": comp, "memory": r["memory_s"],
             "collective": r["collective_s"]}
    dom = max(terms, key=terms.get)
    bound = terms[dom]
    frac = {k: v / bound for k, v in terms.items()}
    return {"terms": terms, "dominant": dom, "bound_s": bound,
            "compute_fraction": terms["compute"] / bound}


def render(mesh: str = "single") -> str:
    recs = load_records(mesh)
    cols = ["arch", "shape", "status", "compute*", "memory", "collective",
            "dominant", "MF/HLO", "bytes/dev"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | "
                         f"{r.get('status','?')}: "
                         f"{r.get('reason', r.get('error',''))[:60]} |"
                         + " |" * (len(cols) - 3))
            continue
        eff = effective(r)
        mem_gib = (r["memory_analysis"]["argument_bytes"]
                   + r["memory_analysis"]["temp_bytes"]) / 2**30
        lines.append(
            "| " + " | ".join([
                r["arch"], r["shape"], "ok",
                _fmt_s(eff["terms"]["compute"]), _fmt_s(r["memory_s"]),
                _fmt_s(r["collective_s"]), eff["dominant"],
                f"{r['useful_flop_ratio']:.1f}×",
                f"{mem_gib:.2f}GiB"]) + " |")
    lines.append("")
    lines.append("compute\\* = max(HLO-FLOPs, 6·N_active·tokens)/peak — the "
                 "CPU backend's cost_analysis undercounts FLOPs, so the "
                 "analytic MODEL_FLOPS floor is applied; MF/HLO is that "
                 "ratio (≫1 ⇒ undercount, ≪1 ⇒ remat/recompute waste).")
    return "\n".join(lines)


def run():
    csv = []
    for r in load_records("single"):
        if r.get("status") != "ok":
            continue
        eff = effective(r)
        name = f"roofline/{r['arch']}/{r['shape']}"
        csv.append((name, eff["bound_s"] * 1e6, eff["dominant"]))
    return [], csv


if __name__ == "__main__":
    print(render("single"))
    print()
    print(render("multi"))
