"""§Perf hillclimb driver (deliverable g): hypothesis → change → re-lower →
re-analyse, on the three selected (arch × shape) pairs.

Each variant is lowered + compiled with the production mesh and its
roofline terms recorded to experiments/perf/<pair>_<variant>.json; the
iteration log lives in EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.perf_iterate --pair qwen3_train \
        --variant wire_bf16
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

from repro.launch.dryrun import run_one

# the three hillclimb pairs (chosen per the assignment rubric — see
# EXPERIMENTS.md §Perf for the selection rationale)
PAIRS = {
    "qwen3_train": ("qwen3-1.7b", "train_4k"),       # paper-representative
    "mamba2_prefill": ("mamba2-780m", "prefill_32k"),  # worst fraction
    "deepseek_train": ("deepseek-v3-671b", "train_4k"),  # most collective
}

# variant -> (wire_dtype, cfg_overrides); "special" variants are expanded
# by apply_special below.
VARIANTS = {
    "baseline": ("float32", {}),
    "wire_bf16": ("native", {}),
    "no_remat": ("float32", {"remat": False}),
    "remat_dots": ("float32", {"remat_policy": "dots"}),
    "dots+bf16norm": ("float32", {"remat_policy": "dots",
                                  "norm_in_f32": False}),
    "chunk_1024": ("float32", {"attn_chunk": 1024}),
    "chunk_2048": ("float32", {"attn_chunk": 2048}),
    "ssd_chunk_128": ("float32", {}),
    "ssd_chunk_512": ("float32", {}),
    "ssm_split": ("float32", {}),
    "out_sharded": ("float32", {}),
    "ssm_split+out": ("float32", {}),
    "ssm_split+out+vpad": ("float32", {"vocab_size": 50_432}),
    "pod_scope": ("float32", {"node_scope": "pod"}),
    "cap_1x": ("float32", {}),
    "experts_both": ("float32", {}),     # env-driven sharding change
    "cap1x+experts_both": ("float32", {}),
    "moe_groups_16": ("float32", {}),    # GShard-style grouped dispatch
    "moe_groups16+dots": ("float32", {}),
    "groups16+out": ("float32", {}),     # grouped dispatch + residual pin
}


def apply_special(variant, arch, overrides):
    import dataclasses
    from repro.configs import get_config
    cfg = get_config(arch)
    overrides = dict(overrides)
    if variant.startswith("ssd_chunk_"):
        overrides["ssm"] = dataclasses.replace(
            cfg.ssm, chunk_size=int(variant.rsplit("_", 1)[1]))
    if variant.startswith("ssm_split"):
        overrides["ssm"] = dataclasses.replace(cfg.ssm, split_proj=True)
    if variant in ("cap_1x", "cap1x+experts_both"):
        overrides["moe"] = dataclasses.replace(cfg.moe, capacity_factor=1.0)
    if "experts_both" in variant:
        os.environ["REPRO_SHARD_EXPERTS"] = "both"
    if variant.startswith("moe_groups_"):
        g = int(variant.rsplit("_", 1)[1])
        overrides["moe"] = dataclasses.replace(cfg.moe, dispatch_groups=g)
    if variant == "moe_groups16+dots":
        overrides["moe"] = dataclasses.replace(cfg.moe, dispatch_groups=16)
        overrides["remat_policy"] = "dots"
    if variant == "groups16+out":
        overrides["moe"] = dataclasses.replace(cfg.moe, dispatch_groups=16)
    return overrides


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=list(PAIRS))
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    arch, shape = PAIRS[args.pair]
    wire, overrides = VARIANTS[args.variant]
    overrides = apply_special(args.variant, arch, overrides)
    rec = run_one(arch, shape, args.multi, wire_dtype=wire,
                  cfg_overrides=overrides, label=args.variant,
                  sharded_out=("out" in args.variant))
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.pair}_{args.variant}{'_multi' if args.multi else ''}"
    hlo = rec.pop("_hlo", None)
    if hlo is not None:
        import gzip
        with gzip.open(os.path.join(args.out, tag + ".hlo.gz"), "wt") as hf:
            hf.write(hlo)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: rec[k] for k in
                      ("variant", "compute_s", "memory_s", "collective_s",
                       "dominant", "compile_s") if k in rec}, indent=1))


if __name__ == "__main__":
    main()
