"""Kernel micro-benchmarks: wall time of the jnp oracle (the XLA path used
on CPU) + interpret-mode allclose checks of the Pallas kernels. Real-TPU
timing is out of scope in this container (see EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.msp_select import msp_select, msp_select_ref
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref
from repro.models.attention import chunked_attention


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    csv = []
    # flash attention oracle timings + kernel allclose
    B, S, H, KVH, D = 2, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    ref = jax.jit(flash_attention_ref)
    chk = jax.jit(lambda a, b, c: chunked_attention(a, b, c, chunk=128))
    csv.append(("kernels/attention_naive_ref", _time(ref, q, k, v), "xla"))
    csv.append(("kernels/attention_chunked", _time(chk, q, k, v), "xla"))
    pall = flash_attention(q[:1, :128], k[:1, :128], v[:1, :128],
                           block_q=64, block_k=64, interpret=True)
    err = float(jnp.max(jnp.abs(
        pall - flash_attention_ref(q[:1, :128], k[:1, :128], v[:1, :128]))))
    csv.append(("kernels/flash_pallas_interp_maxerr", 0.0, f"{err:.2e}"))

    # ssd
    B, S, H, P, N = 2, 512, 4, 32, 16
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dta = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.2, jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    seq = jax.jit(lambda *a: ssd_scan_ref(*a)[0])
    csv.append(("kernels/ssd_sequential_ref", _time(seq, xdt, dta, b, c),
                "xla"))
    y = ssd_scan(xdt[:1, :128], dta[:1, :128], b[:1, :128], c[:1, :128],
                 chunk=64, interpret=True)
    yr, _ = ssd_scan_ref(xdt[:1, :128], dta[:1, :128], b[:1, :128],
                         c[:1, :128])
    csv.append(("kernels/ssd_pallas_interp_maxerr", 0.0,
                f"{float(jnp.max(jnp.abs(y - yr))):.2e}"))

    # msp_select
    logits = jnp.asarray(rng.normal(size=(512, 4096)) * 3, jnp.float32)
    ref_fn = jax.jit(lambda l: msp_select_ref(l, temperature=10.0,
                                              threshold=0.5, k=8))
    csv.append(("kernels/msp_ref", _time(ref_fn, logits), "xla"))
    co, vo, io, mo = msp_select(logits[:32], temperature=10.0, threshold=0.5,
                                k=8, block_n=8, interpret=True)
    cr, vr, ir, mr = msp_select_ref(logits[:32], temperature=10.0,
                                    threshold=0.5, k=8)
    csv.append(("kernels/msp_pallas_interp_maxerr", 0.0,
                f"{float(jnp.max(jnp.abs(co - cr))):.2e}"))
    return [], csv


if __name__ == "__main__":
    for row in run()[1]:
        print(",".join(str(x) for x in row))
