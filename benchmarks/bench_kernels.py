"""Kernel micro-benchmarks: wall time of the jnp oracle (the XLA path used
on CPU) + interpret-mode allclose checks of the Pallas kernels. Real-TPU
timing is out of scope in this container (see EXPERIMENTS.md §Roofline).

``bench_labeling`` times the unified IDKD labeling engine (DESIGN.md §2)
backend-vs-backend over a (P, C) grid and writes the committed
``BENCH_labeling.json`` baseline that future PRs track.
"""
from __future__ import annotations

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import IDKDConfig
from repro.core import labeling
from repro.core.topology import Topology
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.msp_select import msp_select, msp_select_ref
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref
from repro.models.attention import chunked_attention


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    csv = []
    # flash attention oracle timings + kernel allclose
    B, S, H, KVH, D = 2, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    ref = jax.jit(flash_attention_ref)
    chk = jax.jit(lambda a, b, c: chunked_attention(a, b, c, chunk=128))
    csv.append(("kernels/attention_naive_ref", _time(ref, q, k, v), "xla"))
    csv.append(("kernels/attention_chunked", _time(chk, q, k, v), "xla"))
    pall = flash_attention(q[:1, :128], k[:1, :128], v[:1, :128],
                           block_q=64, block_k=64, interpret=True)
    err = float(jnp.max(jnp.abs(
        pall - flash_attention_ref(q[:1, :128], k[:1, :128], v[:1, :128]))))
    csv.append(("kernels/flash_pallas_interp_maxerr", 0.0, f"{err:.2e}"))

    # ssd
    B, S, H, P, N = 2, 512, 4, 32, 16
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dta = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.2, jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    seq = jax.jit(lambda *a: ssd_scan_ref(*a)[0])
    csv.append(("kernels/ssd_sequential_ref", _time(seq, xdt, dta, b, c),
                "xla"))
    y = ssd_scan(xdt[:1, :128], dta[:1, :128], b[:1, :128], c[:1, :128],
                 chunk=64, interpret=True)
    yr, _ = ssd_scan_ref(xdt[:1, :128], dta[:1, :128], b[:1, :128],
                         c[:1, :128])
    csv.append(("kernels/ssd_pallas_interp_maxerr", 0.0,
                f"{float(jnp.max(jnp.abs(y - yr))):.2e}"))

    # msp_select
    logits = jnp.asarray(rng.normal(size=(512, 4096)) * 3, jnp.float32)
    ref_fn = jax.jit(lambda l: msp_select_ref(l, temperature=10.0,
                                              threshold=0.5, k=8))
    csv.append(("kernels/msp_ref", _time(ref_fn, logits), "xla"))
    co, vo, io, mo = msp_select(logits[:32], temperature=10.0, threshold=0.5,
                                k=8, block_n=8, interpret=True)
    cr, vr, ir, mr = msp_select_ref(logits[:32], temperature=10.0,
                                    threshold=0.5, k=8)
    csv.append(("kernels/msp_pallas_interp_maxerr", 0.0,
                f"{float(jnp.max(jnp.abs(co - cr))):.2e}"))
    return [], csv


# -------------------------------------------------- labeling engine bench
LABELING_GRID = [(1024, 10), (1024, 32_768), (8192, 10), (8192, 32_768)]
LABELING_NODES = 4
LABELING_TOPK = 8


def bench_labeling(out_path: str | None = "BENCH_labeling.json"):
    """Full IDKD round (score → calibrate → select → exchange → average),
    dense vs fused vs sparse backends, over P∈{1k, 8k} × C∈{10, 32k}.

    Every backend sees identical inputs on a ring of 4 nodes. Writes the
    JSON baseline (µs per round) and returns the CSV rows.
    """
    topo = Topology.make("ring", LABELING_NODES)
    cfg = IDKDConfig(label_topk=LABELING_TOPK)
    rng = np.random.default_rng(0)
    csv, cells = [], []
    for P, C in LABELING_GRID:
        pub = jnp.asarray(
            rng.normal(size=(LABELING_NODES, P, C)).astype(np.float32) * 3)
        val = jnp.asarray(
            rng.normal(size=(LABELING_NODES, 128, C)).astype(np.float32) * 4)
        # big dense cells: one full (n, P, C) label tensor per gather pass —
        # a single timing iteration is plenty (and minutes cheaper)
        iters = 1 if P * C >= 8192 * 32_768 else 3
        for backend in ("dense", "fused", "sparse"):
            # cal_logits=None: D_C = D_P score reuse, same as production
            # (the object-identity fast path is invisible under jit)
            fn = jax.jit(functools.partial(
                labeling.label_round, cal_logits=None, topology=topo,
                cfg=cfg, backend=backend))
            us = _time(fn, pub, val, iters=iters)
            name = f"labeling/{backend}_P{P}_C{C}"
            csv.append((name, round(us, 1), "xla"))
            cells.append({"P": P, "C": C, "backend": backend,
                          "us_per_round": round(us, 1)})
    if out_path:
        with open(out_path, "w") as f:
            json.dump({"meta": {"nodes": LABELING_NODES, "topology": "ring",
                                "label_topk": LABELING_TOPK,
                                "jax_backend": jax.default_backend(),
                                "what": "µs per full IDKD labeling round"},
                       "cells": cells}, f, indent=2)
            f.write("\n")
    return [], csv


if __name__ == "__main__":
    for row in run()[1]:
        print(",".join(str(x) for x in row))
    for row in bench_labeling()[1]:
        print(",".join(str(x) for x in row))
