"""Kernel micro-benchmarks: wall time of the jnp oracle (the XLA path used
on CPU) + interpret-mode allclose checks of the Pallas kernels. Real-TPU
timing is out of scope in this container (see EXPERIMENTS.md §Roofline).

``bench_labeling`` times the unified IDKD labeling engine (DESIGN.md §2)
backend-vs-backend over a (P, C) grid and writes the committed
``BENCH_labeling.json`` baseline that future PRs track.
"""
from __future__ import annotations

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import IDKDConfig
from repro.core import labeling
from repro.core.topology import Topology
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.head_select import head_select, head_select_ref
from repro.kernels.msp_select import msp_select, msp_select_ref
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref
from repro.models.attention import chunked_attention


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    csv = []
    # flash attention oracle timings + kernel allclose
    B, S, H, KVH, D = 2, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    ref = jax.jit(flash_attention_ref)
    chk = jax.jit(lambda a, b, c: chunked_attention(a, b, c, chunk=128))
    csv.append(("kernels/attention_naive_ref", _time(ref, q, k, v), "xla"))
    csv.append(("kernels/attention_chunked", _time(chk, q, k, v), "xla"))
    pall = flash_attention(q[:1, :128], k[:1, :128], v[:1, :128],
                           block_q=64, block_k=64, interpret=True)
    err = float(jnp.max(jnp.abs(
        pall - flash_attention_ref(q[:1, :128], k[:1, :128], v[:1, :128]))))
    csv.append(("kernels/flash_pallas_interp_maxerr", 0.0, f"{err:.2e}"))

    # ssd
    B, S, H, P, N = 2, 512, 4, 32, 16
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dta = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.2, jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    seq = jax.jit(lambda *a: ssd_scan_ref(*a)[0])
    csv.append(("kernels/ssd_sequential_ref", _time(seq, xdt, dta, b, c),
                "xla"))
    y = ssd_scan(xdt[:1, :128], dta[:1, :128], b[:1, :128], c[:1, :128],
                 chunk=64, interpret=True)
    yr, _ = ssd_scan_ref(xdt[:1, :128], dta[:1, :128], b[:1, :128],
                         c[:1, :128])
    csv.append(("kernels/ssd_pallas_interp_maxerr", 0.0,
                f"{float(jnp.max(jnp.abs(y - yr))):.2e}"))

    # msp_select
    logits = jnp.asarray(rng.normal(size=(512, 4096)) * 3, jnp.float32)
    ref_fn = jax.jit(lambda l: msp_select_ref(l, temperature=10.0, k=8))
    csv.append(("kernels/msp_ref", _time(ref_fn, logits), "xla"))
    co, vo, io = msp_select(logits[:32], temperature=10.0, k=8, block_n=8,
                            interpret=True)
    cr, vr, ir = msp_select_ref(logits[:32], temperature=10.0, k=8)
    csv.append(("kernels/msp_pallas_interp_maxerr", 0.0,
                f"{float(jnp.max(jnp.abs(co - cr))):.2e}"))

    # head_select (vocab-tiled msp_select from hidden states)
    D = 128
    h = jnp.asarray(rng.normal(size=(512, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, 4096)) * 0.3, jnp.float32)
    hs_ref = jax.jit(lambda a, b: head_select_ref(a, b, temperature=10.0,
                                                  k=8))
    csv.append(("kernels/head_select_ref", _time(hs_ref, h, w), "xla"))
    ch, vh, ih = head_select(h[:32], w, temperature=10.0, k=8, block_rows=8,
                             block_c=512, interpret=True)
    chr_, vhr, ihr = head_select_ref(h[:32], w, temperature=10.0, k=8)
    csv.append(("kernels/head_select_pallas_interp_maxerr", 0.0,
                f"{float(jnp.max(jnp.abs(ch - chr_))):.2e}"))
    return [], csv


# -------------------------------------------------- labeling engine bench
LABELING_GRID = [(1024, 10), (1024, 32_768), (8192, 10), (8192, 32_768)]
LABELING_NODES = 4
LABELING_TOPK = 8

# streaming vs one-shot select stage (DESIGN.md §8): P rows of D-dim
# hidden states against a (D, C) head at C ∈ {1k, 32k, 257k-sim} — the
# largest cell simulates the 257k-vocab LM regime (2^18 columns keeps the
# cell a power-of-two multiple of the microbatch on this container).
STREAM_GRID = [(2048, 1024), (1024, 32_768), (512, 262_144)]
STREAM_D = 128
STREAM_MB = 64


def _stream_select_fns(P: int, C: int, k: int = LABELING_TOPK):
    """(one_shot, streaming) jitted select-stage functions over
    (hidden (P, D), head (D, C)). One-shot materializes the full (P, C)
    logits and runs the fused msp_select oracle; streaming scans
    STREAM_MB-row chunks through the head_select oracle and accumulates
    only (conf, top-k)."""
    def one_shot(h, w):
        return msp_select_ref(h @ w, temperature=10.0, k=k)

    def streaming(h, w):
        chunks = h.reshape(P // STREAM_MB, STREAM_MB, STREAM_D)

        def body(carry, hc):
            return carry, head_select_ref(hc, w, temperature=10.0, k=k)

        _, (conf, vals, idx) = jax.lax.scan(body, None, chunks)
        return (conf.reshape(-1), vals.reshape(P, k), idx.reshape(P, k))

    return jax.jit(one_shot), jax.jit(streaming)


def bench_labeling(out_path: str | None = "BENCH_labeling.json"):
    """Full IDKD round (score → calibrate → select → exchange → average),
    dense vs fused vs sparse backends, over P∈{1k, 8k} × C∈{10, 32k} —
    plus the streaming-vs-one-shot select stage over the STREAM_GRID
    with an analytic peak-memory estimate per cell.

    Every backend sees identical inputs on a ring of 4 nodes. Cells are
    device-labeled so timings only ever compare against a baseline
    recorded on the same backend — a foreign-device baseline shares no
    metric names, and check_regression then demands a baseline refresh
    (its loud no-overlap failure) rather than comparing cpu and tpu
    wall-clocks. Writes the JSON baseline (µs per round) and returns
    the CSV rows.
    """
    topo = Topology.make("ring", LABELING_NODES)
    cfg = IDKDConfig(label_topk=LABELING_TOPK)
    device = jax.default_backend()
    rng = np.random.default_rng(0)
    csv, cells = [], []
    for P, C in LABELING_GRID:
        pub = jnp.asarray(
            rng.normal(size=(LABELING_NODES, P, C)).astype(np.float32) * 3)
        val = jnp.asarray(
            rng.normal(size=(LABELING_NODES, 128, C)).astype(np.float32) * 4)
        # big dense cells: one full (n, P, C) label tensor per gather pass —
        # a single timing iteration is plenty (and minutes cheaper)
        iters = 1 if P * C >= 8192 * 32_768 else 3
        for backend in ("dense", "fused", "sparse"):
            # cal_logits=None: D_C = D_P score reuse, same as production
            # (the object-identity fast path is invisible under jit)
            fn = jax.jit(functools.partial(
                labeling.label_round, cal_logits=None, topology=topo,
                cfg=cfg, backend=backend))
            us = _time(fn, pub, val, iters=iters)
            name = f"labeling/{backend}_P{P}_C{C}"
            csv.append((name, round(us, 1), "xla"))
            cells.append({"stage": "round", "P": P, "C": C,
                          "backend": backend, "device": device,
                          "us_per_round": round(us, 1)})
    for P, C in STREAM_GRID:
        h = jnp.asarray(rng.normal(size=(P, STREAM_D)).astype(np.float32))
        w = jnp.asarray(
            rng.normal(size=(STREAM_D, C)).astype(np.float32) * 0.1)
        one_shot, streaming = _stream_select_fns(P, C)
        iters = 1 if C >= 262_144 else 3
        for path, fn in (("one_shot", one_shot), ("streaming", streaming)):
            us = _time(fn, h, w, iters=iters)
            # peak live logits: the full (P, C) stack vs one microbatch
            # chunk, + the accumulated (P, k) payload on both paths
            live_rows = P if path == "one_shot" else STREAM_MB
            peak = live_rows * C * 4 + P * LABELING_TOPK * 8
            name = f"labeling/select_{path}_P{P}_C{C}"
            csv.append((name, round(us, 1), f"peak={peak}"))
            cells.append({"stage": "select", "path": path, "P": P, "C": C,
                          "mb": STREAM_MB, "device": device,
                          "us_per_round": round(us, 1),
                          "peak_bytes_est": peak})
    if out_path:
        with open(out_path, "w") as f:
            json.dump({"meta": {"nodes": LABELING_NODES, "topology": "ring",
                                "label_topk": LABELING_TOPK,
                                "stream_microbatch": STREAM_MB,
                                "stream_d": STREAM_D,
                                "jax_backend": device,
                                "what": "µs per full IDKD labeling round "
                                        "(stage=round) / per fused select "
                                        "pass (stage=select; "
                                        "peak_bytes_est = live logit bytes "
                                        "+ top-k payload)"},
                       "cells": cells}, f, indent=2)
            f.write("\n")
    return [], csv


if __name__ == "__main__":
    for row in run()[1]:
        print(",".join(str(x) for x in row))
    for row in bench_labeling()[1]:
        print(",".join(str(x) for x in row))
