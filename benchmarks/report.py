"""Fill EXPERIMENTS.md result markers from cached artifacts.

    PYTHONPATH=src python -m benchmarks.report

Replaces the <!-- RESULTS:REPRO --> and <!-- RESULTS:ROOFLINE --> markers
with tables rendered from experiments/bench/*.json and
experiments/dryrun/*.json. Idempotent: keeps the markers in place.
"""
from __future__ import annotations

import re
import sys

sys.path.insert(0, "src")

from benchmarks import (fig3_homogenize, roofline, table2_noniid,  # noqa: E402
                        table3_topology, table4_public, table6_comm,
                        table7_scale)

PATH = "EXPERIMENTS.md"


def repro_section() -> str:
    parts = []
    try:
        rows, _ = table2_noniid.run()
        parts.append("### Table 2 — accuracy vs α (ring 8)\n\n"
                     + table2_noniid.render(rows))
    except Exception as e:  # noqa: BLE001
        parts.append(f"(table2 unavailable: {e})")
    for name, mod in [("Table 3 — topologies", table3_topology),
                      ("Table 4 — public-set choice (α=0.05)", table4_public),
                      ("Table 6 — comm cost", table6_comm),
                      ("Table 7 — scalability", table7_scale)]:
        try:
            rows, _ = mod.run()
            parts.append(f"### {name}\n\n" + mod.render(rows))
        except Exception as e:  # noqa: BLE001
            parts.append(f"({name} unavailable: {e})")
    try:
        rows, _, curves = fig3_homogenize.run()
        r = rows[0]
        parts.append(
            "### Fig 3 — homogenization & convergence\n\n"
            f"* class-skew (mean TV from uniform): {r['pre-IDKD']} pre-IDKD "
            f"→ {r['post-IDKD']} post-IDKD; node-0 empty classes "
            f"{r['node0 empty classes pre']} → {r['node0 empty classes post']}\n"
            f"* accuracy curves (eval every 75 steps): IDKD "
            f"{[round(a, 3) for a in curves['idkd_curve']]} vs QG-DSGDm-N "
            f"{[round(a, 3) for a in curves['qgm_curve']]}")
    except Exception as e:  # noqa: BLE001
        parts.append(f"(fig3 unavailable: {e})")
    parts.append(HONEST_NOTES)
    return "\n\n".join(parts)


HONEST_NOTES = """\
**Honest-reporting notes — what reproduced and what did not**
* ✓ Claim 3 *mechanism*: the MSP detector reproduces exactly — on the
  aligned public set it keeps ≈ the aligned fraction (id_frac 0.49), on
  uniform noise it keeps 0.13, and IDKD > vanilla KD on the aligned set
  (87.11 vs 86.91).
* ✓ Claim 4: homogenization is strong — per-node class skew (TV from
  uniform) 0.610 → 0.137, node-0 empty classes 6 → 0 (Fig 3).
* ✓ Claim 5: comm overhead 0.07% at ResNet scale, and the beyond-paper
  top-8 sparse label codec keeps it at 0.000% at qwen3-1.7b scale where
  the paper's dense codec would cost 2.3% (Table 6).
* ✓ DSGD degrades with skew (88.1 → 84.6) and QG-DSGDm-N dominates DSGD
  by ~4 points at α ≤ 0.1 — the failure mode IDKD builds on is real.
* ✗/~ **Claims 1/2/6 (IDKD > QG-DSGDm-N by 4–8%) did NOT reproduce in the
  Table 2/7 regime**: at ring-8/300 steps QG-IDKD lands within ~1 point
  of QG-DSGDm-N (87.11 vs 88.28 at α=0.05) — i.e. at or slightly below
  the baseline. In the supplementary *calibrated regime* (16-node ring,
  400 steps, exchange at step 260; experiments/calibrated_regime.log)
  the distillation family does beat the baseline — QGM 86.33 < IDKD 86.91
  ≤ vanilla-KD 87.11 — i.e. claim 6's direction holds but the OoD filter's
  *additional* edge over vanilla KD is not resolved there (it IS resolved
  in the ring-8 grid: 87.11 vs 86.91). Root cause of the gap vs the
  paper: with per-step gossip, identical inits and a ~20k-param model on
  synthetic data, the *baseline's* non-IID degradation (which IDKD
  monetizes) is far milder than ResNet20-on-CIFAR; ensemble labels then
  add little over an already-converged consensus. This is the expected
  outcome at repro band 2/5 and we report it as-is.
* The centralized reference under-performs ring QGM here (85.7) because
  exact averaging with the same per-node batch halves the effective
  update diversity at these step counts — unlike the paper's 300-epoch
  regime where it upper-bounds everything."""


def roofline_section() -> str:
    single = roofline.render("single")
    multi = roofline.render("multi")
    return (f"### Single-pod (16×16 = 256 chips)\n\n{single}\n\n"
            f"### Multi-pod (2×16×16 = 512 chips) — proves the pod axis "
            f"shards\n\n{multi}")


def _replace_section(text: str, marker: str, content: str) -> str:
    """Replace everything between ``marker`` and the next '## ' heading."""
    start = text.index(marker) + len(marker)
    rest = text[start:]
    m = re.search(r"\n## ", rest)
    end = start + (m.start() if m else len(rest))
    return text[:start] + "\n\n" + content + "\n\n" + text[end:]


def main():
    with open(PATH) as f:
        text = f.read()
    text = _replace_section(text, "<!-- RESULTS:REPRO -->", repro_section())
    text = _replace_section(text, "<!-- RESULTS:ROOFLINE -->",
                            roofline_section())
    with open(PATH, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
