"""Decentralized driver benchmark: host-loop baseline vs scan driver.

Measures steps/sec of the unified on-device driver (``core.driver``,
DESIGN.md §5) at the default sim node scale (n = 8, ring) for both
consumers — the classifier simulator and the LM launch path — in the
plain and KD phases. Three drivers per cell:

* ``preref``   — the pre-refactor host loop, reconstructed faithfully:
  per-step numpy partition sampling, host-side ``np.where`` private/public
  batch assembly, host→device transfers, one jitted-step dispatch per
  step (what the seed's ``simulator.run`` / ``launch.train.run_training``
  did);
* ``host``     — the driver's host runner: on-device sampling inside one
  jitted step, but still one Python dispatch per step;
* ``scan``     — the driver's ``lax.scan`` chunk runner: zero per-step
  dispatch or host round-trips.

Plus the sharded driver cells (DESIGN.md §7), labeled with the node-mesh
device count so runs at different mesh sizes never collide in the
regression guard:

* ``shard``       — ``make_shard_step`` under ``shard_map`` over the
  node mesh (ppermute gossip), driven by the same scan runner. Run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to measure the
  real 8-device placement (the committed baseline's sharded cells).
* ``scan_im2col`` — (sim path only) the node-stacked scan runner on the
  *same* config the shard cell uses (im2col convs + sparse-KD payloads),
  i.e. the apples-to-apples node-stacked comparator for the shard ratio.
  The LM cells need no twin: their scan/shard configs are identical.

Plus the compressed / compute-overlapped gossip cells (DESIGN.md §9):
the plain LM workload under the stateful mixers — compression ∈ {none,
top-k 1%, top-k 10%} × gossip ∈ {sync, delayed} on the scan runner, each
labeled with its ``bytes_per_step`` ledger wire total, and a top-k 1%
sync/delayed pair on the sharded driver (ppermute payload wires).

Medians over interleaved rounds (this keeps CPU-frequency / noisy-
neighbour drift out of the ratios). Writes ``BENCH_driver.json``.

Findings on a 2-core CPU container (recorded in the committed baseline;
see DESIGN.md §5 for the full analysis):

* the scan driver wins by eliminating ~1–2 ms/step of dispatch + host
  assembly, but XLA:CPU executes while-loop bodies thunk-by-thunk at the
  same per-op cost as top-level graphs, so the win is Amdahl-capped by
  the step's thunk-execution floor (≈1.1–1.6× here, ≥2× expected where
  kernels are fast relative to dispatch — many-core hosts, TPU);
* two XLA:CPU conv pathologies: batched-kernel (vmapped) convs are ~4×
  slower than per-node convs even at top level, and any conv inside a
  ``while`` loop falls off the threaded fast path (~5×). Full scan
  unrolling recovers it but compile time explodes; left off by default.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core import driver
from repro.core.algorithms import make_algorithm
from repro.core.mixing import make_mixer
from repro.core.simulator import DecentralizedSimulator
from repro.core.topology import Topology
from repro.data.dirichlet import dirichlet_partition
from repro.data.pipeline import HomogenizedSampler, NodeSampler
from repro.data.synthetic import (make_classification_data, make_lm_data,
                                  make_public_data)
from repro.launch.steps import stack_params
from repro.models import build_model

from benchmarks.common import step_percentiles

NODES = 8
CHUNK = 20          # steps per timed chunk
ROUNDS = 5          # interleaved rounds; report medians


class _Rate(float):
    """µs/step median that also carries the p95 of its sample rounds.

    Subclassing float keeps every existing consumer (ratios, rounding,
    JSON cells) working on the p50 while ``rate.p95`` rides along for
    the BENCH percentile fields."""

    def __new__(cls, p50: float, p95: float):
        obj = super().__new__(cls, p50)
        obj.p95 = p95
        return obj


def _median_rates(drivers):
    """Interleave ROUNDS of each driver fn; µs/step ``_Rate`` (p50 with
    a ``.p95`` attribute) per driver."""
    for fn in drivers.values():        # compile / warm everything first
        fn()
    times = {k: [] for k in drivers}
    for _ in range(ROUNDS):
        for k, fn in drivers.items():
            t0 = time.time()
            fn()
            times[k].append((time.time() - t0) / CHUNK * 1e6)
    return {k: _Rate(*step_percentiles(v)) for k, v in times.items()}


# ------------------------------------------------------------- sim (CNN)
def _sim_cell(kd: bool):
    data = make_classification_data(image_size=8, n_train=1024, n_val=64,
                                    n_test=128, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=256, kind="aligned", seed=1)
    mcfg = SMALL_CONFIG.replace(image_size=8, cnn_stages=(1, 1, 1),
                                cnn_width=8)
    tcfg = TrainConfig(num_nodes=NODES, steps=CHUNK, batch_size=16, seed=4,
                       idkd=IDKDConfig(start_step=0, temperature=10.0))
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub if kd else None,
                                 kd_mode="idkd" if kd else None)
    params = sim._stacked_init()
    opt = sim.algo.init(params)
    priv = driver.pad_partitions(sim.parts)
    eye = np.eye(10, dtype=np.float32)
    lr = jnp.asarray(0.3, jnp.float32)

    if not kd:
        step_fn = sim._plain_step
        sampler = driver.make_classification_sampler(
            priv, data.train_x, data.train_y, 10, tcfg.batch_size)
        ns = NodeSampler(sim.parts, tcfg.batch_size, 4)
        one = jax.jit(step_fn)

        def preref():
            p, o = params, opt
            for _ in range(CHUNK):
                idx = ns.sample()
                p, o, l = one(p, o, {
                    "images": jnp.asarray(data.train_x[idx]),
                    "labels": jnp.asarray(eye[data.train_y[idx]]),
                    "weights": jnp.ones(idx.shape, np.float32)}, lr)
            jax.block_until_ready(l)
    else:
        step_fn = sim._kd_step
        hom = sim._homogenize(params, tcfg.idkd)
        w = np.asarray(hom.weights)
        labels = np.asarray(hom.labels)
        pubparts = driver.pad_partitions([np.flatnonzero(x > 0) for x in w])
        sampler = driver.make_homogenized_sampler(
            priv, pubparts, data.train_x, data.train_y, pub, w, labels, 10,
            tcfg.batch_size)
        hs = HomogenizedSampler(sim.parts, w, tcfg.batch_size, 4,
                                public_labels=labels)
        one = jax.jit(step_fn)

        def preref():
            p, o = params, opt
            for _ in range(CHUNK):
                pr, pb, ip = hs.sample()
                p, o, l = one(p, o, {
                    "images": jnp.asarray(np.where(
                        ip[..., None, None, None], pub[pb],
                        data.train_x[pr])),
                    "labels": jnp.asarray(np.where(
                        ip[..., None], hs.gather_public(pb),
                        eye[data.train_y[pr]])),
                    "weights": jnp.asarray(np.where(
                        ip, hs.gather_weights(pb), 1.0)).astype(jnp.float32),
                    "is_pub": jnp.asarray(ip)}, lr)
            jax.block_until_ready(l)

    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)
    hostr = driver.make_runner(step_fn, sampler, sim.lr_fn, "host")
    scanr = driver.make_runner(step_fn, sampler, sim.lr_fn, "scan")

    def host():
        jax.block_until_ready(hostr(params, opt, k, s0, CHUNK)[0])

    def scan():
        jax.block_until_ready(scanr(params, opt, k, s0, CHUNK)[0])

    return _median_rates({"preref": preref, "host": host, "scan": scan})


def _sim_shard_cell(kd: bool):
    """Sharded sim cells: the same workload on the shard-mode config
    (im2col convs — lax convs are host-bound on CPU — and sparse-KD
    payloads, the only wire format shard mode exchanges), node-stacked
    vs shard_map. Interleaved together so the ratio is clean."""
    from repro.core.topology import Topology
    from repro.launch.mesh import make_node_mesh
    from repro.launch.sharding import node_stacked_shardings

    data = make_classification_data(image_size=8, n_train=1024, n_val=64,
                                    n_test=128, noise=0.8, seed=0)
    pub = make_public_data(data, n_public=256, kind="aligned", seed=1)
    mcfg = SMALL_CONFIG.replace(image_size=8, cnn_stages=(1, 1, 1),
                                cnn_width=8, conv_backend="im2col")
    icfg = IDKDConfig(start_step=0, temperature=10.0, label_topk=8,
                      label_backend="sparse")
    tcfg = TrainConfig(num_nodes=NODES, steps=CHUNK, batch_size=16, seed=4,
                       idkd=icfg)
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub if kd else None,
                                 kd_mode="idkd" if kd else None)
    params = sim._stacked_init()
    opt = sim.algo.init(params)
    priv = driver.pad_partitions(sim.parts)
    mesh = make_node_mesh(NODES)
    topo = Topology.make("ring", NODES)

    if kd:
        hom = sim._homogenize(params, icfg)
        w = np.asarray(hom.weights)
        payload = (np.asarray(hom.labels.values),
                   np.asarray(hom.labels.indices))
        pubparts = driver.pad_partitions([np.flatnonzero(x > 0) for x in w])
        sampler = driver.make_homogenized_sampler(
            priv, pubparts, data.train_x, data.train_y, pub, w, payload, 10,
            tcfg.batch_size)
        adapter = driver.sparse_kd_adapter(icfg.temperature, icfg.kd_weight)
        stacked_step = sim._sparse_kd_step
    else:
        sampler = driver.make_classification_sampler(
            priv, data.train_x, data.train_y, 10, tcfg.batch_size)
        adapter = driver.classification_adapter
        stacked_step = sim._plain_step
    shard_step = driver.make_shard_step(sim.model, sim.algo, adapter,
                                        mesh=mesh, topology=topo)
    scanr = driver.make_runner(stacked_step, sampler, sim.lr_fn, "scan")
    shardr = driver.make_runner(shard_step, sampler, sim.lr_fn, "shard")
    params_sh = jax.device_put(
        params, node_stacked_shardings(params, mesh, NODES))
    opt_sh = jax.device_put(opt, node_stacked_shardings(opt, mesh, NODES))
    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)

    def scan():
        jax.block_until_ready(scanr(params, opt, k, s0, CHUNK)[0])

    def shard():
        jax.block_until_ready(shardr(params_sh, opt_sh, k, s0, CHUNK)[0])

    rates = _median_rates({"scan_im2col": scan, "shard": shard})
    return rates, int(mesh.shape["node"])


# -------------------------------------------------------------- LM (txf)
def _lm_cell(kd: bool):
    n, B, S = NODES, 8, 32
    cfg = get_config("qwen3-1.7b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    icfg = IDKDConfig(start_step=0, label_topk=8, kd_weight=0.3)
    model = build_model(cfg)
    mixer = make_mixer(Topology.make("ring", n))
    algo = make_algorithm("qg-dsgdm-n", momentum=0.9, weight_decay=1e-4)
    tokens, topics = make_lm_data(cfg.vocab_size, S + 1, 512, seed=4)
    parts = dirichlet_partition(topics, n, 0.1, np.random.default_rng(4))
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    adapter = driver.lm_sparse_kd_adapter(icfg) if kd else driver.lm_adapter
    step_fn = driver.make_step(model, algo, mixer, adapter)
    opt = step_fn.init_opt(params)
    lr = jnp.asarray(0.1, jnp.float32)
    lr_fn = lambda s: lr                                  # noqa: E731
    rngs = [np.random.default_rng(4 + 5 * i) for i in range(n)]
    priv = driver.pad_partitions(parts)

    if kd:
        P = 64
        pub_tokens, _ = make_lm_data(cfg.vocab_size, S, P, num_topics=10,
                                     seed=103)
        rngp = np.random.default_rng(0)
        vals = rngp.dirichlet(np.ones(8), size=(n, P, S)).astype(np.float32)
        idxs = rngp.integers(0, cfg.vocab_size,
                             size=(n, P, S, 8)).astype(np.int32)
        w = np.ones((n, P), np.float32)
        sampler = driver.make_lm_kd_sampler(priv, tokens, B, pub_tokens,
                                            vals, idxs, w, 4)
    else:
        sampler = driver.make_lm_sampler(priv, tokens, B)
    one = jax.jit(step_fn)
    nidx = np.arange(n)[:, None]

    def preref():
        p, o = params, opt
        for _ in range(CHUNK):
            idx = np.stack([r.choice(parts[i], size=B,
                                     replace=len(parts[i]) < B)
                            for i, r in enumerate(rngs)])
            b = {"tokens": jnp.asarray(tokens[idx][:, :, :-1]),
                 "labels": jnp.asarray(tokens[idx][:, :, 1:])}
            if kd:
                pb = np.stack([r.integers(0, P, size=4) for r in rngs])
                b["pub_tokens"] = jnp.asarray(pub_tokens[pb])
                b["pub_vals"] = jnp.asarray(vals[nidx, pb])
                b["pub_idx"] = jnp.asarray(idxs[nidx, pb])
                b["pub_w"] = jnp.asarray(w[nidx, pb])
            p, o, l = one(p, o, b, lr)
        jax.block_until_ready(l)

    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)
    hostr = driver.make_runner(step_fn, sampler, lr_fn, "host")
    scanr = driver.make_runner(step_fn, sampler, lr_fn, "scan")

    def host():
        jax.block_until_ready(hostr(params, opt, k, s0, CHUNK)[0])

    def scan():
        jax.block_until_ready(scanr(params, opt, k, s0, CHUNK)[0])

    return _median_rates({"preref": preref, "host": host, "scan": scan})


def _lm_comp_cell():
    """Compressed / compute-overlapped gossip cells (DESIGN.md §9): the
    plain LM workload under the stateful mixers, compression ∈ {none,
    top-k 1%, top-k 10%} × gossip ∈ {sync, delayed}, all on the scan
    runner. Each cell also records ``bytes_per_step`` — the ledger's
    per-step wire total for the whole ring — so the regression guard
    watches the wire alongside the clock (a top-k cell whose bytes creep
    back toward dense means the sparsifier broke, whatever the µs say)."""
    from repro import sched
    from repro.core.mixing import normalize_compression, payload_elem_count

    n, B, S = NODES, 8, 32
    cfg = get_config("qwen3-1.7b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    model = build_model(cfg)
    topo = Topology.make("ring", n)
    algo = make_algorithm("qg-dsgdm-n", momentum=0.9, weight_decay=1e-4)
    tokens, topics = make_lm_data(cfg.vocab_size, S + 1, 512, seed=4)
    parts = dirichlet_partition(topics, n, 0.1, np.random.default_rng(4))
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    sampler = driver.make_lm_sampler(driver.pad_partitions(parts), tokens, B)
    lr_fn = lambda s: jnp.asarray(0.1, jnp.float32)       # noqa: E731
    nparams = sum(x.size for x in jax.tree.leaves(params)) // n
    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)

    variants = [("none", "sync"), ("none", "delayed"),
                ("topk:0.01", "sync"), ("topk:0.01", "delayed"),
                ("topk:0.1", "sync")]
    drivers, wire = {}, {}
    for comp_name, gossip in variants:
        comp = normalize_compression(None if comp_name == "none"
                                     else comp_name)
        if comp_name == "none" and gossip == "sync":
            mixer = make_mixer(topo)                      # dense baseline
            step_fn = driver.make_step(model, algo, mixer, driver.lm_adapter)
        else:
            mixer = make_mixer(topo, compression=comp, gossip=gossip,
                               stateful=True)
            step_fn = driver.make_step(model, algo, mixer, driver.lm_adapter)
        runr = driver.make_runner(step_fn, sampler, lr_fn, "scan")
        opt = step_fn.init_opt(params)
        key = f"{comp_name}|{gossip}"
        if getattr(runr, "comm", False):
            comm = step_fn.init_comm(params)

            def bench(runr=runr, opt=opt, comm=comm):
                jax.block_until_ready(
                    runr(params, opt, k, s0, CHUNK, None, comm)[0])
        else:
            def bench(runr=runr, opt=opt):
                jax.block_until_ready(runr(params, opt, k, s0, CHUNK)[0])
        drivers[key] = bench
        payload = (payload_elem_count(params, comp) if comp is not None
                   else None)
        wire[key] = float(sched.ledger.gossip_bytes_per_step(
            topo, None, nparams, 4, payload_elems=payload,
            index_bytes=4 if comp is not None else 0).sum())
    return _median_rates(drivers), wire


def _lm_shard_cell(kd: bool):
    """Sharded LM cells: the LM scan/shard configs are identical (no
    convs, KD already sparse), so shard is interleaved directly against
    the node-stacked scan runner."""
    from repro.core.topology import Topology
    from repro.launch.mesh import make_node_mesh
    from repro.launch.sharding import node_stacked_shardings

    n, B, S = NODES, 8, 32
    cfg = get_config("qwen3-1.7b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    icfg = IDKDConfig(start_step=0, label_topk=8, kd_weight=0.3)
    model = build_model(cfg)
    topo = Topology.make("ring", n)
    mesh = make_node_mesh(n)
    algo = make_algorithm("qg-dsgdm-n", momentum=0.9, weight_decay=1e-4)
    tokens, topics = make_lm_data(cfg.vocab_size, S + 1, 512, seed=4)
    parts = dirichlet_partition(topics, n, 0.1, np.random.default_rng(4))
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    adapter = driver.lm_sparse_kd_adapter(icfg) if kd else driver.lm_adapter
    stacked_step = driver.make_step(model, algo, make_mixer(topo), adapter)
    shard_step = driver.make_shard_step(model, algo, adapter, mesh=mesh,
                                        topology=topo)
    opt = stacked_step.init_opt(params)
    lr_fn = lambda s: jnp.asarray(0.1, jnp.float32)       # noqa: E731
    priv = driver.pad_partitions(parts)
    if kd:
        P = 64
        pub_tokens, _ = make_lm_data(cfg.vocab_size, S, P, num_topics=10,
                                     seed=103)
        rngp = np.random.default_rng(0)
        vals = rngp.dirichlet(np.ones(8), size=(n, P, S)).astype(np.float32)
        idxs = rngp.integers(0, cfg.vocab_size,
                             size=(n, P, S, 8)).astype(np.int32)
        w = np.ones((n, P), np.float32)
        sampler = driver.make_lm_kd_sampler(priv, tokens, B, pub_tokens,
                                            vals, idxs, w, 4)
    else:
        sampler = driver.make_lm_sampler(priv, tokens, B)
    scanr = driver.make_runner(stacked_step, sampler, lr_fn, "scan")
    shardr = driver.make_runner(shard_step, sampler, lr_fn, "shard")
    params_sh = jax.device_put(params,
                               node_stacked_shardings(params, mesh, n))
    opt_sh = jax.device_put(opt, node_stacked_shardings(opt, mesh, n))
    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)

    def scan():
        jax.block_until_ready(scanr(params, opt, k, s0, CHUNK)[0])

    def shard():
        jax.block_until_ready(shardr(params_sh, opt_sh, k, s0, CHUNK)[0])

    rates = _median_rates({"scan": scan, "shard": shard})
    return rates, int(mesh.shape["node"])


def _lm_tel_cell():
    """Telemetry metrics-bus overhead cells (DESIGN.md §11): the plain
    LM workload with the on-device metrics carry off vs on, node-stacked
    scan and shard_map runners, all four interleaved. The acceptance
    gate is on ≤ 1.05× off per runner (the metrics update is a handful
    of per-leaf square-sums fused into the step); trajectories are
    bitwise identical either way (tests/test_obs.py)."""
    from repro.launch.mesh import make_node_mesh
    from repro.launch.sharding import (node_stacked_shardings,
                                       node_stacked_specs)
    from repro.obs import metrics as obs_metrics

    n, B, S = NODES, 8, 32
    cfg = get_config("qwen3-1.7b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    model = build_model(cfg)
    topo = Topology.make("ring", n)
    mesh = make_node_mesh(n)
    algo = make_algorithm("qg-dsgdm-n", momentum=0.9, weight_decay=1e-4)
    tokens, topics = make_lm_data(cfg.vocab_size, S + 1, 512, seed=4)
    parts = dirichlet_partition(topics, n, 0.1, np.random.default_rng(4))
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    sampler = driver.make_lm_sampler(driver.pad_partitions(parts), tokens, B)
    lr_fn = lambda s: jnp.asarray(0.1, jnp.float32)       # noqa: E731
    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)

    scan_off = driver.make_step(model, algo, make_mixer(topo),
                                driver.lm_adapter)
    scan_on = driver.make_step(model, algo, make_mixer(topo),
                               driver.lm_adapter, telemetry=True)
    shard_off = driver.make_shard_step(model, algo, driver.lm_adapter,
                                       mesh=mesh, topology=topo)
    shard_on = driver.make_shard_step(model, algo, driver.lm_adapter,
                                      mesh=mesh, topology=topo,
                                      telemetry=True)
    opt = scan_off.init_opt(params)
    params_sh = jax.device_put(params,
                               node_stacked_shardings(params, mesh, n))
    opt_sh = jax.device_put(opt, node_stacked_shardings(opt, mesh, n))
    m0 = obs_metrics.init_node_metrics(n)
    m0_sh = jax.device_put(
        m0, jax.tree.map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp),
            node_stacked_specs(m0, n, "node")))
    runners = {
        "scan|off": driver.make_runner(scan_off, sampler, lr_fn, "scan"),
        "scan|on": driver.make_runner(scan_on, sampler, lr_fn, "scan"),
        "shard|off": driver.make_runner(shard_off, sampler, lr_fn, "shard"),
        "shard|on": driver.make_runner(shard_on, sampler, lr_fn, "shard"),
    }

    def bench(key):
        runr = runners[key]
        mode, tel = key.split("|")
        p = params_sh if mode == "shard" else params
        o = opt_sh if mode == "shard" else opt
        if tel == "on":
            m = m0_sh if mode == "shard" else m0
            return lambda: jax.block_until_ready(
                runr(p, o, k, s0, CHUNK, None, None, m)[0])
        return lambda: jax.block_until_ready(runr(p, o, k, s0, CHUNK)[0])

    rates = _median_rates({key: bench(key) for key in runners})
    return rates, int(mesh.shape["node"])


def _lm_guard_cell():
    """Health-guard overhead cells (DESIGN.md §12): the plain LM
    workload with the on-device guard carry off vs on, node-stacked scan
    and shard_map runners, all four interleaved. Acceptance mirrors the
    telemetry gate: on ≤ 1.05× off per runner (the guard update is
    non-finite sweeps + a loss EMA fused into the step); trajectories
    are bitwise identical either way (tests/test_resil.py)."""
    from repro.launch.mesh import make_node_mesh
    from repro.launch.sharding import (node_stacked_shardings,
                                       node_stacked_specs)
    from repro.resil import GuardSpec, guards as resil_guards

    n, B, S = NODES, 8, 32
    cfg = get_config("qwen3-1.7b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    model = build_model(cfg)
    topo = Topology.make("ring", n)
    mesh = make_node_mesh(n)
    algo = make_algorithm("qg-dsgdm-n", momentum=0.9, weight_decay=1e-4)
    tokens, topics = make_lm_data(cfg.vocab_size, S + 1, 512, seed=4)
    parts = dirichlet_partition(topics, n, 0.1, np.random.default_rng(4))
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    sampler = driver.make_lm_sampler(driver.pad_partitions(parts), tokens, B)
    lr_fn = lambda s: jnp.asarray(0.1, jnp.float32)       # noqa: E731
    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)
    spec = GuardSpec(loss_spike_factor=10.0, consensus_max=1e4)

    scan_off = driver.make_step(model, algo, make_mixer(topo),
                                driver.lm_adapter)
    scan_on = driver.make_step(model, algo, make_mixer(topo),
                               driver.lm_adapter, guard=spec)
    shard_off = driver.make_shard_step(model, algo, driver.lm_adapter,
                                       mesh=mesh, topology=topo)
    shard_on = driver.make_shard_step(model, algo, driver.lm_adapter,
                                      mesh=mesh, topology=topo, guard=spec)
    opt = scan_off.init_opt(params)
    params_sh = jax.device_put(params,
                               node_stacked_shardings(params, mesh, n))
    opt_sh = jax.device_put(opt, node_stacked_shardings(opt, mesh, n))
    g0 = resil_guards.init_node_guard(n)
    g0_sh = jax.device_put(
        g0, jax.tree.map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp),
            node_stacked_specs(g0, n, "node")))
    runners = {
        "scan|off": driver.make_runner(scan_off, sampler, lr_fn, "scan"),
        "scan|on": driver.make_runner(scan_on, sampler, lr_fn, "scan"),
        "shard|off": driver.make_runner(shard_off, sampler, lr_fn, "shard"),
        "shard|on": driver.make_runner(shard_on, sampler, lr_fn, "shard"),
    }

    def bench(key):
        runr = runners[key]
        mode, on = key.split("|")
        p = params_sh if mode == "shard" else params
        o = opt_sh if mode == "shard" else opt
        if on == "on":
            g = g0_sh if mode == "shard" else g0
            return lambda: jax.block_until_ready(
                runr(p, o, k, s0, CHUNK, None, None, None, g)[0])
        return lambda: jax.block_until_ready(runr(p, o, k, s0, CHUNK)[0])

    rates = _median_rates({key: bench(key) for key in runners})
    return rates, int(mesh.shape["node"])


def _lm_shard_comp_cell():
    """Sharded compressed-gossip cells: ``make_shard_step`` with the
    ppermute compressed mixer (top-k 1%, sync and delayed) against the
    node-stacked scan twin on the same spec — the wire actually crossing
    device boundaries is the (values, indices) payload. Labeled with the
    device count like the other shard cells."""
    from repro.launch.mesh import make_node_mesh
    from repro.launch.sharding import node_stacked_shardings

    n, B, S = NODES, 8, 32
    cfg = get_config("qwen3-1.7b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    model = build_model(cfg)
    topo = Topology.make("ring", n)
    mesh = make_node_mesh(n)
    algo = make_algorithm("qg-dsgdm-n", momentum=0.9, weight_decay=1e-4)
    tokens, topics = make_lm_data(cfg.vocab_size, S + 1, 512, seed=4)
    parts = dirichlet_partition(topics, n, 0.1, np.random.default_rng(4))
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    sampler = driver.make_lm_sampler(driver.pad_partitions(parts), tokens, B)
    lr_fn = lambda s: jnp.asarray(0.1, jnp.float32)       # noqa: E731
    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)
    comp = ("topk", 0.01)
    params_sh = jax.device_put(params,
                               node_stacked_shardings(params, mesh, n))

    drivers = {}
    for gossip in ("sync", "delayed"):
        stacked_step = driver.make_step(
            model, algo, make_mixer(topo, compression=comp, gossip=gossip,
                                    stateful=True), driver.lm_adapter)
        shard_step = driver.make_shard_step(model, algo, driver.lm_adapter,
                                            mesh=mesh, topology=topo,
                                            compression=comp, gossip=gossip)
        scanr = driver.make_runner(stacked_step, sampler, lr_fn, "scan")
        shardr = driver.make_runner(shard_step, sampler, lr_fn, "shard")
        opt = stacked_step.init_opt(params)
        comm = stacked_step.init_comm(params)
        opt_sh = jax.device_put(opt, node_stacked_shardings(opt, mesh, n))
        comm0 = shard_step.init_comm(params)
        comm_sh = jax.device_put(comm0,
                                 node_stacked_shardings(comm0, mesh, n))

        def scan(scanr=scanr, opt=opt, comm=comm):
            jax.block_until_ready(
                scanr(params, opt, k, s0, CHUNK, None, comm)[0])

        def shard(shardr=shardr, opt_sh=opt_sh, comm_sh=comm_sh):
            jax.block_until_ready(
                shardr(params_sh, opt_sh, k, s0, CHUNK, None, comm_sh)[0])

        drivers[f"scan|{gossip}"] = scan
        drivers[f"shard|{gossip}"] = shard
    return _median_rates(drivers), int(mesh.shape["node"])


def _lm_mesh_shapes_cell():
    """2-D federation-mesh cells (DESIGN.md §10): the plain LM shard
    workload at every mesh factoring the device pool admits — e.g. 8
    devices split 8×1 (pure node), 4×2, and 2×4 (node × model). Each
    cell is labeled with its ``"mesh"`` shape string so the regression
    guard keys them as distinct cells, and records the gossip
    ``bytes_per_step``, which must be *identical* across model-parallel
    widths: gossip ppermutes over the node axis only, so sharding a
    replica over more devices changes where bytes live, never how many
    cross the node graph."""
    from repro import sched
    from repro.launch.mesh import make_federation_mesh
    from repro.launch.sharding import federation_shardings

    n, B, S = NODES, 8, 32
    cfg = get_config("qwen3-1.7b").reduced().replace(
        num_layers=1, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    model = build_model(cfg)
    topo = Topology.make("ring", n)
    algo = make_algorithm("qg-dsgdm-n", momentum=0.9, weight_decay=1e-4)
    tokens, topics = make_lm_data(cfg.vocab_size, S + 1, 512, seed=4)
    parts = dirichlet_partition(topics, n, 0.1, np.random.default_rng(4))
    params = stack_params(model.init(jax.random.PRNGKey(0)), n)
    sampler = driver.make_lm_sampler(driver.pad_partitions(parts), tokens, B)
    lr_fn = lambda s: jnp.asarray(0.1, jnp.float32)       # noqa: E731
    nparams = sum(x.size for x in jax.tree.leaves(params)) // n
    wire = float(sched.ledger.gossip_bytes_per_step(
        topo, None, nparams, 4).sum())
    k = jax.random.PRNGKey(0)
    s0 = jnp.asarray(0, jnp.int32)

    ndev = len(jax.devices())
    drivers, labels = {}, {}
    for mp in (1, 2, 4):
        if mp > ndev:
            continue
        mesh = make_federation_mesh(n, mp)
        shape = dict(mesh.shape)
        label = f"{shape['node']}x{shape.get('model', 1)}"
        if label in labels.values():
            continue                       # tiny pools collapse shapes
        step = driver.make_shard_step(model, algo, driver.lm_adapter,
                                      mesh=mesh, topology=topo)
        runr = driver.make_runner(step, sampler, lr_fn, "shard")
        p_sh = jax.device_put(params, federation_shardings(params, mesh, n))
        o_sh = jax.device_put(step.init_opt(params),
                              federation_shardings(step.init_opt(params),
                                                   mesh, n))

        def bench(runr=runr, p_sh=p_sh, o_sh=o_sh):
            jax.block_until_ready(runr(p_sh, o_sh, k, s0, CHUNK)[0])

        drivers[mp] = bench
        labels[mp] = label
    rates = _median_rates(drivers)
    return {labels[mp]: us for mp, us in rates.items()}, wire


def run(out_path: str | None = "BENCH_driver.json"):
    csv, cells = [], []
    for path, cell_fn in (("sim", _sim_cell), ("lm", _lm_cell)):
        for kd in (False, True):
            phase = f"{path}_{'kd' if kd else 'plain'}"
            rates = cell_fn(kd)
            for mode, us in rates.items():
                csv.append((f"driver/{phase}_{mode}", round(us, 1),
                            f"{1e6 / us:.1f} steps/s"))
                cells.append({"path": path, "kd": kd, "mode": mode,
                              "us_per_step": round(us, 1),
                              "us_per_step_p95": round(us.p95, 1),
                              "steps_per_sec": round(1e6 / us, 2)})
            csv.append((f"driver/{phase}_speedup", 0.0,
                        f"{rates['preref'] / rates['scan']:.2f}x"))
    # compressed / delayed gossip cells (DESIGN.md §9)
    comp_rates, comp_wire = _lm_comp_cell()
    for key, us in comp_rates.items():
        comp_name, gossip = key.split("|")
        csv.append((f"driver/lm_gossip[{comp_name},{gossip}]",
                    round(us, 1),
                    f"{1e6 / us:.1f} steps/s, "
                    f"{comp_wire[key] / 1e3:.1f} KB/step"))
        cells.append({"path": "lm", "mode": "scan",
                      "compression": comp_name, "gossip": gossip,
                      "us_per_step": round(us, 1),
                      "us_per_step_p95": round(us.p95, 1),
                      "steps_per_sec": round(1e6 / us, 2),
                      "bytes_per_step": round(comp_wire[key], 1)})
    dense_key, topk_key = "none|sync", "topk:0.01|sync"
    csv.append(("driver/lm_gossip_wire_reduction", 0.0,
                f"{comp_wire[dense_key] / comp_wire[topk_key]:.1f}x"))
    # sharded driver cells (labeled with the node-mesh device count, so
    # baselines from different mesh sizes are guard-skipped, not compared)
    for path, cell_fn in (("sim", _sim_shard_cell), ("lm", _lm_shard_cell)):
        for kd in (False, True):
            phase = f"{path}_{'kd' if kd else 'plain'}"
            rates, devices = cell_fn(kd)
            stacked_mode = "scan_im2col" if path == "sim" else "scan"
            for mode, us in rates.items():
                csv.append((f"driver/{phase}_{mode}@{devices}dev",
                            round(us, 1), f"{1e6 / us:.1f} steps/s"))
                cells.append({"path": path, "kd": kd, "mode": mode,
                              "devices": devices,
                              "us_per_step": round(us, 1),
                              "us_per_step_p95": round(us.p95, 1),
                              "steps_per_sec": round(1e6 / us, 2)})
            csv.append((f"driver/{phase}_shard_vs_stacked@{devices}dev",
                        0.0,
                        f"{rates[stacked_mode] / rates['shard']:.2f}x"))
    # telemetry metrics-bus overhead cells (DESIGN.md §11): off vs on
    # per runner; the acceptance gate is on ≤ 1.05x off
    tel_rates, devices = _lm_tel_cell()
    for key, us in tel_rates.items():
        mode, tel = key.split("|")
        dev = f"@{devices}dev" if mode == "shard" else ""
        csv.append((f"driver/lm_plain_{mode}_telemetry_{tel}{dev}",
                    round(us, 1), f"{1e6 / us:.1f} steps/s"))
        cells.append({"path": "lm", "kd": False, "mode": mode,
                      "telemetry": tel == "on",
                      **({"devices": devices} if mode == "shard" else {}),
                      "us_per_step": round(us, 1),
                      "us_per_step_p95": round(us.p95, 1),
                      "steps_per_sec": round(1e6 / us, 2)})
    for mode in ("scan", "shard"):
        dev = f"@{devices}dev" if mode == "shard" else ""
        ratio = tel_rates[f"{mode}|on"] / tel_rates[f"{mode}|off"]
        csv.append((f"driver/lm_plain_{mode}_telemetry_overhead{dev}", 0.0,
                    f"{ratio:.3f}x"))
    # health-guard overhead cells (DESIGN.md §12): off vs on per runner;
    # same acceptance gate as telemetry, on ≤ 1.05x off
    grd_rates, devices = _lm_guard_cell()
    for key, us in grd_rates.items():
        mode, on = key.split("|")
        dev = f"@{devices}dev" if mode == "shard" else ""
        csv.append((f"driver/lm_plain_{mode}_guards_{on}{dev}",
                    round(us, 1), f"{1e6 / us:.1f} steps/s"))
        cells.append({"path": "lm", "kd": False, "mode": mode,
                      "guards": on == "on",
                      **({"devices": devices} if mode == "shard" else {}),
                      "us_per_step": round(us, 1),
                      "us_per_step_p95": round(us.p95, 1),
                      "steps_per_sec": round(1e6 / us, 2)})
    for mode in ("scan", "shard"):
        dev = f"@{devices}dev" if mode == "shard" else ""
        ratio = grd_rates[f"{mode}|on"] / grd_rates[f"{mode}|off"]
        csv.append((f"driver/lm_plain_{mode}_guards_overhead{dev}", 0.0,
                    f"{ratio:.3f}x"))
    # 2-D mesh-shape cells (node × model factorings of the device pool);
    # gossip bytes are mesh-shape-invariant — the guard watches that too
    mesh_rates, mesh_wire = _lm_mesh_shapes_cell()
    for label, us in mesh_rates.items():
        csv.append((f"driver/lm_plain_shard_mesh[{label}]", round(us, 1),
                    f"{1e6 / us:.1f} steps/s, "
                    f"{mesh_wire / 1e3:.1f} KB/step gossip"))
        cells.append({"path": "lm", "kd": False, "mode": "shard",
                      "mesh": label, "us_per_step": round(us, 1),
                      "us_per_step_p95": round(us.p95, 1),
                      "steps_per_sec": round(1e6 / us, 2),
                      "bytes_per_step": round(mesh_wire, 1)})
    # sharded compressed-gossip cells (top-k 1%, sync + delayed)
    shc_rates, devices = _lm_shard_comp_cell()
    for key, us in shc_rates.items():
        mode, gossip = key.split("|")
        csv.append((f"driver/lm_gossip_{mode}[topk:0.01,{gossip}]"
                    f"@{devices}dev", round(us, 1),
                    f"{1e6 / us:.1f} steps/s"))
        cells.append({"path": "lm", "mode": mode, "devices": devices,
                      "compression": "topk:0.01", "gossip": gossip,
                      "us_per_step": round(us, 1),
                      "us_per_step_p95": round(us.p95, 1),
                      "steps_per_sec": round(1e6 / us, 2)})
    if out_path:
        with open(out_path, "w") as f:
            json.dump({"meta": {
                "nodes": NODES, "topology": "ring",
                "chunk_steps": CHUNK, "rounds": ROUNDS,
                "jax_backend": jax.default_backend(),
                "devices": len(jax.devices()),
                "what": ("decentralized driver µs/step, median over "
                         "interleaved rounds: pre-refactor host loop "
                         "(numpy sampling + per-step dispatch) vs driver "
                         "host runner vs lax.scan chunk runner; plus "
                         "shard_map node-mesh cells vs their node-stacked "
                         "twins (mode=shard / scan_im2col, DESIGN.md §7)"),
                "caveat": ("on few-core CPU the step's XLA thunk-execution "
                           "floor bounds the scan win (see DESIGN.md §5); "
                           "an 8-device host mesh oversubscribes the cores, "
                           "yet the LM shard cells still beat node-stacked "
                           "~1.5x (smaller per-device programs parallelize "
                           "across cores better than one fused vmap graph) "
                           "while the conv sim cells stay host-bound "
                           "(~0.85x); the ≥2x target applies where kernels "
                           "are fast relative to dispatch (many-core / "
                           "TPU)")},
                "cells": cells}, f, indent=2)
            f.write("\n")
    return [], csv


if __name__ == "__main__":
    for row in run()[1]:
        print(",".join(str(x) for x in row))
