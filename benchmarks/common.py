"""Shared benchmark harness: run grid cells with JSON result caching.

Every paper-table benchmark builds on ``run_cell`` — one decentralized
simulator run for a (method × α × topology × n) cell — with results cached
under ``experiments/bench/`` so re-runs are incremental and the final
``benchmarks.run`` report is cheap to regenerate.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import IDKDConfig, TrainConfig
from repro.configs.resnet20_cifar import SMALL_CONFIG
from repro.core.simulator import DecentralizedSimulator
from repro.data.synthetic import (ClassificationData,
                                  make_classification_data, make_public_data)

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "experiments/bench")

# quick-mode defaults (CPU, single core): small images, short runs.
# NOISE is set so accuracy saturates well below 100% — the non-IID failure
# mode needs headroom to be visible; calibration notes in EXPERIMENTS.md.
IMAGE_SIZE = 8
N_TRAIN = 768
N_PUBLIC = 768
NOISE = 2.0
STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "300"))
BATCH = 16
KD_START_FRAC = 0.65          # label exchange after the plateau (paper: 240/300)
KD_TEMPERATURE = 4.0          # tuned on validation, as the paper tunes T

_METHODS = {
    # name -> (algorithm, kd_mode)
    "dsgd": ("dsgd", None),
    "relay-sgd": ("relaysgd", None),
    "qg-dsgdm-n": ("qg-dsgdm-n", None),
    "qg-dsgdm-n+kd": ("qg-dsgdm-n", "vanilla"),
    "qg-idkd": ("qg-dsgdm-n", "idkd"),
    "sgd-centralized": ("centralized", None),
}

_data_cache: Dict[Any, Any] = {}


def get_data(seed: int = 0) -> ClassificationData:
    key = ("data", seed)
    if key not in _data_cache:
        _data_cache[key] = make_classification_data(
            image_size=IMAGE_SIZE, n_train=N_TRAIN, n_val=256, n_test=512,
            noise=NOISE, seed=seed)
    return _data_cache[key]


def get_public(kind: str = "aligned", seed: int = 0) -> np.ndarray:
    key = ("pub", kind, seed)
    if key not in _data_cache:
        _data_cache[key] = make_public_data(get_data(seed),
                                            n_public=N_PUBLIC, kind=kind,
                                            seed=seed + 1)
    return _data_cache[key]


def run_cell(method: str, alpha: float, nodes: int = 8,
             topology: str = "ring", public_kind: str = "aligned",
             seed: int = 4, steps: Optional[int] = None,
             use_cache: bool = True) -> Dict[str, Any]:
    """One simulator run; returns a JSON-able result dict."""
    steps = steps or STEPS
    tag = f"{method}_a{alpha}_n{nodes}_{topology}_{public_kind}_s{seed}_t{steps}"
    path = os.path.join(CACHE_DIR, tag + ".json")
    if use_cache and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    algorithm, kd_mode = _METHODS[method]
    topo = "chain" if algorithm == "relaysgd" else topology
    tcfg = TrainConfig(
        algorithm=algorithm, topology=topo, num_nodes=nodes, alpha=alpha,
        steps=steps, batch_size=BATCH, seed=seed,
        lr=0.5 if "qg" in algorithm or algorithm == "centralized" else 0.1,
        weight_decay=1e-4 if "qg" in algorithm else 5e-4,
        idkd=IDKDConfig(start_step=int(steps * KD_START_FRAC),
                        temperature=KD_TEMPERATURE))
    mcfg = SMALL_CONFIG.replace(image_size=IMAGE_SIZE)
    data = get_data(seed=0)
    pub = get_public(public_kind) if kd_mode else None
    sim = DecentralizedSimulator(mcfg, tcfg, data, pub, kd_mode=kd_mode,
                                 eval_every=max(steps // 4, 1),
                                 eval_batches=2)
    r = sim.run()
    out = {
        "method": method, "alpha": alpha, "nodes": nodes,
        "topology": topo, "public_kind": public_kind, "seed": seed,
        "steps": steps,
        "final_acc": r.final_acc,
        "acc_history": r.acc_history,
        "loss_history": r.loss_history,
        "consensus_history": r.consensus_history,
        "id_fraction": r.id_fraction,
        "comm_bytes_per_iter": r.comm_bytes_per_iter,
        "label_bytes_total": r.label_bytes_total,
        "pre_hist": np.asarray(r.pre_hist).tolist()
        if r.pre_hist is not None else None,
        "post_hist": np.asarray(r.post_hist).tolist()
        if r.post_hist is not None else None,
        "wall_seconds": r.wall_seconds,
    }
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f)
    return out


def mean_std(cells) -> str:
    accs = [c["final_acc"] * 100 for c in cells]
    return f"{np.mean(accs):.2f} ± {np.std(accs):.2f}"


def step_percentiles(samples) -> tuple:
    """(p50, p95) of a per-step timing sample list (µs/step).

    BENCH cells record both: the regression guard gates on the median
    (``us_per_step`` — robust to one noisy round), while the p95 keeps
    tail latency visible in the committed baselines without ever
    failing a build on it.
    """
    a = np.asarray(list(samples), np.float64)
    return (float(np.percentile(a, 50)), float(np.percentile(a, 95)))
