"""Paper Table 3: ring vs social (Florentine-families) topologies."""
from __future__ import annotations

import time

from benchmarks.common import mean_std, run_cell

GRID = [("ring", 8), ("social", 15)]
METHODS = ["qg-dsgdm-n", "qg-idkd"]
ALPHAS = [0.1, 0.05]


def run(seeds=(4,)):
    rows, csv = [], []
    for method in METHODS:
        row = {"method": method}
        for topo, n in GRID:
            for alpha in ALPHAS:
                t0 = time.time()
                cells = [run_cell(method, alpha, nodes=n, topology=topo,
                                  seed=s) for s in seeds]
                row[f"{topo}{n}/α={alpha}"] = mean_std(cells)
                csv.append((f"table3/{method}/{topo}{n}/a{alpha}",
                            (time.time() - t0) * 1e6,
                            f"acc={cells[0]['final_acc']*100:.2f}"))
        rows.append(row)
    return rows, csv


def render(rows) -> str:
    cols = list(rows[0].keys())
    lines = [" | ".join(cols), " | ".join(["---"] * len(cols))]
    for r in rows:
        lines.append(" | ".join(str(r[c]) for c in cols))
    return "\n".join(lines)


if __name__ == "__main__":
    print(render(run()[0]))
